"""Shared benchmark utilities: timing + CSV/JSON emission."""
from __future__ import annotations

import json
import time
from typing import Callable


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """Returns (result, microseconds per call)."""
    result, us, _ = timed_compile(fn, *args, repeats=repeats, **kw)
    return result, us


def timed_compile(fn: Callable, *args, repeats: int = 3, **kw):
    """``timed`` with the warmup made explicit: also returns the first
    (compiling) call's wall-clock in seconds, so benchmarks can report
    ``compile_seconds`` separately instead of folding jit compile into —
    or silently dropping it from — the steady-state per-call figure."""
    t0 = time.perf_counter()
    fn(*args, **kw)                      # warmup / compile
    compile_seconds = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(repeats):
        result = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return result, us, compile_seconds


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(name: str, **fields):
    """One machine-readable result line (used by bench_dse throughput/RSS)."""
    print(json.dumps({"name": name, **fields}, sort_keys=True))
