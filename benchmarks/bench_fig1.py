"""Paper Fig. 1 reproduction: layer-wise firing-neuron ratio for a
784-600-600-600 style model (reduced widths on CPU), trained on the
synthetic MNIST/FMNIST stand-ins.  The claim under test: firing density
DECLINES with depth (static:firing ratio grows), the motivation for
layer-wise LHR."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import encoding, snn, sparsity, train_snn
from repro.data import synthetic


def run(quick: bool = False):
    widths = 128 if quick else 256
    steps = 80 if quick else 200
    for name, seed in (("synth-mnist", 0), ("synth-fmnist", 17)):
        data = synthetic.make_images(name=name, seed=seed,
                                     n_train=1024, n_test=256,
                                     noise=0.15 if seed == 0 else 0.25)
        cfg = snn.SNNConfig(
            name=name, input_shape=(28, 28),
            layers=(snn.Dense(widths), snn.Dense(widths), snn.Dense(widths),
                    snn.Dense(10 * 10)),
            num_classes=10, pcr=10, num_steps=15)
        res = train_snn.train(cfg, data, steps=steps, batch_size=64)
        key = jax.random.key(5)
        x = jnp.asarray(data.x_test[:64])
        spikes_in = encoding.rate_encode(key, x, cfg.num_steps)
        (stats, us) = timed(lambda: sparsity.analyze(cfg, res.params,
                                                     spikes_in), repeats=1)
        ratios = [s.firing_ratio for s in stats]
        for s in stats:
            emit(f"fig1/{name}/layer{s.layer}", us,
                 f"firing_ratio={s.firing_ratio:.4f} "
                 f"static:firing={s.static_to_firing:.1f}")
        hidden = ratios[1:]                # exclude encoded input layer
        monotone = all(hidden[i] >= hidden[i + 1] - 0.02
                       for i in range(len(hidden) - 1))
        emit(f"fig1/{name}/deeper_is_sparser", 0.0,
             f"{monotone} acc={res.test_accuracy:.3f}")


if __name__ == "__main__":
    run()
