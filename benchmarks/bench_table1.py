"""Paper Table I reproduction: latency (cycles), FPGA resources (LUT/REG) and
energy for every TW row of the five networks, driven by the paper's own
published per-layer spike statistics.  All rows of a network evaluate in ONE
batched call through the vectorized cycle model and component library (the
DSE fast path); per-row output gives prediction vs paper value + relative
error, and summary lines give median errors (the reproduction fidelity
reported in EXPERIMENTS.md)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core.accelerator import cycle_model, paper_data, paper_nets, resources


def run(quick: bool = False):
    lat_errs, lut_errs, reg_errs, e_errs = [], [], [], []
    for net in paper_data.NETS:
        cfg0 = paper_nets.build(net)
        counts = paper_nets.paper_counts(net, cfg0)
        rows = paper_data.tw_rows(net)
        lhr = np.asarray([row.lhr for row in rows], dtype=np.int64)
        (cycles, us) = timed(
            lambda: cycle_model.latency_cycles(cfg0, counts, lhr_matrix=lhr))
        res = resources.estimate_vector(cfg0, lhr_matrix=lhr)
        energy = resources.energy_mj_vector(cfg0, counts, cycles,
                                            lhr_matrix=lhr, lut=res.lut)
        for i, row in enumerate(rows):
            lat_err = cycles[i] / row.cycles - 1
            lat_errs.append(abs(lat_err))
            derived = (f"cycles={cycles[i]:.0f}/paper={row.cycles:.0f}"
                       f"({lat_err:+.0%})")
            if row.lut is not None:
                lut_err = res.lut[i] / (row.lut * 1e3) - 1
                lut_errs.append(abs(lut_err))
                reg_errs.append(abs(res.reg[i] / (row.reg * 1e3) - 1))
                derived += f" lut={res.lut[i]/1e3:.1f}K({lut_err:+.0%})"
            if row.energy_mj is not None:
                e_err = energy[i] / row.energy_mj - 1
                e_errs.append(abs(e_err))
                derived += f" E={energy[i]:.2f}mJ({e_err:+.0%})"
            lhr_s = "x".join(map(str, row.lhr))
            emit(f"table1/{net}/lhr-{lhr_s}", us / len(rows), derived)
    emit("table1/median_latency_err", 0.0, f"{np.median(lat_errs):.1%}")
    emit("table1/median_lut_err", 0.0, f"{np.median(lut_errs):.1%}")
    emit("table1/median_reg_err", 0.0, f"{np.median(reg_errs):.1%}")
    emit("table1/median_energy_err", 0.0, f"{np.median(e_errs):.1%}")

    # headline claims
    base = resources.estimate(paper_nets.build("net-1", lhr=(1, 1, 1)))
    opt = resources.estimate(paper_nets.build("net-1", lhr=(4, 8, 8)))
    emit("table1/claim_net1_resource_saving", 0.0,
         f"{1 - opt.lut/base.lut:.0%} (paper: 76%)")

    # Paper text: "31.25x speed up, 27% fewer resources" for net-4 vs [34].
    # The paper's own (32,16,8,16,64) table row is 843,518 cycles = only
    # 1.85x — the text's 31.25x matches the FASTEST config's latency column
    # (x0.03 ratio).  We report both readings.
    cfg0 = paper_nets.build("net-4")
    counts = paper_nets.paper_counts("net-4", cfg0)
    prior = paper_data.baseline_row("net-4").cycles
    both = cycle_model.latency_cycles(
        cfg0, counts, lhr_matrix=np.asarray([(1, 1, 1, 1, 1),
                                             (32, 16, 8, 16, 64)]))
    fastest, row32 = float(both[0]), float(both[1])
    emit("table1/claim_net4_speedup_vs_prior", 0.0,
         f"fastest-config={prior/fastest:.1f}x (paper text: 31.25x); "
         f"lhr-32x16x8x16x64={prior/row32:.1f}x (paper row: 1.85x)")


if __name__ == "__main__":
    run()
