"""Kernel benchmarks: micro (block-skip rates + fused-LIF timings) and an
end-to-end BPTT benchmark of the training hot path.

The micro section reports skip fractions of the sparsity-aware spike GEMM on
real trained-SNN traffic (the TPU-granular analogue of the paper's PENC
savings).  The BPTT section times the forward (``loss_fn``) and one full
forward+backward training step (``jax.value_and_grad`` of the rate loss
through ``lax.scan``) for all three matmul backends — pure jnp, the
block-skip Pallas kernel (now with block-skip *backward* kernels behind its
custom_vjp), and the fused GEMM+LIF scan-step kernel — across the built-in
workloads' T x population grid, emitting one JSON line per cell in the
``BENCH_*.json`` schema (``*_fwd_seconds`` / ``*_bwd_seconds`` /
``*_step_seconds`` per backend, ``skip_fraction`` / ``bwd_skip_fraction``)
so ``tools/bench_diff.py`` tracks the training hot path across runs.  Conv
workloads (dvs-conv) are first-class cells: their Conv layers route through
the patch-tiled block-skip kernel and their skip fractions are measured on
the im2col patch matrices the kernel actually tiles.

Wall-clock here is CPU-interpret (no TPU) — the hardware-independent figure
of merit is the SKIP FRACTION.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json, timed, timed_compile
from repro.core import encoding, snn, train_snn
from repro.core.workloads import registry
from repro.data import synthetic
from repro.kernels import ops, ref


def _micro(quick: bool) -> None:
    # trained-model traffic
    data = synthetic.make_images(seed=0, n_train=512, n_test=128)
    cfg = snn.SNNConfig(name="k", input_shape=(28, 28),
                        layers=(snn.Dense(256), snn.Dense(256),
                                snn.Dense(10 * 5)),
                        num_classes=10, pcr=5, num_steps=15)
    res = train_snn.train(cfg, data, steps=60 if quick else 150,
                          batch_size=64)
    x = jnp.asarray(data.x_test[:32])
    spikes_in = encoding.rate_encode(jax.random.key(0), x, cfg.num_steps)
    all_spikes = snn.apply(cfg, res.params, spikes_in,
                           return_all_layers=True)
    layer_w = [res.params[0]["w"], res.params[1]["w"], res.params[2]["w"]]
    trains = [spikes_in.reshape(-1, 784)] + [
        s.reshape(-1, s.shape[-1]) for s in all_spikes[:-1]]
    for l, (train, w) in enumerate(zip(trains, layer_w)):
        density = float(train.mean())
        base = ops.skip_fraction(train, block_m=8, block_k=128)
        perm = ops.firing_rate_permutation(train.mean(axis=0))
        sp, wp = ops.apply_permutation(train, w, perm)
        perm_skip = ops.skip_fraction(sp, block_m=8, block_k=128)
        out, us = timed(lambda: ops.spike_gemm(
            sp[:64], wp, block_m=8).block_until_ready(), repeats=1)
        want = ref.spike_gemm_ref(sp[:64], wp)
        ok = bool(jnp.allclose(out, want, atol=1e-3))
        emit(f"kernels/spike_gemm/layer{l}", us,
             f"density={density:.3f} skip={base:.2f} "
             f"skip_profiled={perm_skip:.2f} allclose={ok}")

    # fused LIF shape sweep
    for shape in [(8, 512), (64, 4096)]:
        u = jnp.zeros(shape)
        s = jnp.zeros(shape)
        c = jnp.ones(shape) * 0.5
        (out, us) = timed(lambda: ops.lif_step(
            u, s, c, beta=0.9, threshold=1.0)[0].block_until_ready(),
            repeats=1)
        emit(f"kernels/lif_step/{shape[0]}x{shape[1]}", us, "interpret-mode")


def _layer_skip_fractions(cfg: snn.SNNConfig, params, spikes_in
                          ) -> tuple[float, float]:
    """Mean (base, profile-permuted) tile-skip fraction over every spiking
    layer's input traffic — the tiles the kernel path actually skips.
    Dense layers measure the flattened train; Conv layers measure the
    im2col PATCH matrix their block-skip kernel tiles (spike_conv.py).
    The profiled permutation is Dense-only, so conv layers contribute their
    base skip to the profiled mean.  ``layer_input_trains`` yields exactly
    one train per spiking layer."""
    trains = snn.layer_input_trains(cfg, params, spikes_in)
    bm, bk = snn.KERNEL_BLOCKS["block_m"], snn.KERNEL_BLOCKS["block_k"]
    base, perm = [], []
    for spec, train in zip(cfg.spiking_layers(), trains):
        if isinstance(spec, snn.Dense):
            flat = train.reshape(-1, int(np.prod(train.shape[2:])))
            base.append(ops.skip_fraction(flat, bm, bk))
            p = train_snn.train_firing_permutation(train)
            perm.append(ops.skip_fraction(flat[:, p], bm, bk))
        elif isinstance(spec, snn.Conv):
            t, b = train.shape[:2]
            patches = ops.conv_patches(
                train.reshape((t * b,) + train.shape[2:]),
                spec.kernel, spec.kernel, spec.stride, spec.padding)
            frac = ops.skip_fraction(patches, bm, bk)
            base.append(frac)
            perm.append(frac)
    return float(np.mean(base)), float(np.mean(perm))


def _bptt_cell(wl: registry.Workload, T: int, pop: float) -> None:
    cfg = wl.build(T, pop)
    data = wl.make_data(T)
    res = train_snn.train(cfg, data, steps=wl.train_steps,
                          batch_size=wl.batch_size, lr=wl.lr, seed=0)
    xb = jnp.asarray(data.x_train[:wl.batch_size])
    yb = jnp.asarray(data.y_train[:wl.batch_size])
    key = jax.random.key(0)

    fields = {}
    step_seconds = {}
    for backend in snn.MATMUL_BACKENDS:
        fwd = jax.jit(
            lambda p, b=backend: train_snn.loss_fn(cfg, p, key, xb, yb,
                                                   matmul_backend=b))
        vg = jax.jit(jax.value_and_grad(
            lambda p, b=backend: train_snn.loss_fn(cfg, p, key, xb, yb,
                                                   matmul_backend=b)))
        # repeats=3: these fields are regression-tracked by bench_diff, so
        # average away single-sample scheduler noise on shared CI runners.
        # The warmup call is the explicit compile pass — its wall-clock is
        # reported separately as *_compile_seconds, never folded into the
        # steady-state per-call figures.
        _, us_fwd, c_fwd = timed_compile(
            lambda: jax.block_until_ready(fwd(res.params)), repeats=3)
        _, us, c_vg = timed_compile(
            lambda: jax.block_until_ready(vg(res.params)), repeats=3)
        step_seconds[backend] = us / 1e6
        fields[f"{backend}_fwd_seconds"] = round(us_fwd / 1e6, 6)
        # the backward's cost is the fwd+bwd step minus the fwd-only pass
        # (both jitted end to end; clamp against scheduler noise)
        fields[f"{backend}_bwd_seconds"] = round(
            max((us - us_fwd) / 1e6, 0.0), 6)
        fields[f"{backend}_step_seconds"] = round(us / 1e6, 6)
        # total jit-compile cost of this backend's cell (fwd + fwd/bwd):
        # what every fresh cellfarm worker pays once per cell, and what
        # stacked training amortizes over the whole cell stack
        fields[f"{backend}_compile_seconds"] = round(c_fwd + c_vg, 6)

    spikes_in = train_snn._encode_input(
        jax.random.key(1), jnp.asarray(data.x_test[:32]), T)
    skip, skip_profiled = _layer_skip_fractions(cfg, res.params, spikes_in)
    emit_json(f"kernels/bptt/{wl.name}/T{T}/p{pop:g}",
              speedup=round(step_seconds["jnp"]
                            / max(step_seconds["spike_gemm"], 1e-12), 4),
              fused_speedup=round(
                  step_seconds["jnp"]
                  / max(step_seconds["spike_gemm_fused"], 1e-12), 4),
              skip_fraction=round(skip, 4),
              skip_fraction_profiled=round(skip_profiled, 4),
              # dW = S^T.g reuses the forward's occupancy flags verbatim, so
              # the backward's spike-side pass skips exactly the tiles the
              # forward skips; the dS pass adds cotangent-occupancy skips on
              # top (zero early in training, grows as surrogates saturate)
              bwd_skip_fraction=round(skip, 4),
              accuracy=round(res.test_accuracy, 4),
              **fields)


def _bptt(quick: bool) -> None:
    # conv cells ride the same grid now that Conv routes through the
    # patch-tiled kernel; quick mode keeps one shrunk dvs-conv cell in CI
    names = ["mnist-mlp", "dvs-conv"] if quick else registry.names()
    for name in names:
        wl = dataclasses.replace(
            registry.get(name),
            n_train=256, n_test=64, train_steps=20 if quick else 60)
        if any(isinstance(l, snn.Conv) for l in wl.layers):
            # interpret-mode Pallas executes the B·OH·OW patch grid
            # serially — shrink the retina/batch so conv cells stay
            # benchmarkable on CPU (skip fractions are size-honest either
            # way; wall-clock is CPU-interpret for every cell)
            wl = dataclasses.replace(
                wl, input_shape=(8, 8, 2), batch_size=16, n_train=128,
                num_steps_choices=(2,) if quick else (4, 8),
                population_choices=(1.0,) if quick else (1.0, 2.0))
        Ts = wl.num_steps_choices[:2] if quick else wl.num_steps_choices
        pops = wl.population_choices[:2] if quick else wl.population_choices
        for T in Ts:
            for pop in pops:
                _bptt_cell(wl, int(T), float(pop))


def run(quick: bool = False):
    _micro(quick)
    _bptt(quick)


if __name__ == "__main__":
    run()
