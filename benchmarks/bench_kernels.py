"""Kernel-level benchmarks: block-skip rates of the sparsity-aware spike
GEMM on real trained-SNN traffic (the TPU-granular analogue of the paper's
PENC savings), and fused-LIF correctness/shape sweep timings in interpret
mode.  Wall-clock here is CPU-interpret (no TPU) — the figure of merit is
the SKIP FRACTION, which is hardware-independent."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import encoding, snn, train_snn
from repro.data import synthetic
from repro.kernels import ops, ref


def run(quick: bool = False):
    # trained-model traffic
    data = synthetic.make_images(seed=0, n_train=512, n_test=128)
    cfg = snn.SNNConfig(name="k", input_shape=(28, 28),
                        layers=(snn.Dense(256), snn.Dense(256),
                                snn.Dense(10 * 5)),
                        num_classes=10, pcr=5, num_steps=15)
    res = train_snn.train(cfg, data, steps=60 if quick else 150,
                          batch_size=64)
    x = jnp.asarray(data.x_test[:32])
    spikes_in = encoding.rate_encode(jax.random.key(0), x, cfg.num_steps)
    all_spikes = snn.apply(cfg, res.params, spikes_in,
                           return_all_layers=True)
    layer_w = [res.params[0]["w"], res.params[1]["w"], res.params[2]["w"]]
    trains = [spikes_in.reshape(-1, 784)] + [
        s.reshape(-1, s.shape[-1]) for s in all_spikes[:-1]]
    for l, (train, w) in enumerate(zip(trains, layer_w)):
        density = float(train.mean())
        base = ops.skip_fraction(train, block_m=8, block_k=128)
        perm = ops.firing_rate_permutation(train.mean(axis=0))
        sp, wp = ops.apply_permutation(train, w, perm)
        perm_skip = ops.skip_fraction(sp, block_m=8, block_k=128)
        out, us = timed(lambda: ops.spike_gemm(
            sp[:64], wp, block_m=8).block_until_ready(), repeats=1)
        want = ref.spike_gemm_ref(sp[:64], wp)
        ok = bool(jnp.allclose(out, want, atol=1e-3))
        emit(f"kernels/spike_gemm/layer{l}", us,
             f"density={density:.3f} skip={base:.2f} "
             f"skip_profiled={perm_skip:.2f} allclose={ok}")

    # fused LIF shape sweep
    for shape in [(8, 512), (64, 4096)]:
        u = jnp.zeros(shape)
        s = jnp.zeros(shape)
        c = jnp.ones(shape) * 0.5
        (out, us) = timed(lambda: ops.lif_step(
            u, s, c, beta=0.9, threshold=1.0)[0].block_until_ready(),
            repeats=1)
        emit(f"kernels/lif_step/{shape[0]}x{shape[1]}", us, "interpret-mode")


if __name__ == "__main__":
    run()
