"""Stacked-cell training benchmark: the ISSUE-8 headline number.

A same-signature grid of model cells (one training recipe, different
seeds — the shape every seed-replicated DSE sweep and every ``datasets``
axis of same-topology variants produces) trains two ways:

* **farm** — the pre-stacking path: per-cell jobs sharded over spawned
  worker processes (``cellfarm.resolve_cells(stack=False)``).  Every
  worker pays a fresh interpreter + JAX import, and every cell a fresh
  jit compile.
* **stacked** — one ``jit(vmap(train_step))`` batch in-process
  (``cellstack.resolve_stacked``): one compile for the whole stack, the
  cell axis folded into the block-skip kernels' M dimension.

Both paths publish through the content-addressed ``TraceCache`` and the
stacked cells are asserted to be *cache hits for a later solo resolve* —
the bit-exactness contract that makes the comparison honest.  The BENCH
line reports ``cells_per_second`` for the stacked path (tracked by
``tools/bench_diff.py`` as higher-is-better), the farm path's figure, the
``stack_speedup`` ratio, and the stack's jit ``compile_seconds``
separately.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

from benchmarks.common import emit_json
from repro.core import snn, workloads
from repro.distributed import cellfarm, cellstack


def _workload(quick: bool) -> workloads.Workload:
    base = workloads.get("mnist-mlp")
    return dataclasses.replace(
        base, name="bench-cellstack-mlp",
        layers=(snn.Dense(24 if quick else 48),),
        pcr=2, n_train=256, n_test=128,
        train_steps=20 if quick else 60, trace_samples=32)


def run(quick: bool = False):
    wl = _workload(quick)
    n_cells = 4 if quick else 8            # acceptance floor: >= 4 cells
    assignment = {"num_steps": 2, "population": 1.0}
    jobs = [cellfarm.CellJob(workload=wl, assignment=assignment, seed=s)
            for s in range(n_cells)]
    sigs = {cellstack.stack_signature(j) for j in jobs}
    assert len(sigs) == 1, f"grid must share one stack signature, got {sigs}"

    with tempfile.TemporaryDirectory() as root:
        # (a) per-cell process farm on the same machine
        t0 = time.perf_counter()
        farmed = cellfarm.resolve_cells(jobs, f"{root}/farm", workers=2,
                                        stack=False)
        farm_dt = time.perf_counter() - t0
        assert all(o.trained for o in farmed)
        cellfarm.shutdown_pool()           # don't leak workers past the bench

        # (b) one vmapped stack, in-process
        cache = workloads.TraceCache(root=f"{root}/stack")
        stats: dict = {}
        t0 = time.perf_counter()
        outcomes = cellstack.resolve_stacked(jobs, cache.root, cache=cache,
                                             stats=stats)
        stack_dt = time.perf_counter() - t0
        assert all(o.trained for o in outcomes)

        # the honesty check: every stacked cell is a later solo-recipe hit
        for job in jobs:
            art = cache.resolve(job.workload, job.assignment, seed=job.seed)
            assert art.cache_hit, "stacked cell missed on solo resolve"

        speedup = farm_dt / max(stack_dt, 1e-9)
        emit_json("cellstack/grid",
                  cells=n_cells,
                  farm_seconds=round(farm_dt, 3),
                  farm_cells_per_second=round(n_cells / max(farm_dt, 1e-9),
                                              3),
                  stacked_seconds=round(stack_dt, 3),
                  cells_per_second=round(n_cells / max(stack_dt, 1e-9), 3),
                  compile_seconds=round(stats.get("compile_seconds", 0.0),
                                        3),
                  stack_speedup=round(speedup, 3))
        if speedup <= 1.0:
            raise AssertionError(
                f"stacked training must beat the per-cell farm on a "
                f"same-signature grid: speedup {speedup:.3f} <= 1")


if __name__ == "__main__":
    run()
