"""Elastic cell-fleet benchmark: workers drain a spooled study through the
shared-cache lease protocol (``repro.distributed.fleet``).

What the BENCH lines measure (tracked by ``tools/bench_diff.py``):

* ``cells_per_second`` — fleet-side cell throughput: wall-clock from
  "jobs spooled" to "every cell published", with two in-process
  ``FleetWorker``\\ s draining the queue (threads, not spawned
  interpreters — the lease/spool machinery is what's under test, and a
  JAX import per worker would drown it).
* ``lease_takeovers`` — every cell starts under a *stale* lease left by
  a simulated dead fleet, so the workers must break and reclaim each one
  before training; the count asserts the takeover path runs at benchmark
  scale, not just in unit tests.
* ``cache_hit_rate`` — dedup measure: a second pass over the same study
  resolves every cell from the shared cache.  A drop below 1.0 means the
  fleet trained a cell the cache should have served.

The run also *asserts* the contract: the fleet trains each cell exactly
once (``sum(cells_trained) == n_cells``, zero failures), every stale
lease is taken over, and the replay pass is all hits.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time

from benchmarks.common import emit_json
from repro.core import snn, workloads
from repro.distributed import cellfarm, fleet


def _workload(quick: bool) -> workloads.Workload:
    base = workloads.get("mnist-mlp")
    return dataclasses.replace(
        base, name="bench-fleet-mlp",
        layers=(snn.Dense(16 if quick else 32),),
        pcr=1, n_train=128 if quick else 512, n_test=64,
        train_steps=4 if quick else 40, trace_samples=16)


def run(quick: bool = False):
    wl = _workload(quick)
    t_values = (2, 3) if quick else (2, 3, 4)
    pops = (0.5, 1.0)
    jobs = [cellfarm.CellJob(workload=wl,
                             assignment={"num_steps": t, "population": p})
            for t in t_values for p in pops]
    n_cells = len(jobs)
    n_workers = 2

    with tempfile.TemporaryDirectory() as root:
        # a dead fleet's leftovers: one stale lease per cell, heartbeat
        # an hour past — every claim must go through the takeover path
        old = time.time() - 3600.0
        for job in jobs:
            lease = fleet.acquire(root, cellfarm._job_key(job), "w-dead")
            os.utime(lease.path, (old, old))
        fleet.spool(root, jobs)

        members = [fleet.FleetWorker(root, worker_id=f"bench-w{i}",
                                     poll=0.01)
                   for i in range(n_workers)]
        threads = [threading.Thread(target=w.run,
                                    kwargs=dict(idle_timeout=0.5))
                   for w in members]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        out = fleet.resolve_cluster(jobs, root, timeout=600.0)
        dt = time.perf_counter() - t0
        for t in threads:
            t.join()

        trained = sum(w.stats["cells_trained"] for w in members)
        failed = sum(w.stats["cells_failed"] for w in members)
        takeovers = sum(w.stats["lease_takeovers"] for w in members)
        assert [o.error for o in out] == [None] * n_cells
        assert trained == n_cells and failed == 0, (trained, failed)
        assert takeovers == n_cells, takeovers

        # dedup replay: the whole study again, straight from the cache
        cache = workloads.TraceCache(root=root)
        for job in jobs:
            art = cache.resolve(job.workload, job.assignment, seed=job.seed)
            assert art.cache_hit
        hit_rate = cache.hits / (cache.hits + cache.misses)
        assert hit_rate == 1.0, cache.stats

        emit_json("fleet/two_worker_drain",
                  cells=n_cells, workers=n_workers,
                  cells_per_second=round(n_cells / dt, 4),
                  lease_takeovers=takeovers,
                  cache_hit_rate=round(hit_rate, 4))


if __name__ == "__main__":
    run(quick=True)
