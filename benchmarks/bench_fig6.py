"""Paper Fig. 6 reproduction: the latency-LUT trade-off cloud per network —
a full LHR design-space sweep with Pareto frontier extraction, plus the
DSE engine's throughput (configs evaluated per second: the paper's "rapid
exploration" claim).  Runs on the streaming multi-axis engine: candidates
are never materialized, only the (cycles, lut, energy) frontier survives."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import dse
from repro.core.accelerator import paper_data, paper_nets


def _fmt(row: dict) -> str:
    return (f"lhr={'x'.join(map(str, row['lhr']))} "
            f"cycles={row['cycles']:.0f} lut={row['lut']/1e3:.1f}K "
            f"E={row['energy']:.2f}mJ")


def run(quick: bool = False):
    nets = ["net-1", "net-3"] if quick else ["net-1", "net-2", "net-3",
                                             "net-4", "net-5"]
    for net in nets:
        cfg = paper_nets.build(net)
        counts = paper_nets.paper_counts(net, cfg)
        space = dse.SearchSpace.product_lhr(cfg,
                                            max_lhr=64 if quick else 256)
        t0 = time.perf_counter()
        result = dse.search(cfg, counts, space,
                            objectives=("cycles", "lut", "energy"))
        dt = time.perf_counter() - t0
        n = result.n_evaluated
        # the paper's Fig. 6 frontier is 2-objective (latency vs area);
        # restricting the 3-obj frontier to its (cycles, lut) mask recovers
        # exactly the global 2-objective frontier
        front = result.frontier
        fr = front.take(dse.pareto_mask(front.columns["cycles"],
                                        front.columns["lut"]))
        fr = fr.sorted_by("cycles")
        emit(f"fig6/{net}/sweep", dt / n * 1e6,
             f"candidates={n} pareto={len(fr)} "
             f"throughput={n/dt:.0f}cfg/s")
        for tag, row in (("fastest", fr.row(0)),
                         ("smallest", fr.row(len(fr) - 1)),
                         ("min_energy", front.row(front.argmin("energy")))):
            emit(f"fig6/{net}/{tag}", 0.0, _fmt(row))
        # irregularity the paper highlights: frontier points where fewer
        # LUTs do NOT cost latency (layer-wise allocation effect)
        cyc = fr.columns["cycles"]
        lut = fr.columns["lut"]
        wins = int(np.sum((lut[1:] < lut[:-1]) & (cyc[1:] <= cyc[:-1] * 1.02)))
        emit(f"fig6/{net}/free_area_savings", 0.0, f"{wins} frontier steps")


if __name__ == "__main__":
    run()
