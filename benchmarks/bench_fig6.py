"""Paper Fig. 6 reproduction: the latency-LUT trade-off cloud per network —
a full LHR design-space sweep with Pareto frontier extraction, plus the
DSE engine's throughput (configs evaluated per second: the paper's "rapid
exploration" claim)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import dse
from repro.core.accelerator import paper_data, paper_nets


def run(quick: bool = False):
    nets = ["net-1", "net-3"] if quick else ["net-1", "net-2", "net-3",
                                             "net-4", "net-5"]
    for net in nets:
        cfg = paper_nets.build(net)
        counts = paper_nets.paper_counts(net, cfg)
        t0 = time.perf_counter()
        result = dse.sweep(cfg, counts, max_lhr=64 if quick else 256)
        dt = time.perf_counter() - t0
        n = len(result.candidates)
        frontier = result.frontier
        emit(f"fig6/{net}/sweep", dt / n * 1e6,
             f"candidates={n} pareto={len(frontier)} "
             f"throughput={n/dt:.0f}cfg/s")
        # frontier extremes + knee
        fr = sorted(frontier, key=lambda c: c.cycles)
        for tag, c in (("fastest", fr[0]), ("smallest", fr[-1]),
                       ("min_energy", result.min_energy())):
            emit(f"fig6/{net}/{tag}", 0.0,
                 f"lhr={'x'.join(map(str, c.lhr))} cycles={c.cycles:.0f} "
                 f"lut={c.lut/1e3:.1f}K E={c.energy_mj:.2f}mJ")
        # irregularity the paper highlights: frontier points where fewer
        # LUTs do NOT cost latency (layer-wise allocation effect)
        wins = sum(1 for a, b in zip(fr, fr[1:])
                   if b.lut < a.lut and b.cycles <= a.cycles * 1.02)
        emit(f"fig6/{net}/free_area_savings", 0.0, f"{wins} frontier steps")


if __name__ == "__main__":
    run()
