"""Paper Fig. 7 reproduction: spike-train length vs population-coding ratio
— accuracy (trained on synthetic MNIST stand-in) and hardware latency from
the cycle model, for PCR in {1, 10, 30} over a T sweep.

Claims under test: (i) population coding rescues short-train accuracy,
(ii) latency grows with T and with PCR, (iii) there is a T "sweet spot"
(paper: ~15 steps) past which accuracy saturates."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import encoding, snn, train_snn
from repro.core.accelerator import arch as hw_arch
from repro.core.accelerator import cycle_model
from repro.data import synthetic


def run(quick: bool = False):
    t_values = [2, 8, 15] if quick else [2, 4, 6, 8, 10, 15, 20, 25]
    pcrs = [1, 10] if quick else [1, 10, 30]
    # hard enough that short trains actually fail without population coding
    data = synthetic.make_images(seed=3, n_train=768, n_test=256, noise=0.55)
    results = {}
    for pcr in pcrs:
        for T in t_values:
            cfg = snn.SNNConfig(
                name=f"pop{pcr}-T{T}", input_shape=(28, 28),
                layers=(snn.Dense(64), snn.Dense(64),
                        snn.Dense(10 * pcr)),
                num_classes=10, pcr=pcr, num_steps=T)
            res = train_snn.train(cfg, data, steps=60 if quick else 120,
                                  batch_size=64)
            counts = train_snn.trace_counts(cfg, res.params, data.x_test,
                                            max_samples=16)
            hw = hw_arch.from_layer_sizes(
                cfg.name, (784, 64, 64, 10 * pcr), lhr=(1, 1, 1),
                num_steps=T)
            # both variants in one batched call: the parallel classifier and
            # the serial-output one (a single NU serves the whole classifier
            # — where the paper's "higher PCR costs latency" materializes)
            both = cycle_model.latency_cycles(
                hw, counts, lhr_matrix=np.asarray([(1, 1, 1),
                                                   (1, 1, 10 * pcr)]))
            cycles, cyc_serial = float(both[0]), float(both[1])
            results[(pcr, T)] = (res.test_accuracy, cycles, cyc_serial)
            emit(f"fig7/pop{pcr}/T{T}", 0.0,
                 f"acc={res.test_accuracy:.3f} cycles={cycles:.0f} "
                 f"serial_out={cyc_serial:.0f}")
    # claims
    if 1 in pcrs and 10 in pcrs:
        t0 = t_values[0]
        emit("fig7/claim_pop_rescues_short_trains", 0.0,
             f"pop10@T{t0}={results[(10, t0)][0]:.3f} >= "
             f"pop1@T{t0}={results[(1, t0)][0]:.3f}: "
             f"{results[(10, t0)][0] >= results[(1, t0)][0]}")
    for pcr in pcrs:
        cyc = [results[(pcr, T)][1] for T in t_values]
        emit(f"fig7/claim_latency_monotone_in_T/pop{pcr}", 0.0,
             f"{all(a < b for a, b in zip(cyc, cyc[1:]))}")
    t_mid = t_values[len(t_values) // 2]
    if (10, t_mid) in results and (1, t_mid) in results:
        # the paper's two-sided claim: PCR costs latency when the output
        # layer is serialized, and the layer-wise pipeline HIDES that cost
        # when the classifier has its own NUs (paper Sec. VI-C conclusion)
        serial_cost = results[(10, t_mid)][2] > results[(1, t_mid)][2]
        pipelined_free = (results[(10, t_mid)][1]
                          <= results[(1, t_mid)][1] * 1.1)
        emit("fig7/claim_higher_pcr_costs_latency_when_serialized", 0.0,
             f"{serial_cost}")
        emit("fig7/claim_pipeline_hides_pcr_cost", 0.0, f"{pipelined_free}")


if __name__ == "__main__":
    run()
