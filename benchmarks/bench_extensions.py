"""Beyond-Table-I DSE dimensions the paper names but does not quantify:

  * memory blocks per layer (port contention vs BRAM/mapping-logic area) —
    paper Sec. IV "reduce the memory blocks";
  * synapse weight precision (BRAM footprint vs fixed-point accuracy) —
    paper Sec. III "weight quantization size ... significantly affects the
    system's memory requirements";
  * input spike-coding scheme (rate vs TTFS vs burst) — paper Sec. II-A
    lists the schemes; Sec. VI-B attributes a rival's accuracy edge to
    "optimized spike encoding schemes".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import dse, encoding, snn, train_snn, validate
from repro.core.accelerator import arch as hw_arch
from repro.core.accelerator import paper_nets
from repro.data import synthetic


def run(quick: bool = False):
    # ---- memory-block contention sweep (net-1, published traffic) ----
    cfg = paper_nets.build("net-1", lhr=(2, 2, 2))
    counts = paper_nets.paper_counts("net-1", cfg)
    for cand in dse.sweep_memory_blocks(cfg, counts):
        emit(f"ext/mem_blocks/net-1/{'x'.join(map(str, cand.blocks))}", 0.0,
             f"cycles={cand.cycles:.0f} lut={cand.lut/1e3:.1f}K "
             f"bram={cand.bram}")

    # ---- weight-precision sweep: BRAM + fixed-point accuracy ----
    data = synthetic.make_images(seed=9, n_train=512, n_test=128, noise=0.4)
    net_cfg = snn.SNNConfig(
        name="wq", input_shape=(28, 28),
        layers=(snn.Dense(64), snn.Dense(10 * 4)),
        num_classes=10, pcr=4, num_steps=10)
    res = train_snn.train(net_cfg, data, steps=60 if quick else 120,
                          batch_size=64)
    hw = hw_arch.from_snn_config(net_cfg)
    brams = dse.sweep_weight_bits(hw)
    weights = [np.asarray(p["w"]) for p in res.params]
    biases = [np.asarray(p["b"]) for p in res.params]
    x = jnp.asarray(data.x_test[:96])
    y = data.y_test[:96]
    spikes_in = np.asarray(encoding.rate_encode(jax.random.key(0), x, 10)
                           ).reshape(10, len(y), -1).astype(np.int64)
    for bits in (4, 6, 8, 12):
        acc = validate.quantized_accuracy(
            weights, biases, spikes_in, y, num_classes=10,
            frac_bits=bits - 1, beta=0.95, threshold=1.0)
        emit(f"ext/weight_bits/{bits}", 0.0,
             f"acc={acc:.3f} (float={res.test_accuracy:.3f}) "
             f"bram={brams.get(bits, '-')}")

    # ---- encoding-scheme ablation at fixed T ----
    T = 10
    for name, make in (
            ("rate", lambda xx: encoding.rate_encode(jax.random.key(1), xx, T)),
            ("ttfs", lambda xx: encoding.ttfs_encode(xx, T)),
            ("burst", lambda xx: encoding.burst_encode(jax.random.key(1),
                                                       xx, T))):
        spikes = make(x)
        out = snn.apply(net_cfg, res.params, spikes)
        pred = np.asarray(encoding.population_decode(out, 10))
        acc = float((pred == y).mean())
        density = float(spikes.mean())
        emit(f"ext/encoding/{name}", 0.0,
             f"acc={acc:.3f} spike_density={density:.3f} "
             f"(model trained with rate)")


if __name__ == "__main__":
    run()
