"""Budgeted joint-strategy exploration benchmark: the ask/tell ``explore``
driver searching the full (num_steps x population x per-layer LHR x
weight_bits) digit space with ``EvolutionarySearch`` under a training
budget in cache misses.

This is the NAS-style loop the exhaustive ``coexplore`` cell grid cannot
express: the strategy decides which model cells are worth training, the
budget caps how many actually train, and candidates in unaffordable cells
bounce back to the strategy as ``+inf``.  JSON lines report the frontier,
candidate throughput, the cache hit/miss counters, and the budget audit —
plus a checkpoint/resume round-trip check (a resumed study must finish
with the identical frontier and zero retraining).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json
from repro import optim
from repro.core import dse, snn, train_snn, workloads
from repro.core.accelerator import arch


def _workload(quick: bool) -> workloads.Workload:
    base = workloads.get("mnist-mlp")
    return dataclasses.replace(
        base, name="bench-explore-mlp",
        layers=(snn.Dense(24 if quick else 48),),
        pcr=2, n_train=256 if quick else 768, n_test=128,
        train_steps=20 if quick else 80, trace_samples=32)


def run(quick: bool = False):
    wl = _workload(quick)
    t_values = (2, 4) if quick else (2, 4, 8)
    pops = (0.5, 1.0) if quick else (0.5, 1.0, 2.0)
    n_cells = len(t_values) * len(pops)
    budget = max(1, n_cells // 2)             # train at most half the grid
    tmpl = arch.from_snn_config(wl.build(t_values[0], 1.0))
    space = (dse.SearchSpace(tmpl)
             .add_model("num_steps", t_values)
             .add_model("population", pops)
             .add_per_layer("lhr", [[1, 2, 4, 8] for _ in tmpl.layers])
             .add_global("weight_bits", (4, 8)))
    make = lambda: dse.EvolutionarySearch(
        population=16 if quick else 32,
        generations=4 if quick else 8, seed=0)

    # Explicit warmup: compile one cell's jitted train step at the grid's
    # first shape and report its wall-clock separately — the study timing
    # below then measures training throughput, not (only) jit compile.
    # Each in-process cell still pays its own compile for *other* (T, pop)
    # shapes; that recurring cost is exactly what `compile_seconds` makes
    # visible (and what stacked training amortizes — see bench_cellstack).
    cfg0 = wl.build(t_values[0], pops[0])
    data0 = wl.make_data(t_values[0])
    tx = optim.adam(wl.lr)
    params0, opt0, key0 = train_snn.init_cell(cfg0, tx, 0)
    step0 = jax.jit(train_snn.make_train_step(cfg0, tx, wl.matmul_backend))
    xb = jnp.asarray(data0.x_train[:wl.batch_size])
    yb = jnp.asarray(data0.y_train[:wl.batch_size])
    t0 = time.perf_counter()
    jax.block_until_ready(step0(params0, opt0, key0, xb, yb))
    compile_seconds = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as root:
        cache = workloads.TraceCache(root=f"{root}/cells")
        t0 = time.perf_counter()
        study = dse.explore(space, workload=wl, cache=cache,
                            strategy=make(), train_budget=budget,
                            checkpoint_dir=f"{root}/study")
        dt = time.perf_counter() - t0
        s = study.summary
        emit_json("explore/joint_budgeted",
                  cells_in_grid=n_cells, train_budget=budget,
                  cells_resolved=s["cells_resolved"],
                  cells_skipped=s["cells_skipped"],
                  cache=s["cache"],
                  budget_spent=s["train_budget"]["spent"],
                  budget_remaining=s["train_budget"]["remaining"],
                  candidates=study.n_evaluated,
                  frontier=len(study.frontier),
                  seconds=round(dt, 2),
                  compile_seconds=round(compile_seconds, 3),
                  cands_per_sec=round(study.n_evaluated / max(dt, 1e-9)),
                  cells_per_second=round(
                      s["cells_resolved"] / max(dt, 1e-9), 3))
        if cache.misses > budget:
            raise AssertionError(
                f"budget violated: {cache.misses} misses > {budget}")

        # resume audit: re-opening the finished study retrains nothing and
        # keeps the exact frontier
        cache2 = workloads.TraceCache(root=f"{root}/cells")
        t0 = time.perf_counter()
        resumed = dse.explore(space, workload=wl, cache=cache2,
                              strategy=make(), train_budget=budget,
                              checkpoint_dir=f"{root}/study", resume=True)
        dt2 = time.perf_counter() - t0

        def rows(t):
            cols = [np.asarray(t.columns[k], np.float64).reshape(len(t), -1)
                    for k in sorted(t.columns)]
            a = np.concatenate(cols, axis=1)
            return a[np.lexsort(a.T)]

        same = bool(np.array_equal(rows(resumed.frontier),
                                   rows(study.frontier)))
        emit_json("explore/resume", retrained=cache2.misses,
                  frontier_matches=same, seconds=round(dt2, 2))
        if cache2.misses:
            raise AssertionError("resume retrained a cell")
        if not same:
            raise AssertionError("resumed frontier size diverged")


if __name__ == "__main__":
    run()
