"""Benchmark harness entry point: one module per paper table/figure plus
kernel-level and DSE-throughput benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]

Each line of output is ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-sized)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: table1,fig1,fig6,fig7,"
                         "kernels,ext,dse,coexplore,explore,cellstack,"
                         "service,fleet")
    args = ap.parse_args()

    from benchmarks import (bench_cellstack, bench_coexplore, bench_dse,
                            bench_explore, bench_extensions, bench_fig1,
                            bench_fig6, bench_fig7, bench_fleet,
                            bench_kernels, bench_service, bench_table1)
    suites = {
        "table1": bench_table1.run,
        "fig1": bench_fig1.run,
        "fig6": bench_fig6.run,
        "fig7": bench_fig7.run,
        "kernels": bench_kernels.run,
        "ext": bench_extensions.run,
        "dse": bench_dse.run,
        "coexplore": bench_coexplore.run,
        "explore": bench_explore.run,
        "cellstack": bench_cellstack.run,
        "service": bench_service.run,
        "fleet": bench_fleet.run,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] or \
        list(suites)
    failures = 0
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            suites[name](quick=args.quick)
            print(f"{name}/TOTAL,{(time.time()-t0)*1e6:.0f},ok")
        except Exception:                                    # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}/TOTAL,0,FAILED")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
