"""Multi-tenant DSE service benchmark: two tenants submit overlapping
co-exploration studies to one ``DSEService`` over a shared trace cache.

What the BENCH lines measure (all tracked by ``tools/bench_diff.py``):

* ``studies_per_second`` — end-to-end study throughput of the cooperative
  scheduler (admission -> interleaved ``Study.step()`` rounds ->
  completion), training included.
* ``events_per_second`` — typed-protocol event emission rate (frontier
  updates + progress + lifecycle), the streaming-side cost.
* ``cache_hit_rate`` — the cross-tenant deduplication measure: tenant B's
  cells overlap tenant A's, so with one shared content-addressed cache
  every overlapping cell trains exactly once and B resolves hits.  A drop
  means tenants started retraining each other's cells.

The run also *asserts* the dedup contract (misses == distinct cells) and
that both tenants' frontiers are identical — the overlap is total, so
tenant B's study is a pure cache-replay of tenant A's.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

from benchmarks.common import emit_json
from repro.core import snn, workloads
from repro.serve import DSEService, FrontierUpdate, Submission


def _workload(quick: bool) -> workloads.Workload:
    base = workloads.get("mnist-mlp")
    return dataclasses.replace(
        base, name="bench-service-mlp",
        layers=(snn.Dense(24 if quick else 48),),
        pcr=2, n_train=256 if quick else 768, n_test=128,
        train_steps=20 if quick else 80, trace_samples=32)


def run(quick: bool = False):
    wl = _workload(quick)
    t_values = (2, 3) if quick else (2, 4, 8)
    pops = (0.5, 1.0) if quick else (0.5, 1.0, 2.0)
    n_cells = len(t_values) * len(pops)
    kwargs = dict(workload=wl, num_steps=t_values, population=pops,
                  max_lhr=4 if quick else 8, weight_bits=(4, 8),
                  chunk_size=4096)

    with tempfile.TemporaryDirectory() as root:
        cache = workloads.TraceCache(root=f"{root}/cells")
        service = DSEService(cache, max_active=2)
        t0 = time.perf_counter()
        handles = [service.submit(Submission(tenant=t, name="sweep",
                                             **kwargs))
                   for t in ("tenant-a", "tenant-b")]
        service.run_until_idle()
        dt = time.perf_counter() - t0

        stats = service.stats
        events = {h.study_id: h.events() for h in handles}
        n_events = sum(len(v) for v in events.values())
        n_frontier = sum(1 for v in events.values() for e in v
                        if isinstance(e, FrontierUpdate))
        emit_json("service/two_tenant",
                  tenants=2, cells_per_tenant=n_cells,
                  completed=stats["completed"],
                  cache_hits=stats["cache"]["hits"],
                  cache_misses=stats["cache"]["misses"],
                  cache_hit_rate=round(stats["cache"]["hit_rate"], 4),
                  events=n_events, frontier_updates=n_frontier,
                  seconds=round(dt, 2),
                  studies_per_second=round(stats["completed"]
                                           / max(dt, 1e-9), 3),
                  events_per_second=round(n_events / max(dt, 1e-9), 1))

        if stats["completed"] != 2:
            raise AssertionError(f"expected 2 completed studies, got "
                                 f"{stats['completed']} ({stats})")
        if cache.misses != n_cells:
            raise AssertionError(
                f"cross-tenant dedup violated: {cache.misses} training "
                f"runs for {n_cells} distinct cells")
        fa, fb = (h.frontier for h in handles)
        if len(fa) != len(fb):
            raise AssertionError(
                f"identical submissions diverged: frontier sizes "
                f"{len(fa)} vs {len(fb)}")


if __name__ == "__main__":
    run()
