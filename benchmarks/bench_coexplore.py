"""Model-hardware co-exploration benchmark: the paper's Fig. 8-style
robustness study — spike-train length T vs neuron population size, with
accuracy as a first-class Pareto objective next to latency/area/energy.

One ``coexplore`` call sweeps (num_steps x population x per-layer LHR x
weight_bits); each model cell trains once through the content-addressed
trace cache, and a SECOND identical call must resolve every cell as a cache
hit (the acceptance check for "re-running a sweep never retrains").  JSON
lines report per-cell accuracy, the joint frontier's accuracy-latency
extremes, candidate throughput, and the cache hit/miss counters of both
runs.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import numpy as np

from benchmarks.common import emit_json
from repro.core import dse, snn, workloads


def _workload(quick: bool) -> workloads.Workload:
    base = workloads.get("mnist-mlp")
    return dataclasses.replace(
        base, name="bench-co-mlp",
        layers=(snn.Dense(32 if quick else 64),),
        pcr=2, n_train=384 if quick else 1024, n_test=128,
        train_steps=25 if quick else 120, trace_samples=32)


def run(quick: bool = False):
    wl = _workload(quick)
    t_values = (2, 4) if quick else (2, 4, 8, 15)
    pops = (0.5, 1.0) if quick else (0.5, 1.0, 2.0)
    bits = (4, 8)
    with tempfile.TemporaryDirectory() as root:
        cache = workloads.TraceCache(root=root)

        t0 = time.perf_counter()
        res = dse.coexplore(wl, num_steps=t_values, population=pops,
                            max_lhr=8, weight_bits=bits, cache=cache)
        dt = time.perf_counter() - t0
        first_stats = dict(res.cache_stats)

        for c in res.cells:
            emit_json("coexplore/cell", workload=c.workload,
                      num_steps=c.assignment["num_steps"],
                      population=c.assignment["population"],
                      accuracy=round(c.accuracy, 4),
                      quant_acc={str(b): round(a, 4)
                                 for b, a in sorted(c.quant_acc.items())},
                      cache_hit=c.cache_hit, hw_candidates=c.n_evaluated)

        fr = res.frontier
        cyc = np.asarray(fr.columns["cycles"])
        err = np.asarray(fr.columns["error"])
        best_acc = fr.row(int(np.argmin(err)))
        best_lat = fr.row(int(np.argmin(cyc)))
        emit_json("coexplore/frontier", size=len(fr),
                  candidates=res.n_evaluated,
                  cells=len(res.cells),
                  seconds=round(dt, 2),
                  hw_cands_per_sec=round(res.n_evaluated / dt),
                  best_accuracy={"acc": round(best_acc["accuracy"], 4),
                                 "T": best_acc["num_steps"],
                                 "pop": best_acc["population"],
                                 "cycles": round(best_acc["cycles"])},
                  lowest_latency={"acc": round(best_lat["accuracy"], 4),
                                  "T": best_lat["num_steps"],
                                  "pop": best_lat["population"],
                                  "cycles": round(best_lat["cycles"])})

        # Fig. 8-style claims: latency grows with T on the frontier; the
        # accuracy-optimal and latency-optimal corners differ (a genuine
        # accuracy-latency trade-off exists).
        ts = np.asarray(fr.columns["num_steps"])
        mean_cyc_by_t = {int(t): float(cyc[ts == t].mean())
                         for t in np.unique(ts)}
        ordered = sorted(mean_cyc_by_t)
        monotone = all(mean_cyc_by_t[a] < mean_cyc_by_t[b]
                       for a, b in zip(ordered, ordered[1:]))
        emit_json("coexplore/claim_latency_grows_with_T",
                  mean_cycles_by_T=mean_cyc_by_t, holds=monotone)
        emit_json("coexplore/claim_tradeoff_exists",
                  holds=bool(best_acc["cycles"] > best_lat["cycles"]
                             or best_acc["accuracy"] > best_lat["accuracy"]))

        # repeat run: every cell must come from the cache (no retraining)
        t0 = time.perf_counter()
        res2 = dse.coexplore(wl, num_steps=t_values, population=pops,
                             max_lhr=8, weight_bits=bits, cache=cache)
        dt2 = time.perf_counter() - t0
        all_hit = all(c.cache_hit for c in res2.cells)
        emit_json("coexplore/cache", first_run=first_stats,
                  repeat_all_hits=all_hit,
                  repeat_seconds=round(dt2, 2),
                  speedup=round(dt / max(dt2, 1e-9), 1))
        # auditable Study counters: hit/miss + budget surface on the summary
        emit_json("coexplore/summary", **res2.summary)
        if not all_hit:
            raise AssertionError("repeat coexplore retrained a cell: "
                                 f"{[c.cache_hit for c in res2.cells]}")


if __name__ == "__main__":
    run()
