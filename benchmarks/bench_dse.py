"""DSE sweep throughput: seed-style per-candidate object loop vs the chunked
vectorized engine, on the identical candidate set.

The seed engine vectorized latency and LUT but still built one ``Candidate``
object per design and called scalar ``resources.energy_mj`` (a full
``estimate`` + ``accumulate_ops``) per candidate in a Python loop — and the
grid materialized every candidate up front.  The refactored engine streams
chunks of a declarative ``SearchSpace`` through batched NumPy columns.  Each
JSON line reports candidates/sec and peak traced allocations; the summary
line reports the speedup (acceptance floor: >= 5x at 100k candidates).
"""
from __future__ import annotations

import resource as _resource
import time
import tracemalloc

import numpy as np

from benchmarks.common import emit_json
from repro.core import dse
from repro.core.accelerator import arch, cycle_model, resources


def _seed_style_sweep(cfg, counts, lhr: np.ndarray) -> list[dse.Candidate]:
    """The seed engine's sweep loop, verbatim: vectorized cycles/LUT, then a
    Python loop materializing a config + scalar energy per candidate."""
    cycles = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr)
    lut = resources.estimate_lut_vector(cfg, lhr)
    mask = dse.pareto_mask(cycles, lut)
    cands = []
    for i in range(len(lhr)):
        c = cfg.with_lhr(tuple(int(x) for x in lhr[i]))
        cands.append(dse.Candidate(
            lhr=tuple(int(x) for x in lhr[i]),
            cycles=float(cycles[i]), lut=float(lut[i]),
            energy_mj=resources.energy_mj(c, counts, float(cycles[i])),
            pareto=bool(mask[i])))
    return cands


def _chunked_sweep(cfg, counts, space, n: int, chunk_size: int):
    acc = dse.ParetoAccumulator(("cycles", "lut"))
    for start in range(0, n, chunk_size):
        idx = np.arange(start, min(start + chunk_size, n), dtype=np.int64)
        cols = space.decode(idx)
        metrics = dse.evaluate_columns(cfg, counts, cols)
        acc.update(dse.CandidateTable({**cols, **metrics}))
    return acc.frontier


def _measure(label: str, fn, n: int):
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    emit_json(f"dse/{label}", candidates=n, seconds=round(dt, 3),
              cands_per_sec=round(n / dt),
              peak_traced_mb=round(peak / 2**20, 1),
              rss_mb=round(_resource.getrusage(
                  _resource.RUSAGE_SELF).ru_maxrss / 1024, 1))
    return out, dt


def run(quick: bool = False):
    n_target = 20_000 if quick else 100_000
    # 6 fc layers of 256 logical neurons -> 9^6 = 531441 LHR vectors; both
    # paths evaluate the same first n_target candidates of the grid.
    cfg = arch.from_layer_sizes("bench", (512,) + (256,) * 6, num_steps=5)
    counts = [np.full(5, 40.0)] * 6
    space = dse.SearchSpace.product_lhr(cfg, max_lhr=256)
    n = min(n_target, space.size)
    lhr = space.decode(np.arange(n, dtype=np.int64))["lhr"]

    frontier, dt_new = _measure(
        "chunked_vectorized",
        lambda: _chunked_sweep(cfg, counts, space, n, chunk_size=32768), n)
    cands, dt_old = _measure(
        "seed_object_loop", lambda: _seed_style_sweep(cfg, counts, lhr), n)

    seed_frontier = sorted((c.cycles, c.lut) for c in cands if c.pareto)
    new_frontier = sorted(zip(frontier.columns["cycles"].tolist(),
                              frontier.columns["lut"].tolist()))
    emit_json("dse/summary", candidates=n,
              speedup=round(dt_old / dt_new, 1),
              frontier_match=seed_frontier == new_frontier,
              frontier_size=len(new_frontier))


if __name__ == "__main__":
    run()
