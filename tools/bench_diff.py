"""Diff two BENCH_*.json line files and flag performance regressions.

CI uploads one JSON-lines artifact per run (``benchmarks.run --quick``
output filtered to ``^{`` lines); this tool compares the current run
against the previous one and flags throughput drops / latency growth
beyond a threshold — the ROADMAP "benchmark trajectory" item.

    python tools/bench_diff.py OLD.json NEW.json [--threshold 0.15]
                               [--json] [--strict]

Direction is inferred from the field-name suffix: throughput-like fields
(``*_per_sec``, ``*speedup``) regress when they DROP, latency/footprint
fields (``*seconds``, ``*_mb``) regress when they GROW.  Other numeric
fields are reported informationally when they change but never flagged.
Lines are matched by ``name``; when a name repeats (e.g. one
``coexplore/cell`` line per model cell) the occurrences pair up in order,
and a count mismatch skips the name with a note.

Exit code is 0 unless ``--strict`` is passed and a regression was found
(benchmarks on shared CI runners are noisy — the default is report-only).
"""
from __future__ import annotations

import argparse
import json
import sys

#: field-name suffixes where LARGER is better (regression = drop) —
#: "skip_fraction" covers the kernels suite's ``skip_fraction`` and
#: ``bwd_skip_fraction`` (tiles the sparsity-aware fwd/bwd kernels skip);
#: ``skip_fraction_profiled`` ends in "_profiled" and stays informational.
#: "_per_second" covers the cell-throughput fields ("cells_per_second",
#: "farm_cells_per_second") — singular "second", so it never collides with
#: the LOWER_IS_BETTER "seconds" latency suffix checked first below.
#: "_hit_rate" covers the service suite's cross-tenant "cache_hit_rate"
#: (shared-cache dedup: a drop means tenants started retraining each
#: other's cells).
HIGHER_IS_BETTER = ("_per_sec", "_per_second", "speedup", "skip_fraction",
                    "_hit_rate")
#: field-name suffixes where SMALLER is better (regression = growth) —
#: covers "seconds" ("repeat_seconds", per-backend "*_fwd_seconds" /
#: "*_bwd_seconds" / "*_step_seconds"), "rss_mb", ...
LOWER_IS_BETTER = ("seconds", "_mb")


def load_lines(path: str) -> dict[str, list[dict]]:
    """JSON-lines file -> {name: [records in file order]}."""
    by_name: dict[str, list[dict]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or not line.startswith("{"):
                continue
            rec = json.loads(line)
            by_name.setdefault(rec.get("name", "?"), []).append(rec)
    return by_name


def _direction(field: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 informational."""
    if any(field.endswith(s) for s in LOWER_IS_BETTER):
        return -1
    if any(field.endswith(s) for s in HIGHER_IS_BETTER):
        return 1
    return 0


def diff_records(old: dict, new: dict, threshold: float,
                 name: str, index: int) -> list[dict]:
    out = []
    for field in sorted(set(old) & set(new)):
        a, b = old[field], new[field]
        if field == "name" or not all(
                isinstance(x, (int, float)) and not isinstance(x, bool)
                for x in (a, b)):
            continue
        if a == b:
            continue
        rel = (b - a) / abs(a) if a else float("inf")
        d = _direction(field)
        regressed = (d == 1 and rel < -threshold) or \
                    (d == -1 and rel > threshold)
        out.append({"name": name, "index": index, "field": field,
                    "old": a, "new": b, "rel_change": round(rel, 4),
                    "direction": {1: "higher_better", -1: "lower_better",
                                  0: "info"}[d],
                    "regressed": regressed})
    return out


def diff_files(old_path: str, new_path: str,
               threshold: float) -> tuple[list[dict], list[str]]:
    """Returns (changes, notes).  ``changes`` rows carry ``regressed``."""
    old_by, new_by = load_lines(old_path), load_lines(new_path)
    changes: list[dict] = []
    notes: list[str] = []
    for name in sorted(set(old_by) | set(new_by)):
        olds, news = old_by.get(name, []), new_by.get(name, [])
        if not olds:
            notes.append(f"new benchmark line: {name}")
            continue
        if not news:
            notes.append(f"benchmark line disappeared: {name}")
            continue
        if len(olds) != len(news):
            notes.append(f"skipping {name}: {len(olds)} vs {len(news)} "
                         f"occurrences")
            continue
        for i, (o, n) in enumerate(zip(olds, news)):
            changes.append(diff_records(o, n, threshold, name, i))
    return [c for group in changes for c in group], notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json line files")
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative change that counts as a regression "
                         "(default 0.15 = 15%%)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (one summary object)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when a regression is flagged")
    args = ap.parse_args(argv)

    changes, notes = diff_files(args.old, args.new, args.threshold)
    regressions = [c for c in changes if c["regressed"]]

    if args.json:
        print(json.dumps({"threshold": args.threshold,
                          "n_changes": len(changes),
                          "n_regressions": len(regressions),
                          "regressions": regressions,
                          "changes": changes, "notes": notes},
                         sort_keys=True))
    else:
        for note in notes:
            print(f"  note: {note}")
        perf = [c for c in changes if c["direction"] != "info"]
        if not perf:
            print("no tracked perf fields changed")
        for c in perf:
            idx = f"[{c['index']}]" if c["index"] else ""
            mark = "REGRESSION" if c["regressed"] else "ok"
            print(f"  {mark:>10}  {c['name']}{idx} {c['field']}: "
                  f"{c['old']} -> {c['new']} ({c['rel_change']:+.1%})")
        print(f"{len(regressions)} regression(s) beyond "
              f"{args.threshold:.0%} across {len(perf)} tracked change(s)")

    return 1 if (args.strict and regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
