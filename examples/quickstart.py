"""Quickstart: the paper's whole loop in two minutes on CPU.

1. train a small SNN (surrogate-gradient BPTT, rate coding, population
   output) on the synthetic MNIST stand-in;
2. measure layer-wise firing sparsity (paper Fig. 1);
3. run the cycle-accurate DSE over per-layer LHR (paper Table I / Fig. 6);
4. pick the smallest design inside a latency budget.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import dse, encoding, snn, sparsity, train_snn
from repro.core.accelerator import arch as hw
from repro.core.accelerator import resources
from repro.data import synthetic

# 1. train -----------------------------------------------------------------
data = synthetic.make_images(n_train=1024, n_test=256)
cfg = snn.SNNConfig(
    name="quickstart", input_shape=(28, 28),
    layers=(snn.Dense(128), snn.Dense(128), snn.Dense(10 * 10)),
    num_classes=10, pcr=10, num_steps=15)
result = train_snn.train(cfg, data, steps=150, batch_size=64, verbose=True,
                         log_every=50)
print(f"\ntest accuracy: {result.test_accuracy:.3f}")

# 2. sparsity --------------------------------------------------------------
x = jnp.asarray(data.x_test[:64])
spikes_in = encoding.rate_encode(jax.random.key(7), x, cfg.num_steps)
stats = sparsity.analyze(cfg, result.params, spikes_in)
print("\nlayer-wise firing (paper Fig. 1):")
print(sparsity.firing_table(stats))

# 3. DSE -------------------------------------------------------------------
traces = train_snn.dump_traces(cfg, result.params, data.x_test)
counts = [c.mean(axis=1) for c in traces["layer_input_spike_counts"]]
accel = hw.from_snn_config(cfg)
sweep = dse.sweep(accel, counts, max_lhr=64)
print(f"\nDSE: {len(sweep.candidates)} candidates, "
      f"{len(sweep.frontier)} on the Pareto frontier")
for c in sorted(sweep.frontier, key=lambda c: c.cycles)[:8]:
    print(f"  lhr={str(c.lhr):>14} cycles={c.cycles:>9.0f} "
          f"lut={c.lut/1e3:>7.1f}K energy={c.energy_mj:.3f} mJ")

# 4. pick ------------------------------------------------------------------
budget = 2.0 * sorted(sweep.frontier, key=lambda c: c.cycles)[0].cycles
best = sweep.best_within_latency(budget)
base = resources.estimate(accel)
print(f"\nsmallest design within 2x fastest latency: lhr={best.lhr} "
      f"-> {best.lut/1e3:.1f}K LUT "
      f"({1 - best.lut/base.lut:.0%} smaller than all-parallel), "
      f"{best.cycles:.0f} cycles/image")
