"""Elastic cell-fleet walkthrough: spawned workers drain a cluster study
through the shared cache root, and one of them dies mid-run.

    PYTHONPATH=src python examples/fleet_workers.py

Two ``fleet.run_worker`` processes enroll against a shared trace-cache
root — the only coordination substrate there is: pending cells spool to
``<root>/queue/`` as wire-format jobs, each worker claims one by
atomically creating ``<root>/<key>/.lease`` (its mtime is the worker's
heartbeat) and publishes through the content-addressed ``TraceCache``.
The submitting study just calls ``dse.explore(workers="cluster")``: it
blocks on lease/publish progress and would reclaim any cell whose
heartbeat went stale (a SIGKILL'd worker, simulated below), training it
in-process — so the study completes no matter how much of the fleet
survives.  On a real cluster the root lives on a network mount and the
workers on other hosts; nothing in the protocol changes.
"""
import dataclasses
import multiprocessing
import os
import signal
import tempfile
import time

from repro.core import dse, snn, workloads
from repro.distributed import fleet


def tiny(name):
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name=name,
        layers=(snn.Dense(16),), pcr=1,
        n_train=128, n_test=64, train_steps=6, trace_samples=16)


def main():
    wl = tiny("fleet-example-mlp")
    with tempfile.TemporaryDirectory() as root:
        ctx = multiprocessing.get_context("spawn")   # JAX is not fork-safe
        workers = [ctx.Process(
            target=fleet.run_worker,
            kwargs=dict(root=root, worker_id=f"host-{i}", idle_timeout=20,
                        stats_path=os.path.join(root, f"stats-{i}.json")))
            for i in range(2)]
        for w in workers:
            w.start()

        # kill one worker a few seconds in: its lease goes stale and the
        # cell it was holding is reclaimed by a peer or the submitter
        def assassin():
            time.sleep(8)
            if workers[0].is_alive():
                os.kill(workers[0].pid, signal.SIGKILL)
                print("** worker host-0 SIGKILL'd mid-study **")

        import threading
        threading.Thread(target=assassin, daemon=True).start()

        cache = workloads.TraceCache(root=root)
        study = dse.explore(
            workload=wl, num_steps=(2, 3), population=(0.5, 1.0),
            max_lhr=4, weight_bits=(4, 8), cache=cache, workers="cluster")

        for w in workers:
            w.join(timeout=60)
        print(f"study complete: {study.summary['cells_resolved']} cells "
              f"resolved, frontier size {len(study.frontier)}")
        print(f"every cell loaded from the shared root: {cache.stats}")


if __name__ == "__main__":
    main()
