"""End-to-end paper pipeline (Sec. IV, all five phases) on the synthetic
datasets: Training -> Configuration -> Architecture Generation ->
Simulation & VALIDATION (exact spike-to-spike, fixed-point) -> Evaluation.

    PYTHONPATH=src python examples/train_snn_dse.py [--dataset dvs]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dse, encoding, snn, train_snn, validate, workloads
from repro.core.accelerator import arch as hw
from repro.core.accelerator import cycle_model, resources
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "dvs"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--coexplore", action="store_true",
                    help="also run the joint model x hardware co-exploration "
                         "walkthrough (trains several small model cells)")
    args = ap.parse_args()

    # ---- Training Phase ----
    if args.dataset == "mnist":
        data = synthetic.make_images(n_train=1024, n_test=256)
        cfg = snn.SNNConfig(
            name="net", input_shape=(28, 28),
            layers=(snn.Dense(128), snn.Dense(128), snn.Dense(10 * 10)),
            num_classes=10, pcr=10, num_steps=15)
    else:
        data = synthetic.make_events(n_train=256, n_test=64, t=12)
        cfg = snn.SNNConfig(
            name="net", input_shape=(32, 32, 2),
            layers=(snn.Conv(8, 3), snn.MaxPool(2), snn.Conv(8, 3),
                    snn.MaxPool(2), snn.Dense(64), snn.Dense(8 * 4)),
            num_classes=8, pcr=4, num_steps=12)
    res = train_snn.train(cfg, data, steps=args.steps, batch_size=64,
                          verbose=True, log_every=50)
    print(f"accuracy: {res.test_accuracy:.3f}")

    # ---- Configuration Phase: dump spikes + weights ----
    counts = train_snn.trace_counts(cfg, res.params, data.x_test)

    # ---- Architecture Generation ----
    accel = hw.from_snn_config(cfg)

    # ---- Simulation & Validation: exact spike-to-spike (MLP datapath) ----
    if args.dataset == "mnist":
        weights = [p["w"] for p in res.params]
        biases = [p["b"] for p in res.params]
        fp = validate.quantize([np.asarray(w) for w in weights],
                               [np.asarray(b) for b in biases],
                               beta=0.95, threshold=1.0)
        x = np.asarray(data.x_test[0]).reshape(-1)
        spikes = np.asarray(encoding.rate_encode(
            jax.random.key(0), jnp.asarray(x)[None], cfg.num_steps))[:, 0]
        ok = validate.validate(fp, spikes.astype(np.int64),
                               lhr=[4, 8, 8][:len(weights)])
        print(f"spike-to-spike validation (fixed-point, serial HW model): "
              f"{'PASS' if ok else 'FAIL'}")
        assert ok

    # ---- Evaluation Phase: DSE ----
    sweep = dse.sweep(accel, counts, max_lhr=64)
    base = resources.estimate(accel)
    base_cycles = float(cycle_model.latency_cycles(accel, counts))
    print(f"\nall-parallel baseline: {base.lut/1e3:.1f}K LUT, "
          f"{base_cycles:.0f} cycles")
    print(f"{'lhr':>16} {'cycles':>10} {'LUT':>9} {'energy':>9}")
    for c in sorted(sweep.frontier, key=lambda c: c.cycles)[:10]:
        print(f"{str(c.lhr):>16} {c.cycles:>10.0f} {c.lut/1e3:>8.1f}K "
              f"{c.energy_mj:>8.3f}mJ")
    best = sweep.min_energy()
    print(f"\nmin-energy config: lhr={best.lhr} "
          f"({1-best.lut/base.lut:.0%} fewer LUTs, "
          f"{best.cycles/base_cycles:.1f}x latency)")

    # ---- Joint multi-axis DSE (the unified ask/tell front end) ----
    # How to define a search space (see DESIGN.md §8/§10 and the
    # repro.core.dse package docstring):
    #   * add_per_layer — independent options per layer (Cartesian product);
    #   * add_joint     — options are whole per-layer vectors (all layers
    #                     move together);
    #   * add_global    — one value applied to every layer.
    # ``dse.search`` is an exact thin wrapper over ``dse.explore``: the
    # ask/tell driver streams digit chunks through the vectorized cycle
    # model + component library and retains only the k-objective Pareto
    # frontier (call ``dse.explore`` directly for budgets, checkpoints, or
    # workers — see the co-exploration section below).
    space = (dse.SearchSpace(accel)
             .add_per_layer("lhr", [dse.pow2_values(min(32, l.logical))
                                    for l in accel.layers])
             .add_joint("mem_blocks",
                        [tuple(max(1, l.num_nus // d) for l in accel.layers)
                         for d in (1, 2, 4)])
             .add_global("weight_bits", (4, 6, 8)))
    result = dse.search(accel, counts, space,
                        objectives=("cycles", "lut", "bram", "energy"))
    print(f"\njoint DSE over LHR x mem_blocks x weight_bits: "
          f"{result.n_evaluated} candidates, "
          f"{len(result.frontier)} on the 4-objective frontier")
    fr = result.frontier.sorted_by("cycles")
    print(f"{'lhr':>16} {'mem':>14} {'bits':>4} {'cycles':>10} "
          f"{'LUT':>8} {'BRAM':>5} {'energy':>9}")
    for i in range(min(8, len(fr))):
        r = fr.row(i)
        print(f"{str(r['lhr']):>16} {str(r['mem_blocks']):>14} "
              f"{r['weight_bits']:>4} {r['cycles']:>10.0f} "
              f"{r['lut']/1e3:>7.1f}K {r['bram']:>5} "
              f"{r['energy']:>8.3f}mJ")
    # budget pick + materialized hardware config for the winner
    row = result.best_within_latency(2.0 * base_cycles)
    if row is not None:
        hw_cfg = result.config_for(row)
        print(f"\nsmallest joint design within 2x baseline latency: "
              f"lhr={row['lhr']} mem={row['mem_blocks']} "
              f"bits={row['weight_bits']} -> {row['lut']/1e3:.1f}K LUT, "
              f"{row['bram']} BRAM ({hw_cfg.layers[0].weight_bits}-bit "
              f"weights)")
        # accuracy leg of the weight_bits axis (fixed-point datapath)
        if args.dataset == "mnist":
            spikes_b = np.asarray(encoding.rate_encode(
                jax.random.key(1), jnp.asarray(data.x_test[:64]).reshape(64, -1),
                cfg.num_steps)).astype(np.int64)
            acc_q = validate.quantized_accuracy(
                [np.asarray(w) for w in weights],
                [np.asarray(b) for b in biases],
                spikes_b, data.y_test[:64], num_classes=10,
                frac_bits=int(row["weight_bits"]) - 1)
            print(f"fixed-point accuracy at {row['weight_bits']} bits: "
                  f"{acc_q:.3f} (float: {res.test_accuracy:.3f})")

    # ---- Model x hardware co-exploration (the paper's headline loop) ----
    # Model parameters (spike-train length T, neuron population scale)
    # become searchable axes: each model cell trains once through the
    # content-addressed trace cache, then its hardware subspace streams
    # through the same chunked evaluator, with accuracy (as ``error`` =
    # 1 - accuracy) a first-class Pareto objective.  See DESIGN.md §9-§10.
    if args.coexplore:
        wl = dataclasses.replace(
            workloads.get("mnist-mlp"), name="example-co",
            layers=(snn.Dense(48),), pcr=2,
            n_train=512, n_test=128, train_steps=60)
        with tempfile.TemporaryDirectory() as root:
            co = dse.coexplore(wl, num_steps=(4, 8), population=(0.5, 1.0),
                               max_lhr=8, weight_bits=(4, 8),
                               cache=workloads.TraceCache(root=root))
            print(f"\nco-exploration: {len(co.cells)} model cells "
                  f"({co.cache_stats['misses']} trained), "
                  f"{co.n_evaluated} hardware candidates, "
                  f"{len(co.frontier)} on the accuracy-aware frontier")
            print(f"{'T':>3} {'pop':>5} {'lhr':>10} {'bits':>4} "
                  f"{'acc':>6} {'cycles':>8} {'LUT':>8}")
            fr = co.frontier.sorted_by("cycles")
            for i in range(min(8, len(fr))):
                r = fr.row(i)
                print(f"{r['num_steps']:>3} {r['population']:>5.2g} "
                      f"{str(r['lhr']):>10} {r['weight_bits']:>4} "
                      f"{r['accuracy']:>6.3f} {r['cycles']:>8.0f} "
                      f"{r['lut']/1e3:>7.1f}K")

            # Budgeted NAS-style loop (DESIGN.md §10): an evolutionary
            # strategy over the FULL joint digit space decides which cells
            # are worth training — at most train_budget cache misses (the
            # cells above are already cached, so this costs nothing here).
            tmpl = hw.from_snn_config(wl.build(4, 1.0))
            jspace = (dse.SearchSpace(tmpl)
                      .add_model("num_steps", (4, 8))
                      .add_model("population", (0.5, 1.0))
                      .add_per_layer("lhr", [dse.pow2_values(8)
                                             for _ in tmpl.layers])
                      .add_global("weight_bits", (4, 8)))
            budgeted = dse.explore(
                jspace, workload=wl, train_budget=4,
                cache=workloads.TraceCache(root=root),
                strategy=dse.EvolutionarySearch(population=16,
                                                generations=4, seed=0))
            print(f"\nbudgeted explore: {budgeted.summary}")


if __name__ == "__main__":
    main()
