"""End-to-end LM training driver: a ~100M-parameter llama-family model
trained for a few hundred steps on the deterministic synthetic corpus,
with checkpoint/restart supervision.  Loss must drop substantially.

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]

On this CPU container a step of the 100M config at batch 2 x 256 tokens
takes a few seconds; pass --tiny for a seconds-long smoke run.
"""
import argparse
import dataclasses
import math

import jax
import numpy as np

from repro.launch.train import run_training, small_config
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--checkpoint-dir", default="artifacts/lm100m_ckpt")
    args = ap.parse_args()

    base = registry.load_arch("llama3_2_3b")
    if args.tiny:
        cfg = small_config(base, d_model=128, layers=2, vocab=512)
        batch, seq = 8, 64
    else:
        # ~100M: 14L x d640 (d_ff 2560) + 16k vocab
        cfg = small_config(base, d_model=640, layers=14, vocab=16384)
        batch, seq = 2, 256
    n_params = sum(math.prod(l.shape) for l in jax.tree.leaves(
        jax.eval_shape(lambda: registry.init_params(jax.random.key(0), cfg))))
    print(f"model: {cfg.name} scaled to {n_params/1e6:.1f}M params")

    # data vocab 512 << model vocab: a few hundred steps of synthetic chain
    # are enough to show a decisive loss drop
    out = run_training(cfg, steps_n=args.steps, global_batch=batch,
                       seq_len=seq, lr=1e-3, data_vocab=512,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=100, log_every=10)
    losses = out["losses"]
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "loss did not drop"
    print("OK")


if __name__ == "__main__":
    main()
