"""DSE-as-a-service walkthrough: two tenants, overlapping search spaces,
one warm cache, streamed Pareto frontiers.

    PYTHONPATH=src python examples/serve_dse.py

Tenant *alpha* and tenant *beta* each submit a joint model x hardware
co-exploration study.  Their model-cell grids overlap on (T=2,3) x
(pop=0.5): the service resolves every cell through one shared
content-addressed ``TraceCache``, so whichever tenant reaches an
overlapping cell first trains it and the other gets a cache hit — the
cross-tenant deduplication the ROADMAP's "millions of users, one warm
cache, zero redundant training" story is built on.  Both studies step
concurrently (round-robin) on the service scheduler, and each tenant
watches its own typed event stream: monotone ``FrontierUpdate`` snapshots
plus ``Progress`` cache/budget counters.
"""
import dataclasses
import tempfile

from repro.core import snn, workloads
from repro.serve import (DSEService, FrontierUpdate, Progress,
                         StudyCompleted, Submission)


def tiny(name):
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name=name,
        layers=(snn.Dense(16),), pcr=1,
        n_train=128, n_test=64, train_steps=6, trace_samples=16)


def main():
    wl = tiny("serve-dse-mlp")
    with tempfile.TemporaryDirectory() as root:
        cache = workloads.TraceCache(root=f"{root}/cells")
        service = DSEService(cache, checkpoint_root=f"{root}/studies",
                             max_active=2, tenant_quota=16)

        # overlapping grids: both tenants want T in (2,3) at pop 0.5;
        # alpha also sweeps pop 1.0, beta also sweeps T=4
        alpha = service.submit(Submission(
            tenant="alpha", name="sweep", workload=wl,
            num_steps=(2, 3), population=(0.5, 1.0),
            max_lhr=4, weight_bits=(4, 8)))
        beta = service.submit(Submission(
            tenant="beta", name="sweep", workload=wl,
            num_steps=(2, 3, 4), population=(0.5,),
            max_lhr=4, weight_bits=(4, 8)))

        service.run_until_idle()

        for handle in (alpha, beta):
            print(f"\n=== {handle.study_id} ===")
            for event in handle.events():
                if isinstance(event, FrontierUpdate):
                    print(f"  round {event.round}: frontier -> "
                          f"{event.frontier_size} points over "
                          f"{event.objectives}")
                elif isinstance(event, Progress):
                    c = event.cache
                    print(f"  round {event.round}: cells "
                          f"{event.cells_resolved} resolved, cache "
                          f"{c.get('hits', 0)} hits / "
                          f"{c.get('misses', 0)} misses, budget "
                          f"{event.budget}")
                elif isinstance(event, StudyCompleted):
                    print(f"  completed: {event.summary['n_evaluated']} "
                          f"candidates, frontier "
                          f"{event.summary['frontier_size']}")
                else:
                    print(f"  {type(event).__name__}")

        stats = service.stats
        print(f"\nservice: {stats['completed']} studies, "
              f"{stats['events_emitted']} events, cache hit rate "
              f"{stats['cache']['hit_rate']:.2f} "
              f"({stats['cache']['hits']} hits / "
              f"{stats['cache']['misses']} misses)")
        # 5 distinct cells across both grids, 7 resolutions: the two
        # overlapping cells trained once and hit once
        assert stats["cache"]["misses"] == 5


if __name__ == "__main__":
    main()
