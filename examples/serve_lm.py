"""Batched LM serving demo: prefill + KV-cache decode with the serving
engine (continuous-batching bookkeeping, greedy sampling).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.launch.train import small_config
from repro.models import registry
from repro.serve import engine

base = registry.load_arch("tinyllama_1_1b")
cfg = small_config(base, d_model=128, layers=2, vocab=512)
params = registry.init_params(jax.random.key(0), cfg)

loop = engine.ServeLoop(cfg, params, batch_size=4, max_len=64)
rng = np.random.default_rng(0)
requests = [
    engine.Request(uid=i, prompt=rng.integers(1, 512, size=n).astype(np.int32),
                   max_new_tokens=8 + 4 * i)
    for i, n in enumerate((5, 9, 3, 7))
]
done = loop.run(requests)
for r in done:
    print(f"request {r.uid}: prompt[{len(r.prompt)}] -> "
          f"{len(r.generated)} tokens: {r.generated}")
assert all(r.done for r in done)
print("serving loop complete")
