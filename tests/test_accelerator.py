"""Tests for the cycle-accurate accelerator model, resources, DSE, and the
Table-I reproduction fidelity."""
import dataclasses

import numpy as np
import pytest

from repro.core import dse
from repro.core.accelerator import (arch, cycle_model, paper_data, paper_nets,
                                    resources)


def _fc_cfg(lhr=(1, 1), sizes=(100, 50, 20), T=5):
    return arch.from_layer_sizes("t", sizes, lhr=lhr, num_steps=T)


class TestLayerLatency:
    def test_zero_spikes_floor(self):
        cfg = _fc_cfg()
        t = cfg.timing
        lat = cycle_model.layer_latency(cfg.layers[0], 0.0, t)
        # PENC still scans chunks; activation walks owned neurons; sync
        assert lat == cfg.layers[0].penc_chunks + t.act_cycles + t.sync_cycles

    def test_linear_in_spikes_and_lhr(self):
        cfg = _fc_cfg()
        t = cfg.timing
        l0 = cfg.layers[0]
        base = cycle_model.layer_latency(l0, 10, t)
        more = cycle_model.layer_latency(l0, 20, t)
        assert more - base == 10 * (1 + l0.lhr * t.acc_cycles_per_op)
        l0_hi = dataclasses.replace(l0, lhr=5)
        hi = cycle_model.layer_latency(l0_hi, 10, t)
        assert hi > base

    def test_memory_contention_serializes(self):
        l = arch.LayerHW(kind="fc", logical=64, fan_in_size=64, lhr=1,
                         mem_blocks=16)
        assert l.contention == 4
        t = arch.TimingModel()
        lat_shared = cycle_model.layer_latency(l, 10, t)
        l_priv = dataclasses.replace(l, mem_blocks=0)
        lat_priv = cycle_model.layer_latency(l_priv, 10, t)
        assert lat_shared > lat_priv

    def test_conv_event_driven_activation_caps(self):
        l = arch.LayerHW(kind="conv", logical=8, fan_in_size=1024, lhr=1,
                         kernel=3, out_positions=1024)
        t = arch.TimingModel(conv_event_driven_act=True)
        small = cycle_model.layer_latency(l, 5, t)
        # affected = 5*9 = 45 < 1024 positions
        t2 = arch.TimingModel(conv_event_driven_act=False)
        dense = cycle_model.layer_latency(l, 5, t2)
        assert dense > small


class TestPipeline:
    def test_single_layer_sums(self):
        lat = np.array([[3.0, 4.0, 5.0]])       # (L=1, T=3)
        assert cycle_model.pipeline_latency(lat) == 12.0

    def test_bottleneck_dominates(self):
        # slow middle layer: steady state = T * slow + fills
        L, T, slow = 3, 50, 100.0
        lat = np.full((L, T), 1.0)
        lat[1] = slow
        total = float(cycle_model.pipeline_latency(lat))
        assert total == 1.0 + T * slow + 1.0     # fill + steady + drain

    def test_lower_bound_max_layer(self):
        rng = np.random.default_rng(0)
        lat = rng.uniform(1, 10, size=(4, 20))
        total = float(cycle_model.pipeline_latency(lat))
        assert total >= lat.sum(axis=1).max()
        assert total <= lat.sum()                # never worse than serial

    @pytest.mark.parametrize("seed", range(4))
    def test_vectorized_matches_scalar(self, seed):
        """Property: the vmapped DSE path == per-config scalar evaluation."""
        rng = np.random.default_rng(seed)
        cfg = _fc_cfg(T=8)
        counts = [rng.integers(0, 40, size=8).astype(float) for _ in range(2)]
        lhr_mat = np.array([[1, 1], [2, 4], [4, 2], [10, 5]])
        vec = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr_mat)
        for i, lhr in enumerate(lhr_mat):
            scalar = cycle_model.latency_cycles(cfg.with_lhr(tuple(lhr)), counts)
            np.testing.assert_allclose(vec[i], scalar)


class TestPipelineInvariants:
    """Structural properties of the exact layer-pipeline recurrence."""

    @pytest.mark.parametrize("seed", range(3))
    def test_single_layer_reduces_to_running_sum(self, seed):
        rng = np.random.default_rng(seed)
        lat = rng.uniform(0, 20, size=(1, 17))
        assert float(cycle_model.pipeline_latency(lat)) == \
            pytest.approx(lat.sum())

    @pytest.mark.parametrize("pos", [0, 1, 2, 3])
    def test_zero_latency_layer_is_noop(self, pos):
        rng = np.random.default_rng(11)
        lat = rng.uniform(1, 10, size=(3, 12))
        with_zero = np.insert(lat, pos, 0.0, axis=0)
        np.testing.assert_allclose(cycle_model.pipeline_latency(with_zero),
                                   cycle_model.pipeline_latency(lat))

    @pytest.mark.parametrize("seed", range(3))
    def test_batched_equals_per_candidate_scalar(self, seed):
        """(L, T, C) batched evaluation == C independent (L, T) scalars."""
        rng = np.random.default_rng(seed)
        lat = rng.uniform(0, 15, size=(4, 9, 6))
        batched = cycle_model.pipeline_latency(lat)
        assert batched.shape == (6,)
        for c in range(lat.shape[2]):
            np.testing.assert_allclose(batched[c],
                                       cycle_model.pipeline_latency(
                                           lat[:, :, c]))

    def test_latency_seconds_forwards_batched_kwargs(self):
        """The wall-clock wrapper accepts the same candidate matrices as
        latency_cycles (it used to silently support only the scalar path)."""
        cfg = _fc_cfg(T=4)
        counts = [np.full(4, 12.0)] * 2
        lhr = np.array([[1, 1], [4, 2], [10, 5]])
        mem = np.array([[0, 0], [2, 2], [4, 1]])
        pw = np.array([50, 100, 100])
        sec = cycle_model.latency_seconds(cfg, counts, lhr_matrix=lhr,
                                          mem_blocks_matrix=mem,
                                          penc_width=pw)
        cyc = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr,
                                         mem_blocks_matrix=mem,
                                         penc_width=pw)
        assert sec.shape == (3,)
        np.testing.assert_allclose(sec, cyc / (cfg.timing.clock_mhz * 1e6))

    def test_latency_seconds_per_candidate_clock(self):
        """A sweep with a clock_mhz axis gets each candidate's seconds at
        its own clock, not the base config's."""
        cfg = _fc_cfg(T=4)
        counts = [np.full(4, 12.0)] * 2
        lhr = np.array([[1, 1], [4, 2]])
        clk = np.array([100.0, 200.0])
        sec = cycle_model.latency_seconds(cfg, counts, lhr_matrix=lhr,
                                          clock_mhz=clk)
        cyc = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr)
        np.testing.assert_allclose(sec, cyc / (clk * 1e6))


class TestCountsFromTraces:
    def test_mean_over_sample_axes_and_retention(self):
        rng = np.random.default_rng(0)
        raw = [rng.uniform(0, 30, size=(5, 8)), rng.uniform(0, 30, size=(5,))]
        out = cycle_model.counts_from_traces(raw, pool_before=[False, True],
                                             pool_retention=0.5)
        np.testing.assert_allclose(out[0], raw[0].mean(axis=1))
        np.testing.assert_allclose(out[1], raw[1] * 0.5)

    def test_counts_from_averages_matches_manual(self):
        cfg = _fc_cfg(T=6)
        cfg = dataclasses.replace(
            cfg, timing=dataclasses.replace(cfg.timing, pool_retention=0.7))
        got = cycle_model.counts_from_averages(cfg, [10.0, 20.0],
                                               pool_before=[False, True])
        np.testing.assert_allclose(got[0], np.full(6, 10.0))
        np.testing.assert_allclose(got[1], np.full(6, 20.0 * 0.7))


class TestResources:
    def test_monotone_in_lhr(self):
        lo = resources.estimate(_fc_cfg(lhr=(1, 1)))
        hi = resources.estimate(_fc_cfg(lhr=(10, 10)))
        assert hi.lut < lo.lut and hi.reg < lo.reg and hi.dsp < lo.dsp

    def test_bram_counts_weights(self):
        cfg = _fc_cfg()
        r = resources.estimate(cfg)
        bits = (100 * 50 + 50 * 20) * 8
        assert r.bram36 >= bits // (36 * 1024)

    def test_lut_vector_matches_scalar(self):
        cfg = _fc_cfg()
        lhr_mat = np.array([[1, 1], [4, 2], [25, 10]])
        vec = resources.estimate_lut_vector(cfg, lhr_mat)
        for i, lhr in enumerate(lhr_mat):
            np.testing.assert_allclose(
                vec[i], resources.estimate(cfg.with_lhr(tuple(lhr))).lut)

    def test_energy_positive_and_increasing_with_cycles(self):
        cfg = _fc_cfg()
        counts = [np.full(5, 10.0)] * 2
        e1 = resources.energy_mj(cfg, counts, 1000)
        e2 = resources.energy_mj(cfg, counts, 100000)
        assert 0 < e1 < e2


class TestTable1Fidelity:
    """The reproduction claim: our calibrated model reproduces the paper's
    own Table I within TLM-grade error."""

    def test_latency_median_error_under_15pct(self):
        errs = []
        for net in paper_data.NETS:
            cfg0 = paper_nets.build(net)
            counts = paper_nets.paper_counts(net, cfg0)
            for r in paper_data.tw_rows(net):
                pred = float(cycle_model.latency_cycles(cfg0.with_lhr(r.lhr),
                                                        counts))
                errs.append(abs(pred / r.cycles - 1))
        assert np.median(errs) < 0.15, f"median latency err {np.median(errs):.1%}"

    def test_lut_median_error_under_10pct(self):
        errs = []
        for net in paper_data.NETS:
            for r in paper_data.tw_rows(net):
                if r.lut is None:
                    continue
                est = resources.estimate(paper_nets.build(net, lhr=r.lhr))
                errs.append(abs(est.lut / (r.lut * 1e3) - 1))
        assert np.median(errs) < 0.10, f"median LUT err {np.median(errs):.1%}"

    def test_net1_lhr_488_saves_76pct_resources(self):
        """Headline claim (i): (4,8,8) cuts ~76% of LUTs vs (1,1,1)."""
        base = resources.estimate(paper_nets.build("net-1", lhr=(1, 1, 1)))
        opt = resources.estimate(paper_nets.build("net-1", lhr=(4, 8, 8)))
        saving = 1 - opt.lut / base.lut
        assert 0.70 < saving < 0.85

    def test_latency_monotone_in_uniform_lhr(self):
        cfg0 = paper_nets.build("net-1")
        counts = paper_nets.paper_counts("net-1", cfg0)
        prev = 0.0
        for k in (1, 2, 4, 8):
            cur = float(cycle_model.latency_cycles(cfg0.with_lhr((k, k, k)),
                                                   counts))
            assert cur > prev
            prev = cur


class TestDSE:
    def _setup(self):
        cfg = paper_nets.build("net-1")
        counts = paper_nets.paper_counts("net-1", cfg)
        return cfg, counts

    def test_grid_covers_powers_of_two(self):
        cfg, _ = self._setup()
        grid = dse.lhr_grid(cfg, max_lhr=8)
        assert grid.shape[1] == 3
        assert set(np.unique(grid)) == {1, 2, 4, 8}

    def test_pareto_frontier_nondominated(self):
        cfg, counts = self._setup()
        res = dse.sweep(cfg, counts, max_lhr=16)
        frontier = res.frontier
        assert len(frontier) >= 3
        for a in frontier:
            for b in res.candidates:
                assert not (b.cycles < a.cycles and b.lut < a.lut), \
                    f"{a.lhr} dominated by {b.lhr}"

    def test_auto_select_budgets(self):
        cfg, counts = self._setup()
        res = dse.sweep(cfg, counts, max_lhr=16)
        fast = res.best_within_area(max_lut=50e3)
        small = res.best_within_latency(max_cycles=20e3)
        assert fast.lut <= 50e3
        assert small.cycles <= 20e3
        # optimality: nothing beats them inside their own budget
        for c in res.candidates:
            if c.lut <= 50e3:
                assert fast.cycles <= c.cycles
            if c.cycles <= 20e3:
                assert small.lut <= c.lut
