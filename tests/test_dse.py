"""Tests for the unified multi-axis DSE subsystem: k-objective Pareto on
ties/duplicates, chunked-vs-monolithic equivalence, joint-axis sweeps
reproducing the legacy wrappers exactly, batched cycle-model/resource paths
against their scalar twins, and >200k-candidate streaming."""
import dataclasses

import numpy as np
import pytest

from repro.core import dse
from repro.core.accelerator import arch, cycle_model, paper_nets, resources


def _fc_cfg(lhr=(1, 1), sizes=(100, 50, 20), T=5):
    return arch.from_layer_sizes("t", sizes, lhr=lhr, num_steps=T)


def _net1():
    cfg = paper_nets.build("net-1")
    return cfg, paper_nets.paper_counts("net-1", cfg)


def _brute_force_mask(obj):
    n = len(obj)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        for j in range(n):
            if np.all(obj[j] <= obj[i]) and np.any(obj[j] < obj[i]):
                mask[i] = False
                break
    return mask


def _sorted_rows(a):
    a = np.asarray(a, np.float64)
    return a[np.lexsort(a.T)]


class TestParetoMask:
    def test_ties_and_duplicates(self):
        obj = np.array([[1.0, 2.0], [1.0, 2.0],     # duplicated frontier pt
                        [2.0, 1.0],
                        [2.0, 2.0],                  # dominated by (1,2)
                        [1.0, 3.0],                  # dominated by (1,2)
                        [3.0, 1.0]])                 # dominated by (2,1)
        mask = dse.pareto_mask_k(obj)
        np.testing.assert_array_equal(
            mask, [True, True, True, False, False, False])

    @pytest.mark.parametrize("k", [2, 3, 4])
    @pytest.mark.parametrize("seed", range(3))
    def test_matches_brute_force_with_ties(self, k, seed):
        rng = np.random.default_rng(seed)
        # small integer grid => plenty of exact ties and duplicates
        obj = rng.integers(0, 4, size=(60, k)).astype(float)
        np.testing.assert_array_equal(dse.pareto_mask_k(obj),
                                      _brute_force_mask(obj))

    def test_blockwise_matches_single_block(self):
        rng = np.random.default_rng(7)
        obj = rng.integers(0, 10, size=(500, 3)).astype(float)
        np.testing.assert_array_equal(dse.pareto_mask_k(obj, block=17),
                                      dse.pareto_mask_k(obj, block=10_000))

    def test_legacy_two_objective_signature(self):
        cyc = np.array([1.0, 2.0, 3.0, 2.0])
        lut = np.array([3.0, 2.0, 1.0, 2.0])
        mask = dse.pareto_mask(cyc, lut)
        np.testing.assert_array_equal(mask, [True, True, True, True])
        assert not dse.pareto_mask(np.array([1.0, 2.0]),
                                   np.array([1.0, 2.0]))[1]


class TestParetoAccumulator:
    @pytest.mark.parametrize("chunk", [1, 7, 64, 1000])
    @pytest.mark.parametrize("seed", range(3))
    def test_incremental_equals_monolithic(self, chunk, seed):
        rng = np.random.default_rng(seed)
        obj = rng.integers(0, 5, size=(300, 3)).astype(float)  # many dups
        acc = dse.ParetoAccumulator(("a", "b", "c"))
        for s in range(0, len(obj), chunk):
            sub = obj[s:s + chunk]
            acc.update(dse.CandidateTable(
                {"a": sub[:, 0], "b": sub[:, 1], "c": sub[:, 2]}))
        got = np.stack([acc.frontier.columns[k] for k in "abc"], axis=1)
        # exact full-row duplicates are kept once, independent of chunking
        want = np.unique(obj[dse.pareto_mask_k(obj)], axis=0)
        np.testing.assert_array_equal(_sorted_rows(got), _sorted_rows(want))

    def test_empty_and_single_updates(self):
        acc = dse.ParetoAccumulator(("x",))
        assert len(acc.frontier) == 0
        acc.update(dse.CandidateTable({"x": np.array([3.0, 1.0, 2.0])}))
        np.testing.assert_array_equal(acc.frontier.columns["x"], [1.0])

    def test_string_columns_supported(self):
        """Non-numeric columns (the coexplore ``dataset`` axis) survive the
        merge: distinct datasets with tied objectives both stay, exact
        re-evaluations still dedup."""
        acc = dse.ParetoAccumulator(("cycles",))
        chunk = dse.CandidateTable(
            {"dataset": np.array(["mnist", "dvs"]),
             "cycles": np.array([5.0, 5.0])})
        acc.update(chunk)
        acc.update(chunk)                       # exact re-evaluation
        assert len(acc.frontier) == 2
        assert sorted(acc.frontier.columns["dataset"].tolist()) == \
            ["dvs", "mnist"]

    def test_reevaluated_candidate_kept_once(self):
        """Re-visiting the same candidate (Random/EvolutionarySearch) must
        not inflate the frontier, while distinct candidates with tied
        objectives both survive."""
        acc = dse.ParetoAccumulator(("cycles", "lut"))
        chunk = dse.CandidateTable({"lhr": np.array([[1, 2], [2, 1]]),
                                    "cycles": np.array([5.0, 5.0]),
                                    "lut": np.array([3.0, 3.0])})
        acc.update(chunk)
        acc.update(chunk)                       # exact re-evaluation
        assert len(acc.frontier) == 2           # tie kept, re-visit dropped
        assert sorted(map(tuple, acc.frontier.columns["lhr"].tolist())) == \
            [(1, 2), (2, 1)]


class TestSearchSpace:
    def test_size_and_decode_order_match_product(self):
        cfg = _fc_cfg()
        space = dse.SearchSpace.product_lhr(cfg, max_lhr=8)
        grid = dse.lhr_grid(cfg, max_lhr=8)
        assert space.size == len(grid)
        np.testing.assert_array_equal(
            space.decode(np.arange(space.size))["lhr"], grid)

    def test_joint_and_global_axes(self):
        cfg = _fc_cfg()
        space = (dse.SearchSpace(cfg)
                 .add_joint("mem_blocks", [(1, 1), (2, 2), (4, 2)])
                 .add_global("weight_bits", (4, 8)))
        assert space.size == 6
        cols = space.decode(np.arange(6))
        assert cols["mem_blocks"].shape == (6, 2)
        assert cols["weight_bits"].shape == (6,)
        # last axis fastest (itertools.product order)
        np.testing.assert_array_equal(cols["weight_bits"],
                                      [4, 8, 4, 8, 4, 8])
        np.testing.assert_array_equal(cols["mem_blocks"][:, 0],
                                      [1, 1, 2, 2, 4, 4])

    def test_per_layer_defaults_fill_uncovered_layers(self):
        cfg = _fc_cfg(lhr=(5, 2))
        space = dse.SearchSpace(cfg, [dse.Axis("lhr", (1, 4), layer=0)])
        cols = space.decode(np.arange(space.size))
        np.testing.assert_array_equal(cols["lhr"],
                                      [[1, 2], [4, 2]])

    def test_conflicting_axes_rejected(self):
        cfg = _fc_cfg()
        space = dse.SearchSpace(cfg).add_global("weight_bits", (4, 8))
        with pytest.raises(ValueError):
            space.add_global("weight_bits", (16,))
        with pytest.raises(ValueError):
            space.add_joint("weight_bits", [(4, 4)])


class TestBatchedModels:
    """The batched cycle-model/resource paths equal their scalar twins on
    materialized configs — for every axis, not just LHR."""

    def _combos(self, cfg, seed=0):
        rng = np.random.default_rng(seed)
        n = 12
        L = len(cfg.layers)
        lhr = np.stack([rng.choice(dse.pow2_values(l.logical), size=n)
                        for l in cfg.layers], axis=1)
        mem = np.stack([rng.choice([0, 1, 2, 8], size=n)
                        for _ in range(L)], axis=1)
        wb = rng.choice([4, 8, 16], size=n)
        pw = rng.choice([50, 100], size=n)
        return lhr, mem, wb, pw

    def test_latency_joint_lhr_mem_penc_matches_scalar(self):
        cfg, counts = _net1()
        lhr, mem, _, pw = self._combos(cfg)
        vec = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr,
                                         mem_blocks_matrix=mem,
                                         penc_width=pw)
        for i in range(len(lhr)):
            c = cfg.with_updates(lhr=lhr[i], mem_blocks=mem[i],
                                 penc_width=int(pw[i]))
            scalar = cycle_model.latency_cycles(c, counts)
            np.testing.assert_array_equal(vec[i], scalar)

    def test_estimate_vector_matches_scalar(self):
        cfg, _ = _net1()
        lhr, mem, wb, pw = self._combos(cfg, seed=1)
        vec = resources.estimate_vector(cfg, lhr_matrix=lhr,
                                        mem_blocks_matrix=mem,
                                        weight_bits=wb, penc_width=pw)
        for i in range(len(lhr)):
            c = cfg.with_updates(lhr=lhr[i], mem_blocks=mem[i],
                                 weight_bits=int(wb[i]),
                                 penc_width=int(pw[i]))
            r = resources.estimate(c)
            np.testing.assert_allclose(vec.lut[i], r.lut, rtol=1e-12)
            np.testing.assert_allclose(vec.reg[i], r.reg, rtol=1e-12)
            assert vec.bram36[i] == r.bram36
            assert vec.dsp[i] == r.dsp

    def test_energy_vector_matches_scalar(self):
        cfg, counts = _net1()
        lhr, _, _, _ = self._combos(cfg, seed=2)
        cycles = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr)
        vec = resources.energy_mj_vector(cfg, counts, cycles, lhr_matrix=lhr)
        for i in range(len(lhr)):
            c = cfg.with_lhr(tuple(int(x) for x in lhr[i]))
            assert vec[i] == resources.energy_mj(c, counts, float(cycles[i]))


class TestChunkedEquivalence:
    def test_chunked_vs_monolithic_search(self):
        cfg, counts = _net1()
        space = (dse.SearchSpace.product_lhr(cfg, max_lhr=8)
                 .add_global("weight_bits", (4, 8)))
        a = dse.search(cfg, counts, space, chunk_size=13)
        b = dse.search(cfg, counts, space, chunk_size=10**6)
        assert a.n_evaluated == b.n_evaluated == space.size
        ga = np.stack([a.frontier.columns[k] for k in a.objectives], axis=1)
        gb = np.stack([b.frontier.columns[k] for k in b.objectives], axis=1)
        np.testing.assert_array_equal(_sorted_rows(ga), _sorted_rows(gb))

    def test_search_frontier_equals_legacy_sweep(self):
        cfg, counts = _net1()
        legacy = dse.sweep(cfg, counts, max_lhr=16)
        res = dse.search(cfg, counts,
                         dse.SearchSpace.product_lhr(cfg, max_lhr=16),
                         objectives=("cycles", "lut"), chunk_size=97)
        want = sorted((c.lhr, c.cycles, c.lut) for c in legacy.frontier)
        got = sorted((r["lhr"], r["cycles"], r["lut"])
                     for r in (res.frontier.row(i)
                               for i in range(len(res.frontier))))
        assert want == got


class TestLegacyWrappers:
    """The rewired wrappers reproduce the seed implementations exactly."""

    def test_sweep_matches_seed_style_per_candidate_loop(self):
        cfg, counts = _net1()
        res = dse.sweep(cfg, counts, max_lhr=8, chunk_size=11)
        assert len(res.candidates) == 4 ** 3
        for c in list(res.candidates)[::17]:
            ccfg = cfg.with_lhr(c.lhr)
            assert c.cycles == float(cycle_model.latency_cycles(ccfg, counts))
            assert c.lut == resources.estimate(ccfg).lut
            assert c.energy_mj == resources.energy_mj(ccfg, counts, c.cycles)

    def test_sweep_memory_blocks_matches_seed(self):
        cfg, counts = _net1()
        cfg = cfg.with_lhr((2, 2, 2))
        got = dse.sweep_memory_blocks(cfg, counts, divisors=(1, 2, 4, 8))
        assert len(got) == 4
        for cand in got:
            layers = tuple(dataclasses.replace(l, mem_blocks=b)
                           for l, b in zip(cfg.layers, cand.blocks))
            c = dataclasses.replace(cfg, layers=layers)
            assert cand.blocks == tuple(l.num_mem_blocks for l in layers)
            assert cand.cycles == float(cycle_model.latency_cycles(c, counts))
            r = resources.estimate(c)
            assert cand.lut == r.lut and cand.bram == r.bram36

    def test_sweep_weight_bits_matches_seed(self):
        cfg, _ = _net1()
        got = dse.sweep_weight_bits(cfg, (4, 6, 8, 12, 16))
        for bits, bram in got.items():
            layers = tuple(dataclasses.replace(l, weight_bits=bits)
                           for l in cfg.layers)
            c = dataclasses.replace(cfg, layers=layers)
            assert bram == resources.estimate(c).bram36

    def test_joint_axis_sweep_reproduces_both_wrappers(self):
        """One joint LHR x mem_blocks x weight_bits space contains the old
        single-axis sweeps as slices, with identical numbers."""
        cfg, counts = _net1()
        cfg = cfg.with_lhr((2, 2, 2))
        divisors = (1, 2, 4)
        bits = (4, 8)
        space = (dse.SearchSpace(cfg)
                 .add_joint("mem_blocks",
                            [tuple(max(1, l.num_nus // d) for l in cfg.layers)
                             for d in divisors])
                 .add_global("weight_bits", bits))
        res = dse.search(cfg, counts, space, keep_all=True)
        t = res.table
        assert res.n_evaluated == len(divisors) * len(bits)
        mem_ref = dse.sweep_memory_blocks(cfg, counts, divisors=divisors)
        bits_ref = dse.sweep_weight_bits(cfg, bits)
        for i in range(len(t)):
            row = t.row(i)
            mem_row = mem_ref[i // len(bits)]
            assert row["mem_blocks"] == mem_row.blocks
            assert row["cycles"] == mem_row.cycles
            assert row["lut"] == mem_row.lut
            # BRAM depends only on weight_bits for these layers
            assert row["bram"] == bits_ref[row["weight_bits"]]


class TestStreamingLargeSpace:
    def test_over_200k_candidates_stream_without_cap(self):
        cfg = arch.from_layer_sizes("big", (512, 256, 256, 256, 256),
                                    num_steps=2)
        counts = [np.full(2, 30.0)] * 4
        space = (dse.SearchSpace.product_lhr(cfg, max_lhr=256)
                 .add_joint("mem_blocks",
                            [tuple(max(1, l.num_nus // d)
                                   for l in cfg.layers)
                             for d in (1, 2, 4, 8)])
                 .add_global("weight_bits", (4, 6, 8, 12))
                 .add_global("penc_width", (64, 100)))
        assert space.size > 200_000
        # the seed grid path refuses a space this large ...
        with pytest.raises(ValueError, match="exceed cap"):
            dse.lhr_grid(arch.from_layer_sizes(
                "x", (512,) + (256,) * 6), max_lhr=256)
        # ... the streaming engine does not
        res = dse.search(cfg, counts, space, chunk_size=32768)
        assert res.n_evaluated == space.size
        assert res.table is None                     # nothing materialized
        assert 0 < len(res.frontier) < res.n_evaluated
        fo = np.stack([res.frontier.columns[k] for k in res.objectives],
                      axis=1)
        assert dse.pareto_mask_k(fo).all()           # mutually non-dominated

    def test_streaming_frontier_equals_monolithic_on_control_space(self):
        """Same axes, smaller extents: chunked streaming returns the exact
        monolithic frontier."""
        cfg = arch.from_layer_sizes("ctl", (128, 64, 64), num_steps=2)
        counts = [np.full(2, 10.0)] * 2
        space = (dse.SearchSpace.product_lhr(cfg, max_lhr=16)
                 .add_global("weight_bits", (4, 8)))
        chunked = dse.search(cfg, counts, space, chunk_size=19)
        mono = dse.search(cfg, counts, space, chunk_size=10**6,
                          keep_all=True)
        mask = dse.pareto_mask_k(np.stack(
            [mono.table.columns[k] for k in mono.objectives], axis=1))
        want = np.stack([mono.table.columns[k][mask]
                         for k in mono.objectives], axis=1)
        got = np.stack([chunked.frontier.columns[k]
                        for k in chunked.objectives], axis=1)
        np.testing.assert_array_equal(_sorted_rows(got), _sorted_rows(want))


class TestStrategiesAndSelect:
    def _small(self):
        cfg = _fc_cfg(sizes=(64, 32, 16), T=3)
        counts = [np.full(3, 8.0)] * 2
        space = dse.SearchSpace.product_lhr(cfg, max_lhr=8)
        return cfg, counts, space

    def test_random_search_deterministic_and_valid(self):
        cfg, counts, space = self._small()
        a = dse.search(cfg, counts, space,
                       strategy=dse.RandomSearch(200, seed=3), keep_all=True)
        b = dse.search(cfg, counts, space,
                       strategy=dse.RandomSearch(200, seed=3), keep_all=True)
        assert a.n_evaluated == b.n_evaluated == 200
        np.testing.assert_array_equal(a.table.columns["lhr"],
                                      b.table.columns["lhr"])
        caps = np.asarray([min(8, l.logical) for l in cfg.layers])
        assert (a.table.columns["lhr"] <= caps).all()

    def test_evolutionary_search_runs_and_converges_sane(self):
        cfg, counts, space = self._small()
        res = dse.search(cfg, counts, space,
                         strategy=dse.EvolutionarySearch(
                             population=16, generations=5, seed=0))
        assert res.n_evaluated == 16 * 5
        fo = np.stack([res.frontier.columns[k] for k in res.objectives],
                      axis=1)
        assert dse.pareto_mask_k(fo).all()

    def test_auto_select_budgets(self):
        cfg, counts = _net1()
        space = dse.SearchSpace.product_lhr(cfg, max_lhr=16)
        picked, row = dse.auto_select(cfg, counts, max_cycles=20e3,
                                      space=space, keep_all=True)
        assert row["cycles"] <= 20e3
        assert picked.lhr == row["lhr"]
        # optimality vs the exhaustive legacy sweep
        legacy = dse.sweep(cfg, counts, max_lhr=16)
        best = legacy.best_within_latency(20e3)
        assert row["lut"] == best.lut
        picked2, row2 = dse.auto_select(cfg, counts, max_lut=50e3,
                                        space=space, keep_all=True)
        assert row2["lut"] <= 50e3
        assert row2["cycles"] == legacy.best_within_area(50e3).cycles
        _, row3 = dse.auto_select(cfg, counts, space=space)
        assert row3["energy"] == legacy.min_energy().energy_mj
        assert dse.auto_select(cfg, counts, max_cycles=1.0,
                               space=space) is None

    def test_frontier_only_result_rejects_non_objective_queries(self):
        cfg, counts = _net1()
        res = dse.search(cfg, counts,
                         dse.SearchSpace.product_lhr(cfg, max_lhr=8),
                         objectives=("cycles", "lut"))
        with pytest.raises(ValueError, match="not search objectives"):
            res.min_energy()                     # energy not an objective
        with pytest.raises(ValueError, match="not search objectives"):
            res.best_under("lut", bram=100)
        assert res.best_within_latency(1e9) is not None   # objectives: fine
        full = dse.search(cfg, counts,
                          dse.SearchSpace.product_lhr(cfg, max_lhr=8),
                          objectives=("cycles", "lut"), keep_all=True)
        assert full.min_energy() is not None     # full table: any column
