"""Distributed-runtime tests.

Mesh-based behaviours run in SUBPROCESSES with
``xla_force_host_platform_device_count=8`` so the main pytest process keeps
its default single-device view (the dry-run contract in DESIGN.md §6).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


class TestShardingRules:
    def test_param_specs_cover_tree_single_device(self):
        """Spec construction is pure metadata — works without any mesh."""
        import jax
        from repro.configs.base import ArchConfig
        from repro.distributed import sharding
        from repro.models import registry
        from repro.launch.mesh import make_production_mesh
        # Use mesh only for axis sizes; build on the default 1-device view is
        # not possible for a 256-mesh, so fabricate a shape-compatible mock.
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        cfg = ArchConfig(name="t", family="transformer", num_layers=2,
                         d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=512,
                         head_dim=16, dtype="float32")
        shapes = jax.eval_shape(
            lambda: registry.init_params(jax.random.key(0), cfg))
        specs = sharding.param_specs(cfg, shapes, MockMesh())
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "index"))
        assert len(flat_shapes) == len(flat_specs)
        # every sharded dim must divide by its mesh axis
        for shape, spec in zip(flat_shapes, flat_specs):
            for dim, entry in zip(shape.shape, tuple(spec)):
                if entry == "model":
                    assert dim % 16 == 0, (shape.shape, tuple(spec))

    def test_moe_expert_vs_ffn_sharding(self):
        import jax
        from repro.models import registry
        from repro.distributed import sharding

        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        for arch, expect_expert in (("arctic_480b", True),
                                    ("mixtral_8x7b", False)):
            cfg = registry.load_arch(arch)
            shapes = jax.eval_shape(
                lambda: registry.init_params(jax.random.key(0), cfg))
            specs = sharding.param_specs(cfg, shapes, MockMesh(), fsdp=False)
            wg = specs["layers"]["moe"]["w_gate"]
            if expect_expert:
                assert tuple(wg)[1] == "model", tuple(wg)  # (L, E, d, ff)
            else:
                assert tuple(wg)[3] == "model", tuple(wg)


class TestTrainStepParallel:
    def test_train_step_matches_single_device(self):
        """The sharded train step computes the same loss as 1-device."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.configs.base import ArchConfig, ShapeConfig
            from repro.models import registry
            from repro.train import steps
            from repro.distributed import sharding
            from repro.data import pipeline

            cfg = ArchConfig(name='m', family='transformer', num_layers=2,
                             d_model=64, n_heads=4, n_kv=2, d_ff=128,
                             vocab=256, head_dim=16, dtype='float32')
            settings = steps.TrainSettings(learning_rate=1e-2, z_loss=0.0,
                                           microbatches=2)
            dcfg = pipeline.DataConfig(vocab=256, seq_len=32, global_batch=8)
            batch = pipeline.synthetic_lm_batch(dcfg, 0)
            params = registry.init_params(jax.random.key(0), cfg)
            tx = steps.make_optimizer(settings)
            opt0 = tx.init(params)

            # single device reference
            step1 = jax.jit(steps.build_train_step(cfg, settings))
            p1, o1, m1 = step1(params, opt0,
                               {k: jnp.asarray(v) for k, v in batch.items()})

            mesh = jax.make_mesh((2, 4), ('data', 'model'))
            with mesh:
                p_sh, o_sh, p_s, o_s = steps.state_shardings(cfg, settings,
                                                             mesh)
                bspecs = sharding.batch_specs(
                    cfg, {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                          for k, v in batch.items()}, mesh)
                b_sh = sharding.to_named(bspecs, mesh)
                params_d = jax.device_put(params, p_sh)
                opt_d = jax.device_put(opt0, o_sh)
                batch_d = {k: jax.device_put(jnp.asarray(v), b_sh[k])
                           for k, v in batch.items()}
                stepN = jax.jit(steps.build_train_step(cfg, settings, mesh),
                                in_shardings=(p_sh, o_sh, b_sh),
                                out_shardings=(p_sh, o_sh, None))
                pN, oN, mN = stepN(params_d, opt_d, batch_d)
            np.testing.assert_allclose(float(m1['loss']), float(mN['loss']),
                                       rtol=1e-4)
            d1 = jax.tree.leaves(p1)[3]
            dN = jax.tree.leaves(pN)[3]
            np.testing.assert_allclose(np.asarray(d1), np.asarray(dN),
                                       atol=2e-5)
            print('PARALLEL_OK')
        """)
        assert "PARALLEL_OK" in out


class TestCheckpoint:
    def test_roundtrip_identity(self, tmp_path):
        import jax
        import jax.numpy as jnp
        from repro.checkpoint import store
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)},
                "d": jnp.asarray(3)}
        store.save(str(tmp_path), 7, tree)
        out = store.restore(str(tmp_path), tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_retention_and_latest(self, tmp_path):
        import jax.numpy as jnp
        from repro.checkpoint import store
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            store.save(str(tmp_path), s, tree, keep_last=2)
        assert store.all_steps(str(tmp_path)) == [3, 4]
        assert store.latest_step(str(tmp_path)) == 4

    def test_async_save(self, tmp_path):
        import jax.numpy as jnp
        from repro.checkpoint import store
        tree = {"x": jnp.arange(1000.0)}
        t = store.save_async(str(tmp_path), 1, tree)
        t.join()
        out = store.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.asarray(tree["x"]))

    def test_elastic_restore_across_meshes(self, tmp_path):
        """Save on a (4,2) mesh, restore on (2,2) — resharding on load."""
        out = run_with_devices(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import store
            mesh1 = jax.make_mesh((4, 2), ('data', 'model'))
            x = jnp.arange(64.0).reshape(8, 8)
            xs = jax.device_put(x, NamedSharding(mesh1, P('data', 'model')))
            store.save({str(tmp_path)!r}, 1, {{'x': xs}})

            mesh2 = jax.make_mesh((2, 2), ('data', 'model'),
                                  devices=jax.devices()[:4])
            tgt = NamedSharding(mesh2, P('model', 'data'))
            out = store.restore({str(tmp_path)!r}, {{'x': x}},
                                shardings={{'x': tgt}})
            assert out['x'].sharding == tgt, out['x'].sharding
            np.testing.assert_array_equal(np.asarray(out['x']),
                                          np.asarray(x))
            print('ELASTIC_OK')
        """)
        assert "ELASTIC_OK" in out


class TestFaultTolerance:
    def test_supervisor_recovers_from_failures(self, tmp_path):
        import jax.numpy as jnp
        from repro.distributed.fault_tolerance import (SupervisorConfig,
                                                       TrainSupervisor)
        state = {"w": jnp.zeros(4), "step": jnp.asarray(0)}
        crashed = {"flag": False}

        def step_fn(state, step):
            if step == 7 and not crashed["flag"]:
                crashed["flag"] = True          # simulated node failure
                raise RuntimeError("node lost")
            return {"w": state["w"] + 1.0, "step": state["step"] + 1}

        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=str(tmp_path),
                             checkpoint_every=2, async_save=False),
            state)
        final = sup.run(step_fn, num_steps=10)
        # restart must not lose or duplicate steps: w ends at exactly 10
        assert float(final["w"][0]) == 10.0
        assert sup.restarts == 1

    def test_supervisor_gives_up_after_max_restarts(self, tmp_path):
        from repro.distributed.fault_tolerance import (SupervisorConfig,
                                                       TrainSupervisor)

        def bad_step(state, step):
            raise RuntimeError("always fails")

        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=str(tmp_path), max_restarts=2,
                             async_save=False), {"x": np.zeros(1)})
        with pytest.raises(RuntimeError):
            sup.run(bad_step, num_steps=5)

    def test_restore_joins_inflight_async_save_first(self, tmp_path,
                                                     monkeypatch):
        """Regression: ``_restore`` used to read ``latest_step`` BEFORE
        joining the in-flight async save, so a crash racing a slow writer
        restored the previous (stale) checkpoint and silently replayed
        already-durable steps.  With a save that publishes step 4 only
        after a delay, the restore must still pick 4, not 2."""
        import threading
        import time as _time

        import jax.numpy as jnp
        from repro.checkpoint import store
        from repro.distributed.fault_tolerance import (SupervisorConfig,
                                                       TrainSupervisor)

        def slow_save_async(path, step, state, keep_last=3):
            def _write():
                _time.sleep(0.5)             # the slow network store
                store.save(path, step, state, keep_last=keep_last)
            t = threading.Thread(target=_write)
            t.start()
            return t

        monkeypatch.setattr(store, "save_async", slow_save_async)
        state = {"w": jnp.arange(3.0)}
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=str(tmp_path),
                             checkpoint_every=2, async_save=True), state)
        store.save(str(tmp_path), 2, state)  # an older durable checkpoint
        sup._save(4)                         # in flight for the next 0.5s
        step = sup._restore()                # "node failure" mid-save
        assert step == 4                     # joined the writer, not stale
        assert sup._pending is None
        assert store.latest_step(str(tmp_path)) == 4


class TestGradientCompression:
    def test_quantize_roundtrip_error_bounded(self):
        import jax.numpy as jnp
        from repro.distributed import compression
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, scale = compression.quantize_int8(g)
        err = np.abs(np.asarray(compression.dequantize(q, scale) - g))
        assert err.max() <= float(scale) / 2 + 1e-6

    def test_error_feedback_converges(self):
        """int8+EF SGD reaches the same loss basin as exact SGD on a toy
        least-squares problem across 8 data shards."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed import compression
            mesh = jax.make_mesh((8,), ('data',))
            rng = np.random.default_rng(0)
            X = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
            w_true = jnp.asarray(rng.standard_normal(16), jnp.float32)
            y = X @ w_true

            def loss_fn(w, batch):
                xb, yb = batch
                return jnp.mean((xb @ w - yb) ** 2)

            grad_step = compression.make_compressed_grad_fn(
                loss_fn, mesh, ('data',))
            w = jnp.zeros(16)
            errors = compression.init_errors(w, 8)
            for i in range(150):
                loss, g, errors = grad_step(w, (X, y), errors)
                w = w - 0.05 * g
            final = float(loss_fn(w, (X, y)))
            assert final < 1e-3, final
            print('EF_CONVERGED', final)
        """)
        assert "EF_CONVERGED" in out
