"""Pipeline-parallel correctness: the GPipe schedule over a 4-stage mesh
must equal sequential layer application."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import pipeline

        mesh = jax.make_mesh((4,), ("stage",))
        L, d, n_micro, b = 8, 16, 6, 4
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((L, d, d)) / np.sqrt(d),
                         jnp.float32)
        xs = jnp.asarray(rng.standard_normal((n_micro, b, d)), jnp.float32)

        def layer(w, x):
            return jnp.tanh(x @ w)

        def stage_fn(params, x):          # params: (L/S, d, d)
            def body(x, w):
                return layer(w, x), None
            out, _ = jax.lax.scan(body, x, params)
            return out

        stage_params = pipeline.stack_stages(ws, 4)
        got = pipeline.pipeline_apply(stage_fn, stage_params, xs, mesh)

        # sequential reference
        want = xs
        for l in range(L):
            want = jax.vmap(lambda x: layer(ws[l], x))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        print("PIPELINE_OK")
    """
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert res.returncode == 0, f"{res.stdout}\n{res.stderr}"
    assert "PIPELINE_OK" in res.stdout
