"""Spike-to-spike validation: serial hardware model == vectorized reference,
exactly, across random nets/traffic/LHR (property-style sweep)."""
import numpy as np
import pytest

from repro.core import validate


def _random_net(rng, sizes):
    weights = [rng.normal(0, 0.5, size=(sizes[i], sizes[i + 1]))
               for i in range(len(sizes) - 1)]
    biases = [rng.normal(0, 0.1, size=(sizes[i + 1],))
              for i in range(len(sizes) - 1)]
    return validate.quantize(weights, biases, beta=0.9, threshold=1.0)


class TestPENC:
    def test_compress_orders_addresses(self):
        bits = np.zeros(250, np.int64)
        bits[[5, 120, 119, 249, 0]] = 1
        addrs = validate.penc_compress(bits, chunk=100)
        assert addrs == [0, 5, 119, 120, 249]

    def test_compress_empty(self):
        assert validate.penc_compress(np.zeros(10, np.int64)) == []

    @pytest.mark.parametrize("seed", range(5))
    def test_compress_complete(self, seed):
        rng = np.random.default_rng(seed)
        bits = (rng.random(333) < 0.3).astype(np.int64)
        addrs = validate.penc_compress(bits)
        assert sorted(addrs) == list(np.nonzero(bits)[0])


class TestSpikeToSpike:
    @pytest.mark.parametrize("seed", range(6))
    def test_hardware_equals_reference(self, seed):
        rng = np.random.default_rng(seed)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((6, 24)) < 0.3).astype(np.int64)
        assert validate.validate(net, spikes)

    @pytest.mark.parametrize("lhr", [[1, 1], [4, 2], [16, 8], [3, 5]])
    def test_lhr_does_not_change_function(self, lhr):
        """The LHR knob is a pure latency/area trade — never functional."""
        rng = np.random.default_rng(42)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((6, 24)) < 0.4).astype(np.int64)
        assert validate.validate(net, spikes, lhr=lhr)

    def test_quantized_net_actually_spikes(self):
        rng = np.random.default_rng(1)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((8, 24)) < 0.5).astype(np.int64)
        out = validate.reference_apply(net, spikes)
        assert out.sum() > 0

    def test_float_vs_fixed_point_agreement(self):
        """Quantization at Q8 should preserve most spikes vs float sim."""
        rng = np.random.default_rng(7)
        sizes = (24, 16, 8)
        weights = [rng.normal(0, 0.5, size=(sizes[i], sizes[i + 1]))
                   for i in range(2)]
        biases = [rng.normal(0, 0.1, size=(sizes[i + 1],)) for i in range(2)]
        net = validate.quantize(weights, biases, beta=0.9, threshold=1.0)
        spikes = (rng.random((10, 24)) < 0.4).astype(np.int64)
        fixed = validate.reference_apply(net, spikes)

        # float simulation of the same dynamics
        u = [np.zeros(16), np.zeros(8)]
        s = [np.zeros(16), np.zeros(8)]
        out = np.zeros((10, 8))
        for t in range(10):
            x = spikes[t].astype(float)
            for l in range(2):
                u[l] = 0.9 * u[l] + x @ weights[l] + biases[l] - 1.0 * s[l]
                s[l] = (u[l] >= 1.0).astype(float)
                x = s[l]
            out[t] = s[-1]
        agreement = (out == fixed).mean()
        assert agreement > 0.95
