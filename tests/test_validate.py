"""Spike-to-spike validation: serial hardware model == vectorized reference,
exactly, across random nets/traffic/LHR (property-style sweep)."""
import numpy as np
import pytest

from repro.core import validate


def _random_net(rng, sizes):
    weights = [rng.normal(0, 0.5, size=(sizes[i], sizes[i + 1]))
               for i in range(len(sizes) - 1)]
    biases = [rng.normal(0, 0.1, size=(sizes[i + 1],))
              for i in range(len(sizes) - 1)]
    return validate.quantize(weights, biases, beta=0.9, threshold=1.0)


class TestPENC:
    def test_compress_orders_addresses(self):
        bits = np.zeros(250, np.int64)
        bits[[5, 120, 119, 249, 0]] = 1
        addrs = validate.penc_compress(bits, chunk=100)
        assert addrs == [0, 5, 119, 120, 249]

    def test_compress_empty(self):
        assert validate.penc_compress(np.zeros(10, np.int64)) == []

    @pytest.mark.parametrize("seed", range(5))
    def test_compress_complete(self, seed):
        rng = np.random.default_rng(seed)
        bits = (rng.random(333) < 0.3).astype(np.int64)
        addrs = validate.penc_compress(bits)
        assert sorted(addrs) == list(np.nonzero(bits)[0])


class TestSpikeToSpike:
    @pytest.mark.parametrize("seed", range(6))
    def test_hardware_equals_reference(self, seed):
        rng = np.random.default_rng(seed)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((6, 24)) < 0.3).astype(np.int64)
        assert validate.validate(net, spikes)

    @pytest.mark.parametrize("lhr", [[1, 1], [4, 2], [16, 8], [3, 5]])
    def test_lhr_does_not_change_function(self, lhr):
        """The LHR knob is a pure latency/area trade — never functional."""
        rng = np.random.default_rng(42)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((6, 24)) < 0.4).astype(np.int64)
        assert validate.validate(net, spikes, lhr=lhr)

    def test_quantized_net_actually_spikes(self):
        rng = np.random.default_rng(1)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((8, 24)) < 0.5).astype(np.int64)
        out = validate.reference_apply(net, spikes)
        assert out.sum() > 0

    def test_float_vs_fixed_point_agreement(self):
        """Quantization at Q8 should preserve most spikes vs float sim."""
        rng = np.random.default_rng(7)
        sizes = (24, 16, 8)
        weights = [rng.normal(0, 0.5, size=(sizes[i], sizes[i + 1]))
                   for i in range(2)]
        biases = [rng.normal(0, 0.1, size=(sizes[i + 1],)) for i in range(2)]
        net = validate.quantize(weights, biases, beta=0.9, threshold=1.0)
        spikes = (rng.random((10, 24)) < 0.4).astype(np.int64)
        fixed = validate.reference_apply(net, spikes)

        # float simulation of the same dynamics
        u = [np.zeros(16), np.zeros(8)]
        s = [np.zeros(16), np.zeros(8)]
        out = np.zeros((10, 8))
        for t in range(10):
            x = spikes[t].astype(float)
            for l in range(2):
                u[l] = 0.9 * u[l] + x @ weights[l] + biases[l] - 1.0 * s[l]
                s[l] = (u[l] >= 1.0).astype(float)
                x = s[l]
            out[t] = s[-1]
        agreement = (out == fixed).mean()
        assert agreement > 0.95


def _random_conv_net(rng, cin=2, c1=4, n_out=6):
    """3x3 conv -> 2x2 OR-pool -> dense classifier, float params."""
    weights = [rng.normal(0, 0.5, size=(3, 3, cin, c1)),
               rng.normal(0, 0.3, size=(4 * 4 * c1, n_out))]
    biases = [rng.normal(0, 0.1, size=(c1,)),
              rng.normal(0, 0.1, size=(n_out,))]
    specs = [("conv", 1, "SAME"), ("pool", 2), ("dense",)]
    return weights, biases, specs


def _float_conv_sim(weights, biases, spikes, beta=0.9, threshold=1.0):
    """Float twin of the fixed-point conv forward (same LIF dynamics)."""
    T, B = spikes.shape[:2]
    w_conv, w_fc = weights
    c1, n_out = w_conv.shape[-1], w_fc.shape[-1]
    H = spikes.shape[2]
    u = [np.zeros((B, H, H, c1)), np.zeros((B, n_out))]
    s = [np.zeros((B, H, H, c1)), np.zeros((B, n_out))]
    out = np.zeros((T, B, n_out))
    for t in range(T):
        x = spikes[t].astype(float)
        xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        acc = np.zeros((B, H, H, c1))
        for dy in range(3):
            for dx in range(3):
                acc += xp[:, dy:dy + H, dx:dx + H, :] @ w_conv[dy, dx]
        u[0] = beta * u[0] + acc + biases[0] - threshold * s[0]
        s[0] = (u[0] >= threshold).astype(float)
        pooled = s[0].reshape(B, H // 2, 2, H // 2, 2, c1).max((2, 4))
        u[1] = beta * u[1] + pooled.reshape(B, -1) @ w_fc + biases[1] \
            - threshold * s[1]
        s[1] = (u[1] >= threshold).astype(float)
        out[t] = s[1]
    return out


class TestFixedPointConv:
    """The conv/pool extension of the fixed-point reference — the datapath
    behind the ``weight_bits`` axis of conv cells (DESIGN.md §13)."""

    def _spikes(self, rng, T=8, B=4, H=8, C=2, density=0.3):
        return (rng.random((T, B, H, H, C)) < density).astype(np.int64)

    def test_high_bits_matches_float(self):
        """At Q12 the quantized conv/pool forward agrees with the float
        simulation on nearly every output spike."""
        rng = np.random.default_rng(7)
        weights, biases, specs = _random_conv_net(rng)
        spikes = self._spikes(rng)
        net = validate.quantize(weights, biases, beta=0.9, threshold=1.0,
                                frac_bits=12, specs=specs)
        fixed = validate.reference_apply_batch(net, spikes)
        flt = _float_conv_sim(weights, biases, spikes)
        assert (flt == fixed).mean() > 0.95

    def test_degrades_monotonically_ish_at_low_bits(self):
        """Coarser grids agree less with the float net; the trend only has
        to be monotonic-ish (thresholding can mask small grid changes)."""
        rng = np.random.default_rng(3)
        weights, biases, specs = _random_conv_net(rng)
        spikes = self._spikes(rng, T=10)
        flt = _float_conv_sim(weights, biases, spikes)
        agree = {}
        for frac in (1, 6, 12):
            net = validate.quantize(weights, biases, beta=0.9,
                                    threshold=1.0, frac_bits=frac,
                                    specs=specs)
            agree[frac] = (validate.reference_apply_batch(net, spikes)
                           == flt).mean()
        assert agree[12] > 0.9
        assert agree[12] >= agree[6] >= agree[1] - 0.05
        assert agree[1] < agree[12]

    def test_pool_is_or_on_spikes(self):
        x = np.zeros((1, 4, 4, 1), np.int64)
        x[0, 0, 0, 0] = 1                     # one spike per 2x2 window -> 1
        x[0, 3, 3, 0] = 1
        got = validate._or_pool_int(x, 2)
        want = np.zeros((1, 2, 2, 1), np.int64)
        want[0, 0, 0, 0] = 1
        want[0, 1, 1, 0] = 1
        np.testing.assert_array_equal(got, want)

    def test_pool_truncates_ragged_edges(self):
        """Odd spatial sizes truncate like snn._or_pool's VALID window."""
        x = np.ones((1, 5, 5, 1), np.int64)
        assert validate._or_pool_int(x, 2).shape == (1, 2, 2, 1)

    def test_dense_specs_equal_legacy_mlp_path(self):
        """An all-dense specs list is bit-identical to the original specs
        =None MLP forward (the generalized loop is a strict superset)."""
        rng = np.random.default_rng(5)
        net = _random_net(rng, (24, 16, 8))
        spikes = (rng.random((6, 4, 24)) < 0.3).astype(np.int64)
        legacy = validate.reference_apply_batch(net, spikes)
        import dataclasses
        net_specs = dataclasses.replace(net, specs=[("dense",), ("dense",)])
        np.testing.assert_array_equal(
            legacy, validate.reference_apply_batch(net_specs, spikes))

    def test_quantized_accuracy_covers_dvs_conv_topology(self):
        """quantized_accuracy no longer raises (or silently skips) on the
        dvs-conv topology: random params, event spikes, valid accuracy."""
        import jax
        from repro.core import snn, workloads
        wl = workloads.get("dvs-conv")
        cfg = wl.build(4, 1.0)
        params = snn.init_params(jax.random.key(0), cfg)
        weights = [np.asarray(p["w"]) for p in params if p]
        biases = [np.asarray(p["b"]) for p in params if p]
        specs = validate.layer_specs(cfg.layers)
        rng = np.random.default_rng(0)
        spikes = (rng.random((4, 8) + cfg.input_shape) < 0.2).astype(np.int64)
        labels = rng.integers(0, cfg.num_classes, 8)
        acc = validate.quantized_accuracy(
            weights, biases, spikes, labels, num_classes=cfg.num_classes,
            frac_bits=7, specs=specs)
        assert 0.0 <= acc <= 1.0

    def test_layer_specs_duck_typing(self):
        from repro.core import snn
        specs = validate.layer_specs(
            (snn.Conv(4, 3, stride=2, padding="VALID"), snn.MaxPool(2),
             snn.Dense(8)))
        assert specs == [("conv", 2, "VALID"), ("pool", 2), ("dense",)]

    def test_serial_paths_reject_conv_nets(self):
        """HardwareModel / reference_apply model the fc datapath only and
        must refuse conv specs loudly instead of mis-shaping."""
        rng = np.random.default_rng(1)
        weights, biases, specs = _random_conv_net(rng)
        net = validate.quantize(weights, biases, beta=0.9, threshold=1.0,
                                specs=specs)
        with pytest.raises(ValueError, match="fc"):
            validate.HardwareModel(net)
        with pytest.raises(ValueError, match="fc"):
            validate.reference_apply(net, np.zeros((2, 24), np.int64))
