"""Tests for the workload registry and the content-addressed trace/accuracy
cache: population scaling, cache key sensitivity, train-or-load roundtrip,
hit/miss accounting, and the lazily extended quantized-accuracy table."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import snn, workloads


def _tiny(**kw):
    base = dict(
        name="tiny-wl", dataset="mnist", input_shape=(28, 28),
        layers=(snn.Dense(10),), num_classes=10, pcr=1,
        n_train=128, n_test=64, train_steps=4, trace_samples=16)
    base.update(kw)
    return workloads.Workload(**base)


class TestRegistry:
    def test_builtins_registered(self):
        assert {"mnist-mlp", "fmnist-mlp", "dvs-conv"} <= set(
            workloads.names())

    def test_get_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown workload"):
            workloads.get("no-such-workload")

    def test_duplicate_register_rejected(self):
        wl = workloads.get("mnist-mlp")
        with pytest.raises(ValueError, match="already registered"):
            workloads.register(wl)

    def test_dataset_encoding_validation(self):
        with pytest.raises(ValueError, match="requires 'event'"):
            _tiny(dataset="dvs", input_shape=(32, 32, 2))
        with pytest.raises(ValueError, match="unknown dataset"):
            _tiny(dataset="cifar")


class TestBuild:
    def test_population_scales_widths(self):
        wl = _tiny(layers=(snn.Dense(64), snn.Dense(32)))
        cfg = wl.build(8, 0.5)
        assert cfg.num_steps == 8
        assert [l.features for l in cfg.layers] == [32, 16, 10]
        cfg2 = wl.build(8, 2.0)
        assert [l.features for l in cfg2.layers] == [128, 64, 10]

    def test_classifier_never_scaled_and_floor_of_one(self):
        wl = _tiny(layers=(snn.Dense(4),), pcr=3)
        cfg = wl.build(2, 0.01)
        assert cfg.layers[0].features == 1          # floor, not zero
        assert cfg.layers[-1].features == 10 * 3    # classifier untouched

    def test_pool_layers_pass_through(self):
        wl = workloads.get("dvs-conv")
        cfg = wl.build(8, 2.0)
        kinds = [type(l).__name__ for l in cfg.layers]
        assert kinds == ["Conv", "MaxPool", "Conv", "MaxPool", "Dense",
                         "Dense"]
        assert cfg.layers[0].features == 16         # 8 * 2.0

    def test_event_data_generated_at_cell_T(self):
        wl = workloads.get("dvs-conv")
        wl = dataclasses.replace(wl, name="dvs-tiny", n_train=8, n_test=4)
        data = wl.make_data(num_steps=5)
        assert data.x_train.shape[1] == 5           # (N, T, H, W, 2)


class TestCacheKey:
    def test_stable_and_assignment_sensitive(self):
        wl = _tiny()
        a = {"num_steps": 4, "population": 1.0}
        assert workloads.cell_key(wl, a, 0) == workloads.cell_key(wl, a, 0)
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(wl, a, 1)
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(
            wl, {"num_steps": 8, "population": 1.0}, 0)
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(
            wl, {"num_steps": 4, "population": 2.0}, 0)

    def test_recipe_and_version_sensitive(self):
        wl = _tiny()
        a = {"num_steps": 4, "population": 1.0}
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(
            dataclasses.replace(wl, train_steps=5), a, 0)
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(
            dataclasses.replace(wl, version=2), a, 0)
        assert workloads.cell_key(wl, a, 0) != workloads.cell_key(
            dataclasses.replace(wl, layers=(snn.Dense(11),)), a, 0)


class TestTraceCache:
    def test_train_once_then_hit(self, tmp_path):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0}, seed=0)
        assert not a.cache_hit
        assert cache.stats == {"hits": 0, "misses": 1}
        assert len(a.counts) == 2                   # hidden + classifier in
        assert a.counts[0].shape == (2, 16)         # (T, trace_samples)
        b = cache.resolve(wl, {"num_steps": 2, "population": 1.0}, seed=0)
        assert b.cache_hit
        assert cache.stats == {"hits": 1, "misses": 1}
        # the loaded artifact is byte-identical to the trained one
        assert b.accuracy == a.accuracy
        for ca, cb in zip(a.counts, b.counts):
            np.testing.assert_array_equal(ca, cb)
        for pa, pb in zip(a.params, b.params):
            np.testing.assert_array_equal(pa["w"], pb["w"])
            np.testing.assert_array_equal(pa["b"], pb["b"])

    def test_distinct_cells_distinct_artifacts(self, tmp_path):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0})
        b = cache.resolve(wl, {"num_steps": 2, "population": 0.5})
        assert a.key != b.key
        assert a.snn_cfg.layers[0].features != b.snn_cfg.layers[0].features
        assert cache.stats == {"hits": 0, "misses": 2}

    def test_quant_accuracy_lazily_extended_and_cached(self, tmp_path):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          quant_bits=(8,))
        assert set(a.quant_acc) == {8}
        assert 0.0 <= a.quant_acc[8] <= 1.0
        # second resolve: hit, and the table extends without retraining
        b = cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          quant_bits=(4, 8))
        assert b.cache_hit and set(b.quant_acc) == {4, 8}
        assert b.quant_acc[8] == a.quant_acc[8]
        # third: fully cached, no recompute path needed
        c = cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          quant_bits=(4, 8))
        assert c.quant_acc == b.quant_acc

    def test_quant_bits_measured_for_conv_net(self, tmp_path):
        """Conv topologies get a real fixed-point leg now (the conv
        reference in ``validate``), not a float-accuracy fallback."""
        wl = dataclasses.replace(
            workloads.get("dvs-conv"), name="dvs-cache-test",
            layers=(snn.Conv(2, 3), snn.MaxPool(2), snn.Dense(8)),
            n_train=32, n_test=16, train_steps=2, batch_size=16,
            trace_samples=8)
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 3, "population": 1.0},
                          quant_bits=(8,))
        assert set(a.quant_acc) == {8}
        assert 0.0 <= a.quant_acc[8] <= 1.0
        assert a.accuracy_at(8) == a.quant_acc[8]

    def test_quant_bits_measured_for_event_mlp(self, tmp_path):
        """Dense-only event workloads feed the pre-encoded (N, T, H, W, 2)
        test set straight into the fixed-point datapath (flattened per
        step) — measured, not skipped."""
        wl = workloads.Workload(
            name="dvs-mlp-cache-test", dataset="dvs", encoding="event",
            input_shape=(8, 8, 2), layers=(snn.Dense(6),), num_classes=4,
            n_train=32, n_test=16, train_steps=2, batch_size=16,
            trace_samples=8)
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 3, "population": 1.0},
                          quant_bits=(8,))
        assert set(a.quant_acc) == {8}
        assert a.accuracy_at(8) == a.quant_acc[8]

    def test_accuracy_at_prefers_quantized(self, tmp_path):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          quant_bits=(8,))
        assert a.accuracy_at(8) == a.quant_acc[8]
        assert a.accuracy_at(None) == a.accuracy
        assert a.accuracy_at(16) == a.accuracy      # unmeasured bits: float


class TestCacheFaults:
    """Corrupt-meta quarantine and budget charge/refund discipline — the
    failure paths the fleet leans on (a torn ``meta.msgpack`` on a network
    store must read as *missing*, and a failed training run must hand its
    pre-charged budget unit back)."""

    def _corrupt(self, cache, key, payload):
        path = os.path.join(cache.root, key, "meta.msgpack")
        with open(path, "wb") as f:
            f.write(payload)
        return path

    def test_torn_meta_quarantined_and_retrained(self, tmp_path):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0})
        path = self._corrupt(cache, a.key, b"\xc1 torn write \xff")
        fresh = workloads.TraceCache(root=str(tmp_path))
        assert not fresh.contains_key(a.key)        # unreadable == missing
        b = fresh.resolve(wl, {"num_steps": 2, "population": 1.0})
        assert not b.cache_hit                      # retrained, not crashed
        assert fresh.stats == {"hits": 0, "misses": 1}
        assert os.path.exists(path + ".corrupt")    # bad bytes kept aside
        assert b.accuracy == a.accuracy             # deterministic retrain
        c = fresh.resolve(wl, {"num_steps": 2, "population": 1.0})
        assert c.cache_hit                          # republish healed it

    def test_meta_missing_required_fields_is_missing(self, tmp_path):
        import msgpack
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0})
        # valid msgpack, wrong shape: a dict without accuracy/quant_acc
        self._corrupt(cache, a.key, msgpack.packb({"workload": wl.name}))
        fresh = workloads.TraceCache(root=str(tmp_path))
        assert not fresh.contains_key(a.key)

    def test_budget_refunded_when_training_fails(self, tmp_path,
                                                 monkeypatch):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        budget = workloads.TrainingBudget(1)

        def boom(*a, **kw):
            raise RuntimeError("injected training failure")

        monkeypatch.setattr(cache, "_train", boom)
        with pytest.raises(RuntimeError, match="injected"):
            cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          budget=budget)
        assert budget.spent == 0                    # charge handed back
        monkeypatch.undo()
        # the un-leaked unit still buys the real training run
        a = cache.resolve(wl, {"num_steps": 2, "population": 1.0},
                          budget=budget)
        assert not a.cache_hit and budget.spent == 1

    def test_budget_refunded_when_publish_fails(self, tmp_path,
                                                monkeypatch):
        wl = _tiny()
        cache = workloads.TraceCache(root=str(tmp_path))
        trained = cache.resolve(wl, {"num_steps": 2, "population": 1.0})
        budget = workloads.TrainingBudget(1)
        other = workloads.TraceCache(root=str(tmp_path / "other"))

        def boom(*a, **kw):
            raise OSError("injected publish failure")

        monkeypatch.setattr(other, "_write_cell", boom)
        with pytest.raises(OSError, match="injected"):
            other.publish(wl, {"num_steps": 2, "population": 1.0},
                          params=trained.params, counts=trained.counts,
                          accuracy=trained.accuracy, budget=budget)
        assert budget.spent == 0

    def test_refund_clamped_at_zero(self):
        budget = workloads.TrainingBudget(5)
        budget.refund(3)                            # nothing charged yet
        assert budget.spent == 0 and budget.remaining == 5
        budget.charge(2)
        budget.refund(10)                           # over-refund: clamp
        assert budget.spent == 0 and budget.remaining == 5
