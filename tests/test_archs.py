"""Per-architecture smoke tests on REDUCED configs of the same family:
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill->decode consistency against the teacher-forced forward (which
exercises every cache path: GQA KV, rolling SWA buffers, SSD states,
hybrid shared-attn caches, enc-dec cross caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.models import registry, ssm

# ---------------------------------------------------------------------------
# Reduced configs (same family wiring, tiny dims)
# ---------------------------------------------------------------------------

REDUCED = {
    "llama3_2_3b": ArchConfig(
        name="llama-r", family="transformer", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32,
        rope="1d", rope_theta=500000.0, dtype="float32"),
    "granite_3_2b": ArchConfig(
        name="granite-r", family="transformer", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32,
        tie_embeddings=True, dtype="float32"),
    "tinyllama_1_1b": ArchConfig(
        name="tinyllama-r", family="transformer", num_layers=2, d_model=128,
        n_heads=4, n_kv=1, d_ff=192, vocab=512, head_dim=32, dtype="float32"),
    "chatglm3_6b": ArchConfig(
        name="chatglm-r", family="transformer", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32, rope="2d",
        dtype="float32"),
    "mixtral_8x7b": ArchConfig(
        name="mixtral-r", family="moe", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32, window=16,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
        dtype="float32"),
    "arctic_480b": ArchConfig(
        name="arctic-r", family="moe", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=128, vocab=512, head_dim=32,
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=2.0,
                      dense_residual=True, dense_d_ff=128),
        dtype="float32"),
    "qwen2_vl_72b": ArchConfig(
        name="qwen2vl-r", family="transformer", num_layers=2, d_model=128,
        n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32, rope="mrope",
        mrope_sections=(4, 6, 6), frontend="vision", dtype="float32"),
    "seamless_m4t_large_v2": ArchConfig(
        name="seamless-r", family="encdec", num_layers=2, encoder_layers=2,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512, head_dim=32,
        frontend="audio", dtype="float32"),
    "mamba2_780m": ArchConfig(
        name="mamba2-r", family="ssm", num_layers=2, d_model=64, n_heads=8,
        n_kv=0, d_ff=0, vocab=512, head_dim=16, rope="none",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=4),
        dtype="float32"),
    "zamba2_2_7b": ArchConfig(
        name="zamba2-r", family="hybrid", num_layers=4, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=512, head_dim=16,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=4),
        shared_attn_every=2, dtype="float32"),
}

B, S = 2, 8


def _batch(cfg: ArchConfig, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, 2, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", list(REDUCED))
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch_id):
        cfg = REDUCED[arch_id]
        params = registry.init_params(jax.random.key(0), cfg)
        batch = _batch(cfg)
        logits, aux = registry.forward(params, cfg, batch)
        assert logits.shape == (B, S, cfg.vocab_padded)
        assert not bool(jnp.isnan(logits).any())
        assert np.isfinite(float(aux))

    def test_train_grad_finite(self, arch_id):
        cfg = REDUCED[arch_id]
        params = registry.init_params(jax.random.key(0), cfg)
        batch = _batch(cfg)

        def loss_fn(p):
            logits, aux = registry.forward(p, cfg, batch, remat=True)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, batch["labels"][..., None], -1)
            return -ll.mean() + 0.01 * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat)
        assert sum(float(jnp.abs(g).sum()) for g in flat) > 0

    def test_prefill_decode_matches_forward(self, arch_id):
        """decode(t) after prefill(<t) must equal the teacher-forced
        forward at position t (tolerance: fp32 matmul reassociation)."""
        cfg = REDUCED[arch_id]
        if cfg.moe is not None:
            pytest.skip("MoE capacity-dropping differs between the grouped "
                        "train path and serving path by design")
        params = registry.init_params(jax.random.key(0), cfg)
        batch = _batch(cfg)
        ref_logits, _ = registry.forward(params, cfg, batch)

        t = S - 1
        pre_batch = {k: (v[:, :t] if k in ("tokens",) else v)
                     for k, v in batch.items() if k != "labels"}
        logits_pre, cache = registry.prefill(params, cfg, pre_batch, max_len=S)
        np.testing.assert_allclose(
            np.asarray(logits_pre[:, 0]), np.asarray(ref_logits[:, t - 1]),
            atol=2e-3, rtol=2e-3)
        logits_dec, cache = registry.decode_step(
            params, cfg, batch["tokens"][:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0]), np.asarray(ref_logits[:, t]),
            atol=2e-3, rtol=2e-3)

    def test_decode_steps_advance(self, arch_id):
        cfg = REDUCED[arch_id]
        params = registry.init_params(jax.random.key(0), cfg)
        batch = _batch(cfg)
        pre_batch = {k: (v[:, :4] if k == "tokens" else v)
                     for k, v in batch.items() if k != "labels"}
        logits, cache = registry.prefill(params, cfg, pre_batch, max_len=S)
        for i in range(3):
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            logits, cache = registry.decode_step(params, cfg, tok, cache)
            assert not bool(jnp.isnan(logits).any())
        assert int(cache["length"]) == 7


class TestSSDCorrectness:
    """The chunked SSD algorithm against a naive per-step recurrence."""

    @pytest.mark.parametrize("seed", range(3))
    @pytest.mark.parametrize("chunk", [1, 2, 4, 8])
    def test_chunked_equals_naive(self, seed, chunk):
        rng = np.random.default_rng(seed)
        Bs, T, H, P, N = 2, 8, 3, 4, 5
        x = jnp.asarray(rng.standard_normal((Bs, T, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.1, 0.9, (Bs, T, H)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 1.0, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((Bs, T, N)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((Bs, T, N)), jnp.float32)

        y_chunk, h_chunk = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)

        # naive recurrence
        h = np.zeros((Bs, H, N, P))
        ys = np.zeros((Bs, T, H, P))
        for t in range(T):
            a = np.exp(np.asarray(A)[None, :] * np.asarray(dt)[:, t])  # (B,H)
            upd = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt)[:, t],
                            np.asarray(Bm)[:, t], np.asarray(x)[:, t])
            h = h * a[:, :, None, None] + upd
            ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(Cm)[:, t], h)
        np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=1e-4,
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(h_chunk), h, atol=1e-4,
                                   rtol=1e-4)

    def test_state_carry_across_calls(self):
        """ssd(x, h0) over two halves == ssd over the whole sequence."""
        rng = np.random.default_rng(0)
        Bs, T, H, P, N = 1, 8, 2, 4, 3
        x = jnp.asarray(rng.standard_normal((Bs, T, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.1, 0.9, (Bs, T, H)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 1.0, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((Bs, T, N)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((Bs, T, N)), jnp.float32)
        y_full, h_full = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
        y1, h1 = ssm.ssd_chunked(x[:, :4], dt[:, :4], A, Bm[:, :4],
                                 Cm[:, :4], chunk=4)
        y2, h2 = ssm.ssd_chunked(x[:, 4:], dt[:, 4:], A, Bm[:, 4:],
                                 Cm[:, 4:], chunk=4, h0=h1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=1e-5)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                                   atol=1e-5)


class TestSlidingWindow:
    def test_swa_matches_masked_full_attention(self):
        """Rolling-buffer decode == full forward with window mask."""
        cfg = dataclasses.replace(REDUCED["mixtral_8x7b"], moe=None,
                                  family="transformer", d_ff=64, window=4)
        params = registry.init_params(jax.random.key(1), cfg)
        batch = _batch(cfg, seed=5)
        ref_logits, _ = registry.forward(params, cfg, batch)
        pre = {"tokens": batch["tokens"][:, :S - 1]}
        _, cache = registry.prefill(params, cfg, pre, max_len=S)
        assert cache["k"].shape[2] == 4          # rolling buffer == window
        logits, _ = registry.decode_step(params, cfg,
                                         batch["tokens"][:, S - 1:], cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref_logits[:, S - 1]),
                                   atol=2e-3, rtol=2e-3)
