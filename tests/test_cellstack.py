"""Stacked-cell training tests (repro.distributed.cellstack).

The load-bearing property is the BIT-EXACTNESS CONTRACT: a cell trained
inside a ``jit(vmap(train_step))`` stack must publish the identical
artifact — params, spike traces, accuracy — a solo ``TraceCache.resolve``
would have trained, so stacking is invisible to every cache consumer.
Parity runs over both matmul backends and both datapaths (rate-encoded
MLP, event-driven conv).  Mesh-sharded stacks run in a subprocess with
forced host devices, same idiom as tests/test_distributed.py.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import dse, snn, workloads
from repro.core.accelerator import arch
from repro.core.lif import LIFParams
from repro.core.workloads.cache import cell_key
from repro.distributed import cellfarm, cellstack

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp(name="stack-mlp", **kw):
    base = dict(name=name, layers=(snn.Dense(12),), pcr=1,
                input_shape=(12, 12), n_train=96, n_test=32,
                train_steps=4, batch_size=32, trace_samples=16)
    base.update(kw)
    return dataclasses.replace(workloads.get("mnist-mlp"), **base)


def _conv(name="stack-conv", **kw):
    base = dict(name=name, layers=(snn.Conv(2, 3), snn.MaxPool(2),
                                   snn.Dense(6)),
                input_shape=(8, 8, 2), num_classes=4, pcr=1,
                n_train=64, n_test=16, train_steps=3, batch_size=16,
                trace_samples=8)
    base.update(kw)
    return dataclasses.replace(workloads.get("dvs-conv"), **base)


def _job(wl, T=2, pop=1.0, seed=0):
    return cellfarm.CellJob(workload=wl,
                            assignment={"num_steps": T, "population": pop},
                            seed=seed)


class TestStackSignature:
    def test_seed_and_shard_degrees_of_freedom_share_a_signature(self):
        """Seeds, data_seed, noise, n_train and the workload NAME are
        host-side per-cell knobs — they must not split a stack (this is
        what lets mnist-mlp and fmnist-mlp cells train together)."""
        wl = _mlp()
        variant = dataclasses.replace(wl, name="stack-mlp-b", data_seed=17,
                                      noise=0.35, n_train=64)
        sigs = {cellstack.stack_signature(_job(wl, seed=0)),
                cellstack.stack_signature(_job(wl, seed=3)),
                cellstack.stack_signature(_job(variant, seed=0))}
        assert len(sigs) == 1

    def test_compiled_shape_changes_split_the_group(self):
        wl = _mlp()
        base = cellstack.stack_signature(_job(wl, T=2))
        assert cellstack.stack_signature(_job(wl, T=3)) != base
        assert cellstack.stack_signature(_job(wl, pop=0.5)) != base
        wider = dataclasses.replace(wl, layers=(snn.Dense(16),))
        assert cellstack.stack_signature(_job(wider)) != base

    def test_recipe_and_numerics_split_the_group(self):
        wl = _mlp()
        base = cellstack.stack_signature(_job(wl))
        for variant in (
                dataclasses.replace(wl, train_steps=5),
                dataclasses.replace(wl, lr=1e-3),
                dataclasses.replace(wl, batch_size=16),
                dataclasses.replace(wl, n_test=16),
                dataclasses.replace(wl, trace_samples=8),
                dataclasses.replace(wl, matmul_backend="spike_gemm"),
                dataclasses.replace(wl, layers=(
                    snn.Dense(12, lif=LIFParams(beta=0.8)),))):
            assert cellstack.stack_signature(_job(variant)) != base

    def test_group_jobs_orders_and_partitions(self):
        wl = _mlp()
        jobs = [_job(wl, T=2, seed=0), _job(wl, T=3, seed=0),
                _job(wl, T=2, seed=1), _job(wl, T=3, seed=1)]
        groups = cellstack.group_jobs(jobs)
        assert sorted(sum(groups.values(), [])) == [0, 1, 2, 3]
        assert sorted(map(sorted, groups.values())) == [[0, 2], [1, 3]]


class TestStackedSoloParity:
    @pytest.mark.parametrize("backend", ["jnp", "spike_gemm"])
    @pytest.mark.parametrize("make_wl", [_mlp, _conv],
                             ids=["mlp", "dvs-conv"])
    def test_stacked_equals_solo_bit_for_bit(self, tmp_path, make_wl,
                                             backend):
        """The contract itself: stack-train a 2-cell group, then train the
        same recipes solo into a fresh cache — params, per-layer trace
        counts and accuracy must be IDENTICAL (assert_array_equal, not
        allclose), and the stacked cache must serve the solo recipe as a
        hit."""
        wl = dataclasses.replace(make_wl(), matmul_backend=backend)
        T = 3 if make_wl is _conv else 2
        jobs = [_job(wl, T=T, seed=s) for s in (0, 1)]

        stack_cache = workloads.TraceCache(root=str(tmp_path / "stack"))
        stats = {}
        outcomes = cellstack.resolve_stacked(jobs, stack_cache.root,
                                             cache=stack_cache, stats=stats)
        assert [o.trained for o in outcomes] == [True, True]
        assert stats["cells"] == 2 and stats["compile_seconds"] > 0

        solo_cache = workloads.TraceCache(root=str(tmp_path / "solo"))
        for job in jobs:
            solo = solo_cache.resolve(job.workload, job.assignment,
                                      seed=job.seed)
            assert not solo.cache_hit                 # actually trained solo
            stacked = stack_cache.resolve(job.workload, job.assignment,
                                          seed=job.seed)
            assert stacked.cache_hit                  # published == solo key
            for a, b in zip(jax.tree.leaves(solo.params),
                            jax.tree.leaves(stacked.params)):
                np.testing.assert_array_equal(a, b)
            assert len(solo.counts) == len(stacked.counts)
            for a, b in zip(solo.counts, stacked.counts):
                np.testing.assert_array_equal(a, b)
            assert solo.accuracy == stacked.accuracy


class TestResolveStacked:
    def test_cached_cells_resolve_without_training(self, tmp_path):
        wl = _mlp()
        cache = workloads.TraceCache(root=str(tmp_path))
        pre = _job(wl, seed=0)
        cache.resolve(pre.workload, pre.assignment, seed=pre.seed)
        stats = {}
        outcomes = cellstack.resolve_stacked(
            [pre, _job(wl, seed=1)], cache.root, cache=cache, stats=stats)
        assert [o.trained for o in outcomes] == [False, True]
        assert stats["cells"] == 1                    # only the miss trained
        assert outcomes[0].key == cell_key(wl, pre.assignment, 0)

    def test_max_stack_slabs_one_large_group(self, tmp_path):
        """A group bigger than max_stack trains in slabs; artifacts stay
        bit-identical to the unslabbed stack (slab membership must never
        leak into a cell)."""
        wl = _mlp()
        jobs = [_job(wl, seed=s) for s in range(3)]
        a = workloads.TraceCache(root=str(tmp_path / "a"))
        b = workloads.TraceCache(root=str(tmp_path / "b"))
        out_a = cellstack.resolve_stacked(jobs, a.root, cache=a, max_stack=2)
        out_b = cellstack.resolve_stacked(jobs, b.root, cache=b)
        assert all(o.trained for o in out_a + out_b)
        for job in jobs:
            slabbed = a.resolve(job.workload, job.assignment, seed=job.seed)
            whole = b.resolve(job.workload, job.assignment, seed=job.seed)
            for x, y in zip(jax.tree.leaves(slabbed.params),
                            jax.tree.leaves(whole.params)):
                np.testing.assert_array_equal(x, y)
            assert slabbed.accuracy == whole.accuracy

    def test_mixed_signatures_resolve_in_job_order(self, tmp_path):
        wl = _mlp()
        jobs = [_job(wl, T=3, seed=0), _job(wl, T=2, seed=0),
                _job(wl, T=2, seed=1)]
        cache = workloads.TraceCache(root=str(tmp_path))
        outcomes = cellstack.resolve_stacked(jobs, cache.root, cache=cache)
        assert all(o.trained for o in outcomes)
        assert [o.key for o in outcomes] == [
            cell_key(j.workload, j.assignment, j.seed) for j in jobs]


class TestResolveCellsStack:
    def test_stack_true_without_workers_never_spawns(self, tmp_path,
                                                     monkeypatch):
        """workers=0 + stack=True: everything (including the mixed-signature
        singleton) trains in-process as C>=1 stacks — the pool must not
        even be constructed."""
        def boom(_):
            raise AssertionError("pool constructed in stack-only mode")
        monkeypatch.setattr(cellfarm, "_get_pool", boom)
        wl = _mlp()
        jobs = [_job(wl, T=2, seed=0), _job(wl, T=2, seed=1),
                _job(wl, T=3, seed=0)]
        outcomes = cellfarm.resolve_cells(jobs, str(tmp_path), workers=0,
                                          stack=True)
        assert all(o.trained for o in outcomes)
        assert [o.key for o in outcomes] == [
            cell_key(j.workload, j.assignment, j.seed) for j in jobs]
        cache = workloads.TraceCache(root=str(tmp_path))
        for job in jobs:
            assert cache.contains(job.workload, job.assignment,
                                  seed=job.seed)

    def test_stack_true_with_pool_farms_only_singletons(self, tmp_path,
                                                        monkeypatch):
        """With a usable pool only >=2-cell groups stack; the lone leftover
        job short-circuits to a serial in-process resolve (1 job never
        justifies a spawn), so no pool is built here either."""
        def boom(_):
            raise AssertionError("1 leftover job must not build a pool")
        monkeypatch.setattr(cellfarm, "_get_pool", boom)
        monkeypatch.setattr(cellfarm.multiprocessing, "cpu_count",
                            lambda: 4)            # a real pool is available
        wl = _mlp()
        jobs = [_job(wl, T=2, seed=0), _job(wl, T=3, seed=0),
                _job(wl, T=2, seed=1)]
        outcomes = cellfarm.resolve_cells(jobs, str(tmp_path), workers=2,
                                          stack=True)
        assert all(o.trained for o in outcomes)
        assert [o.key for o in outcomes] == [
            cell_key(j.workload, j.assignment, j.seed) for j in jobs]

    def test_worker_count_caps(self, monkeypatch):
        monkeypatch.setattr(cellfarm.multiprocessing, "cpu_count",
                            lambda: 16)
        monkeypatch.setattr(cellfarm, "MAX_POOL_WORKERS", 2)
        assert cellfarm._worker_count(10, None) == 2      # module cap
        assert cellfarm._worker_count(10, 1) == 1         # explicit request
        assert cellfarm._worker_count(1, 8) == 1          # never > jobs
        monkeypatch.setattr(cellfarm, "MAX_POOL_WORKERS", 64)
        monkeypatch.setattr(cellfarm.multiprocessing, "cpu_count",
                            lambda: 3)
        assert cellfarm._worker_count(10, None) == 3      # cpu cap

    def test_pool_reuse_and_idempotent_shutdown(self):
        cellfarm.shutdown_pool()
        p = cellfarm._get_pool(2)
        assert cellfarm._get_pool(2) is p                 # reused, not rebuilt
        cellfarm.shutdown_pool()
        assert cellfarm._pool is None
        cellfarm.shutdown_pool()                          # idempotent


class TestStudyStack:
    def test_coexplore_stack_matches_serial(self, tmp_path):
        """The front-end acceptance path: a datasets axis of two same-shape
        workload variants under stack=True yields the exact serial frontier
        (bit-exact training makes strict equality the right assertion) and
        charges the stacked cells as farmed misses — the parent cache only
        ever sees hits."""
        wl_a = _mlp(name="stack-co-a")
        wl_b = _mlp(name="stack-co-b", data_seed=17, noise=0.35)
        kw = dict(datasets=(wl_a, wl_b), num_steps=(2,), max_lhr=2)
        serial_cache = workloads.TraceCache(root=str(tmp_path / "a"))
        serial = dse.coexplore(cache=serial_cache, **kw)

        stack_cache = workloads.TraceCache(root=str(tmp_path / "b"))
        stacked = dse.coexplore(cache=stack_cache, stack=True, **kw)
        assert stacked.study.farmed_misses == 2
        assert stack_cache.misses == 0 and stack_cache.hits == 2

        def rows(t):
            cols = [np.asarray(t.columns[k], np.float64).reshape(len(t), -1)
                    for k in sorted(t.columns) if k != "dataset"]
            a = np.concatenate(cols, axis=1)
            return a[np.lexsort(a.T)]

        np.testing.assert_array_equal(rows(stacked.frontier),
                                      rows(serial.frontier))

    def test_hardware_only_explore_rejects_stack(self):
        cfg = arch.from_layer_sizes("hw", (16, 8), num_steps=2)
        space = dse.SearchSpace.product_lhr(cfg, max_lhr=2)
        counts = [np.full(2, 2.0)]
        with pytest.raises(ValueError, match="hardware-only"):
            dse.explore(space, counts=counts, stack=True)


class TestMeshStack:
    def test_single_device_mesh_is_none_and_specs_lead_with_cells(self):
        assert cellstack.stack_mesh(4) is None            # 1 CPU device here
        specs = cellstack.cell_specs({"w": np.zeros((2, 3)),
                                      "b": np.zeros(3)})
        assert all(s == cellstack.P("cells")
                   for s in jax.tree.leaves(
                       specs, is_leaf=lambda x: hasattr(x, "index")))

    def test_mesh_sharded_stack_matches_solo(self):
        """4 forced host devices, 4 cells: the stack shards over the
        ``"cells"`` mesh (asserted inside) and still publishes bit-exact
        artifacts — mesh partitioning of the vmapped program must not
        perturb a single cell."""
        code = """
        import dataclasses
        import numpy as np
        import jax
        from repro.core import snn, workloads
        from repro.distributed import cellfarm, cellstack

        wl = dataclasses.replace(
            workloads.get("mnist-mlp"), name="mesh-stack",
            layers=(snn.Dense(8),), pcr=1, input_shape=(12, 12),
            n_train=64, n_test=16, train_steps=2, batch_size=16,
            trace_samples=8)
        asn = {"num_steps": 2, "population": 1.0}
        jobs = [cellfarm.CellJob(workload=wl, assignment=asn, seed=s)
                for s in range(4)]
        assert len(jax.devices()) == 4
        assert cellstack.stack_mesh(4) is not None
        assert cellstack.stack_mesh(3) is None      # 3 cells don't divide

        import tempfile
        with tempfile.TemporaryDirectory() as root:
            cache = workloads.TraceCache(root=root + "/stack")
            out = cellstack.resolve_stacked(jobs, cache.root, cache=cache)
            assert all(o.trained for o in out)
            solo = workloads.TraceCache(root=root + "/solo")
            for job in jobs:
                a = solo.resolve(wl, asn, seed=job.seed)
                b = cache.resolve(wl, asn, seed=job.seed)
                assert b.cache_hit
                for x, y in zip(jax.tree.leaves(a.params),
                                jax.tree.leaves(b.params)):
                    np.testing.assert_array_equal(x, y)
                for x, y in zip(a.counts, b.counts):
                    np.testing.assert_array_equal(x, y)
                assert a.accuracy == b.accuracy
        print("MESH-STACK-OK")
        """
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   PYTHONPATH=os.path.join(REPO, "src"))
        res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=560)
        assert res.returncode == 0, \
            f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
        assert "MESH-STACK-OK" in res.stdout
