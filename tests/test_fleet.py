"""Tests for the elastic multi-host cell fleet: the lease protocol
(exclusive create, heartbeat renewal, stale break, ownership-checked
renew/release), the wire-format job spool, the ``FleetWorker``
claim/train/publish loop, fault injection (two claimants race one cell;
a worker SIGKILL'd mid-train whose lease goes stale and is reclaimed),
and end-to-end ``explore(workers="cluster")`` bit-identical equivalence
with serial exploration across spawned worker processes."""
import dataclasses
import json
import multiprocessing
import os
import signal
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core import dse, snn, workloads
from repro.distributed import cellfarm, fleet
from repro.serve import protocol


def _tiny_wl(name="fleet-test-wl"):
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name=name,
        layers=(snn.Dense(12),), pcr=1,
        n_train=128, n_test=64, train_steps=4, trace_samples=16)


def _jobs(wl, steps=(2,), pops=(1.0,)):
    return [cellfarm.CellJob(workload=wl,
                             assignment={"num_steps": t, "population": p})
            for t in steps for p in pops]


def _rows(table):
    """All columns flattened to sortable float rows (strings via crc32)."""
    cols = []
    for k in sorted(table.columns):
        v = np.asarray(table.columns[k])
        if v.dtype.kind in "USO":
            v = np.array([float(zlib.crc32(str(x).encode())) for x in v])
        cols.append(np.asarray(v, np.float64).reshape(len(table), -1))
    a = np.concatenate(cols, axis=1)
    return a[np.lexsort(a.T)]


def _backdate(path, by=3600.0):
    old = time.time() - by
    os.utime(path, (old, old))


class TestLease:
    def test_exclusive_acquire_and_release(self, tmp_path):
        root = str(tmp_path)
        a = fleet.acquire(root, "cell", "w-a", ttl=30)
        assert a is not None
        # a live lease blocks every other claimant
        assert fleet.acquire(root, "cell", "w-b", ttl=30) is None
        a.release()
        b = fleet.acquire(root, "cell", "w-b", ttl=30)
        assert b is not None and b.worker_id == "w-b"

    def test_renew_touches_heartbeat(self, tmp_path):
        lease = fleet.acquire(str(tmp_path), "cell", "w-a", ttl=30)
        _backdate(lease.path)
        stale = os.stat(lease.path).st_mtime
        assert lease.renew()
        assert os.stat(lease.path).st_mtime > stale
        assert not lease.lost

    def test_stale_lease_broken_and_reclaimed(self, tmp_path):
        root = str(tmp_path)
        dead = fleet.acquire(root, "cell", "w-dead", ttl=30)
        _backdate(dead.path)                 # heartbeat long past the TTL
        live = fleet.acquire(root, "cell", "w-live", ttl=30)
        assert live is not None and live.worker_id == "w-live"
        # the demoted holder notices on its next renewal and must not
        # touch (renew) or unlink (release) the new owner's lease
        assert not dead.renew()
        assert dead.lost
        dead.release()
        with open(live.path) as f:
            assert f.read() == "w-live"
        assert live.renew()

    def test_fresh_lease_not_breakable(self, tmp_path):
        root = str(tmp_path)
        fleet.acquire(root, "cell", "w-a", ttl=30)
        for _ in range(3):
            assert fleet.acquire(root, "cell", "w-b", ttl=30) is None

    def test_heartbeat_thread_keeps_lease_live(self, tmp_path):
        root = str(tmp_path)
        lease = fleet.acquire(root, "cell", "w-a", ttl=0.4)
        hb = fleet._Heartbeat(lease, ttl=0.4)
        hb.start()
        try:
            time.sleep(1.2)                  # 3x the TTL: would be stale
            assert fleet.acquire(root, "cell", "w-b", ttl=0.4) is None
        finally:
            hb.stop()


class TestWireFormat:
    def test_cell_job_round_trips_exactly(self):
        job = cellfarm.CellJob(
            workload=_tiny_wl(), seed=3, quant_bits=(4, 8),
            assignment={"num_steps": 2, "population": 0.5})
        wire = protocol.to_wire(job)
        assert wire["event"] == "CellJob"
        back = protocol.from_wire(json.loads(json.dumps(wire)))
        assert back == job                   # frozen dataclass equality

    def test_conv_pool_workload_round_trips(self):
        job = cellfarm.CellJob(workload=workloads.get("dvs-conv"),
                               assignment={"num_steps": 4})
        assert protocol.from_wire(
            json.loads(json.dumps(protocol.to_wire(job)))) == job

    def test_unknown_kind_lists_cell_job(self):
        with pytest.raises(ValueError, match="CellJob"):
            protocol.from_wire({"event": "NoSuchKind"})


class TestSpool:
    def test_spool_idempotent_and_clears_stale_error(self, tmp_path):
        root = str(tmp_path)
        jobs = _jobs(_tiny_wl(), steps=(2, 3))
        keys = fleet.spool(root, jobs)
        assert len(set(keys)) == 2
        fleet._write_error(root, keys[0], "old failure")
        assert fleet.spool(root, jobs) == keys      # re-spool: same keys
        assert fleet._read_error(root, keys[0]) is None
        for key in keys:
            assert fleet._read_job(fleet._spool_path(root, key)) == \
                jobs[keys.index(key)]

    def test_unreadable_job_skipped(self, tmp_path):
        root = str(tmp_path)
        key = fleet.spool(root, _jobs(_tiny_wl()))[0]
        path = fleet._spool_path(root, key)
        with open(path, "w") as f:
            f.write("{not json")
        assert fleet._read_job(path) is None
        assert fleet._read_job(path + ".gone") is None


class TestFleetWorker:
    def test_worker_claims_trains_publishes_drains(self, tmp_path):
        root = str(tmp_path)
        wl = _tiny_wl("fleet-worker-wl")
        key = fleet.spool(root, _jobs(wl))[0]
        worker = fleet.FleetWorker(root, worker_id="w-0", poll=0.01)
        stats = worker.run(max_cells=1)
        assert stats["cells_trained"] == 1 and stats["cells_failed"] == 0
        assert worker.cache.contains_key(key)
        assert not os.path.exists(fleet._spool_path(root, key))
        assert not os.path.exists(fleet._lease_path(root, key))

    def test_worker_drains_already_published(self, tmp_path):
        root = str(tmp_path)
        wl = _tiny_wl("fleet-drain-wl")
        jobs = _jobs(wl)
        cache = workloads.TraceCache(root=root)
        cache.resolve(jobs[0].workload, jobs[0].assignment)
        key = fleet.spool(root, jobs)[0]
        worker = fleet.FleetWorker(root, worker_id="w-0", poll=0.01)
        stats = worker.run(idle_timeout=0.2)
        assert stats == {"cells_trained": 0, "cells_failed": 0,
                         "cells_skipped": 0, "lease_takeovers": 0}
        assert not os.path.exists(fleet._spool_path(root, key))

    def test_worker_failure_writes_error_sidecar(self, tmp_path,
                                                 monkeypatch):
        root = str(tmp_path)
        key = fleet.spool(root, _jobs(_tiny_wl("fleet-fail-wl")))[0]
        worker = fleet.FleetWorker(root, worker_id="w-0", poll=0.01)

        def boom(*a, **kw):
            raise RuntimeError("injected training failure")

        monkeypatch.setattr(worker.cache, "resolve", boom)
        stats = worker.run(max_cells=1)
        assert stats["cells_failed"] == 1 and stats["cells_trained"] == 0
        assert "injected training failure" in fleet._read_error(root, key)
        assert not os.path.exists(fleet._spool_path(root, key))
        assert not os.path.exists(fleet._lease_path(root, key))

    def test_worker_counts_takeover_of_stale_lease(self, tmp_path):
        root = str(tmp_path)
        wl = _tiny_wl("fleet-takeover-wl")
        key = fleet.spool(root, _jobs(wl))[0]
        dead = fleet.acquire(root, key, "w-dead", ttl=30)
        _backdate(dead.path)                 # the dead worker's last beat
        worker = fleet.FleetWorker(root, worker_id="w-1", poll=0.01)
        stats = worker.run(max_cells=1)
        assert stats["lease_takeovers"] == 1
        assert stats["cells_trained"] == 1
        assert worker.cache.contains_key(key)

    def test_two_workers_race_one_cell_exactly_one_trains(self, tmp_path):
        root = str(tmp_path)
        wl = _tiny_wl("fleet-race-wl")
        key = fleet.spool(root, _jobs(wl))[0]
        workers = [fleet.FleetWorker(root, worker_id=f"w-{i}", poll=0.01)
                   for i in range(2)]
        threads = [threading.Thread(
            target=w.run, kwargs=dict(max_cells=1, idle_timeout=2.0))
            for w in workers]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
            assert not t.is_alive()
        trained = sum(w.stats["cells_trained"] for w in workers)
        failed = sum(w.stats["cells_failed"] for w in workers)
        assert trained == 1 and failed == 0  # O_EXCL picked one claimant
        assert workers[0].cache.contains_key(key)


class TestResolveCluster:
    def test_zero_workers_falls_back_in_process(self, tmp_path):
        root = str(tmp_path)
        jobs = _jobs(_tiny_wl("fleet-fallback-wl"), steps=(2, 3))
        out = fleet.resolve_cluster(jobs, root, timeout=0.3, ttl=0.5,
                                    poll=0.05)
        assert [o.error for o in out] == [None, None]
        assert all(o.trained for o in out)
        cache = workloads.TraceCache(root=root)
        assert all(cache.contains_key(o.key) for o in out)
        # resolving again: every cell is a pure hit, nothing re-spooled
        again = fleet.resolve_cluster(jobs, root, timeout=0.3, ttl=0.5)
        assert not any(o.trained for o in again)
        assert not any(os.path.exists(fleet._spool_path(root, o.key))
                       for o in again)

    def test_error_sidecar_ships_as_failed_outcome(self, tmp_path):
        root = str(tmp_path)
        jobs = _jobs(_tiny_wl("fleet-errship-wl"))
        key = cellfarm._job_key(jobs[0])
        # the sidecar must land mid-resolution: spooling (which
        # resolve_cluster does first) clears stale errors by design
        t = threading.Timer(0.3, fleet._write_error,
                            args=(root, key, "ValueError: worker exploded"))
        t.start()
        out = fleet.resolve_cluster(jobs, root, timeout=5.0, ttl=5.0,
                                    poll=0.05, fallback=False)
        t.join()
        assert out[0].error == "ValueError: worker exploded"
        assert not out[0].trained
        assert not os.path.exists(fleet._error_path(root, key))

    def test_no_progress_without_fallback_errors(self, tmp_path):
        root = str(tmp_path)
        jobs = _jobs(_tiny_wl("fleet-noprog-wl"))
        out = fleet.resolve_cluster(jobs, root, timeout=0.2, ttl=0.3,
                                    poll=0.05, fallback=False)
        assert "no progress" in out[0].error

    def test_dead_workers_stale_lease_reclaimed(self, tmp_path):
        """Every cell is leased by a worker that died without a trace
        (stale heartbeats, nothing published): the submitter must break
        the leases and complete the study with zero failed outcomes."""
        root = str(tmp_path)
        jobs = _jobs(_tiny_wl("fleet-deadlease-wl"), steps=(2, 3))
        keys = fleet.spool(root, jobs)
        for key in keys:
            lease = fleet.acquire(root, key, "w-dead", ttl=30)
            _backdate(lease.path)
        out = fleet.resolve_cluster(jobs, root, timeout=0.5, ttl=1.0,
                                    poll=0.05)
        assert [o.error for o in out] == [None, None]
        cache = workloads.TraceCache(root=root)
        assert all(cache.contains_key(k) for k in keys)


class TestFleetProcesses:
    """Fault injection and equivalence with real spawned worker processes
    (each pays a fresh interpreter + JAX import, so these are the slowest
    tests in the suite)."""

    def _spawn(self, root, worker_id, **kw):
        ctx = multiprocessing.get_context("spawn")   # JAX is not fork-safe
        p = ctx.Process(target=fleet.run_worker,
                        kwargs=dict(root=root, worker_id=worker_id, **kw))
        p.start()
        return p

    def test_worker_sigkilled_mid_train_study_completes(self, tmp_path,
                                                        monkeypatch):
        """ISSUE acceptance: kill -9 on a worker mid-study -> its lease
        goes stale, the cell is reclaimed, and the study completes with
        every cell resolved and zero failed outcomes."""
        root = str(tmp_path)
        wl = _tiny_wl("fleet-kill-wl")
        jobs = _jobs(wl, steps=(2, 3))
        keys = fleet.spool(root, jobs)
        proc = self._spawn(root, "w-victim", idle_timeout=300)
        try:
            deadline = time.time() + 240
            while time.time() < deadline:    # wait for the first claim
                if any(os.path.exists(fleet._lease_path(root, k))
                       for k in keys):
                    break
                time.sleep(0.05)
            else:
                pytest.fail("worker never claimed a cell")
            os.kill(proc.pid, signal.SIGKILL)
        finally:
            proc.join(timeout=30)
        # short TTL so the orphaned lease ages out fast
        monkeypatch.setenv("REPRO_FLEET_LEASE_TTL", "1.0")
        monkeypatch.setenv("REPRO_FLEET_TIMEOUT", "2.0")
        cache = workloads.TraceCache(root=root)
        study = dse.explore(workload=wl, num_steps=(2, 3),
                            population=(1.0,), max_lhr=4, weight_bits=(4,),
                            chunk_size=4096, cache=cache, workers="cluster")
        assert study.summary["cells_resolved"] == 2
        assert all(cache.contains_key(k) for k in keys)
        assert len(study.frontier) > 0

    def test_cluster_explore_bit_identical_to_serial(self, tmp_path):
        """ISSUE acceptance: ``explore(workers="cluster")`` with two live
        FleetWorker processes produces a frontier bit-identical to the
        serial run, and no cell is trained twice across the fleet."""
        wl = _tiny_wl("fleet-e2e-wl")
        kw = dict(workload=wl, num_steps=(2, 3), population=(0.5, 1.0),
                  max_lhr=4, weight_bits=(4, 8), chunk_size=4096)
        serial_root = os.path.join(str(tmp_path), "serial")
        serial = dse.explore(cache=workloads.TraceCache(root=serial_root),
                             **kw)
        fa = _rows(serial.frontier)

        root = os.path.join(str(tmp_path), "cluster")
        os.makedirs(root)
        stats_paths = [os.path.join(root, f"stats-{i}.json")
                       for i in range(2)]
        procs = [self._spawn(root, f"w-{i}", idle_timeout=15, stats_path=p)
                 for i, p in enumerate(stats_paths)]
        try:
            cache = workloads.TraceCache(root=root)
            study = dse.explore(cache=cache, workers="cluster", **kw)
        finally:
            for p in procs:
                p.join(timeout=240)
                assert not p.is_alive()
        fb = _rows(study.frontier)
        np.testing.assert_array_equal(fa, fb)       # bit-identical frontier

        stats = [json.load(open(p)) for p in stats_paths]
        trained = sum(s["cells_trained"] for s in stats)
        duplicated = sum(s["cells_skipped"] for s in stats)
        # the parent only ever loads published cells; the fleet trained
        # each of the 4 cells exactly once between the two workers
        assert cache.misses == 0
        assert trained == 4 and duplicated == 0
        assert sum(s["cells_failed"] for s in stats) == 0
        assert study.farmed_misses == 4             # budget unit: publishes
