"""HLO analyzer tests: loop-corrected FLOP/byte/collective accounting
validated against analytic counts on known programs."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis, hlo_parse

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestHLOParse:
    def test_single_matmul_flops(self):
        def f(a, b):
            return a @ b

        a = jnp.ones((64, 128))
        b = jnp.ones((128, 32))
        text = jax.jit(f).lower(a, b).compile().as_text()
        st = hlo_parse.analyze(text)
        assert st.flops == 2 * 64 * 128 * 32
        # bytes: at least read a + b, write out
        assert st.bytes_accessed >= (64 * 128 + 128 * 32 + 64 * 32) * 4

    def test_scan_multiplies_flops(self):
        def layer(x, w):
            return jnp.tanh(x @ w), None

        def f(x, ws):
            out, _ = jax.lax.scan(lambda c, w: layer(c, w), x, ws)
            return out

        x = jnp.ones((32, 64))
        L = 7
        ws = jnp.ones((L, 64, 64))
        text = jax.jit(f).lower(x, ws).compile().as_text()
        st = hlo_parse.analyze(text)
        assert st.flops == pytest.approx(L * 2 * 32 * 64 * 64, rel=0.01), \
            st.flops
        assert st.unknown_trip_loops == 0

    def test_nested_scans_multiply(self):
        def f(x, ws):
            def outer(c, w_outer):
                def inner(ci, w):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, w_outer)
                return ci, None
            out, _ = jax.lax.scan(outer, x, ws)
            return out

        x = jnp.ones((16, 16))
        ws = jnp.ones((3, 5, 16, 16))      # 3 outer x 5 inner
        text = jax.jit(f).lower(x, ws).compile().as_text()
        st = hlo_parse.analyze(text)
        assert st.flops == pytest.approx(15 * 2 * 16 * 16 * 16, rel=0.01), \
            st.flops

    def test_dot_general_batched_contraction(self):
        def f(a, b):
            return jnp.einsum("bik,bkj->bij", a, b)

        a = jnp.ones((4, 8, 16))
        b = jnp.ones((4, 16, 8))
        text = jax.jit(f).lower(a, b).compile().as_text()
        st = hlo_parse.analyze(text)
        assert st.flops == 2 * 4 * 8 * 16 * 8

    def test_collectives_counted_with_wire_multiplier(self):
        code = """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.roofline import hlo_parse
            mesh = jax.make_mesh((8,), ("data",))
            sh = NamedSharding(mesh, P("data"))
            rep = NamedSharding(mesh, P())

            @jax.jit
            def f(x):
                return jnp.sum(x)    # all-reduce over the data axis

            x = jax.ShapeDtypeStruct((64, 32), jnp.float32, sharding=sh)
            lowered = jax.jit(f, in_shardings=sh, out_shardings=rep).lower(x)
            text = lowered.compile().as_text()
            st = hlo_parse.analyze(text)
            assert "all-reduce" in st.collective_bytes_by_kind, \
                st.collective_bytes_by_kind
            # scalar partial-sum all-reduce: wire = 2 x 4 bytes
            assert st.collective_wire_bytes >= 8
            print("COLL_OK")
        """
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert res.returncode == 0, res.stderr
        assert "COLL_OK" in res.stdout


class TestRooflineTerms:
    def test_terms_and_bottleneck(self):
        record = {
            "devices": 256,
            "cost": {"flops": 1e12, "bytes_accessed": 1e9},
            "collectives": {"total_wire_bytes": 5e9, "parsed_flops": 2e12,
                            "parsed_bytes_accessed": 2e9},
        }
        rl = analysis.roofline_from_record(record, model_flops=1e14)
        # parsed numbers preferred over raw cost_analysis
        assert rl.hlo_flops == 2e12
        assert rl.compute_s == pytest.approx(2e12 / analysis.PEAK_FLOPS)
        assert rl.memory_s == pytest.approx(2e9 / analysis.HBM_BW)
        assert rl.collective_s == pytest.approx(5e9 / analysis.LINK_BW)
        assert rl.bottleneck == "collective"
        assert rl.useful_ratio == pytest.approx(1e14 / (2e12 * 256))

    def test_model_flops_moe_uses_active_params(self):
        from repro.models import registry
        from repro.configs.base import SHAPES
        cfg = registry.load_arch("mixtral_8x7b")
        mf = analysis.model_flops(cfg, SHAPES["train_4k"])
        # mixtral: ~13B active of 46.7B total
        tokens = 4096 * 256
        n_active = mf / 6 / tokens
        assert 10e9 < n_active < 16e9, n_active

    def test_decode_flops_linear_in_batch(self):
        from repro.models import registry
        from repro.configs.base import SHAPES
        cfg = registry.load_arch("tinyllama_1_1b")
        f_decode = analysis.model_flops(cfg, SHAPES["decode_32k"])
        assert f_decode == pytest.approx(2 * 1.10e9 * 128, rel=0.05)
