"""Tests for the unified ask/tell exploration driver: the strategy contract
(argument validation, in-chunk dedup, determinism, state round-trips),
hardware-mode bit-exact equivalence with ``search``, budgeted joint
strategies over the full model x hardware digit space, ``Study``
checkpoint/resume, and worker cell farming."""
import dataclasses
import zlib

import numpy as np
import pytest

from repro.core import dse, snn, workloads
from repro.core.accelerator import arch


def _tiny_wl(name="explore-test-wl"):
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name=name,
        layers=(snn.Dense(12),), pcr=1,
        n_train=128, n_test=64, train_steps=4, trace_samples=16)


def _hw_setup(max_lhr=8):
    cfg = arch.from_layer_sizes("t", (64, 32, 16), num_steps=3)
    counts = [np.full(3, 8.0)] * 2
    space = dse.SearchSpace.product_lhr(cfg, max_lhr=max_lhr)
    return cfg, counts, space


def _joint_space(wl, lhr=(1, 2, 4), bits=(4, 8), T=(2, 3),
                 pops=(0.5, 1.0)):
    tmpl = arch.from_snn_config(wl.build(int(T[0]), 1.0))
    return (dse.SearchSpace(tmpl)
            .add_model("num_steps", T)
            .add_model("population", pops)
            .add_per_layer("lhr", [list(lhr) for _ in tmpl.layers])
            .add_global("weight_bits", bits))


def _rows(table):
    """All columns flattened to sortable float rows (strings via crc32)."""
    cols = []
    for k in sorted(table.columns):
        v = np.asarray(table.columns[k])
        if v.dtype.kind in "USO":
            v = np.array([float(zlib.crc32(str(x).encode())) for x in v])
        cols.append(np.asarray(v, np.float64).reshape(len(table), -1))
    a = np.concatenate(cols, axis=1)
    return a[np.lexsort(a.T)]


@pytest.fixture(scope="module")
def shared_cache(tmp_path_factory):
    """One cache for the whole module so each cell trains exactly once."""
    return workloads.TraceCache(root=str(tmp_path_factory.mktemp("cells")))


class TestStrategyContract:
    def test_random_search_argument_validation(self):
        with pytest.raises(ValueError, match="n_samples"):
            dse.RandomSearch(0)
        with pytest.raises(ValueError, match="n_samples"):
            dse.RandomSearch(-5)
        with pytest.raises(ValueError, match="chunk_size"):
            dse.RandomSearch(10, chunk_size=0)
        with pytest.raises(ValueError, match="chunk_size"):
            dse.GridSearch(chunk_size=0)
        with pytest.raises(ValueError, match="generations"):
            dse.EvolutionarySearch(population=8, generations=0)

    def test_random_search_dedups_within_chunk(self):
        _, _, space = _hw_setup(max_lhr=2)          # 2 x 2 = 4 candidates
        s = dse.RandomSearch(50, seed=0, chunk_size=50)
        s.bind(space, ("cycles",))
        total = 0
        while True:
            digits = s.ask(50)
            if len(digits) == 0:
                break
            # every asked chunk is duplicate-free ...
            assert len(np.unique(digits, axis=0)) == len(digits)
            total += len(digits)
            s.tell(digits, np.zeros((len(digits), 1)))
        # ... and the distinct rows still add up to n_samples
        assert total == 50

    def test_grid_state_roundtrip_continues_exactly(self):
        _, _, space = _hw_setup()
        a = dse.GridSearch(chunk_size=7)
        a.bind(space, ("cycles",))
        first = a.ask(7)
        state = a.state_dict()
        b = dse.GridSearch(chunk_size=7)
        b.bind(space, ("cycles",))
        b.load_state_dict(state)
        np.testing.assert_array_equal(
            np.concatenate([first, b.ask(7)]),
            space.digits(np.arange(14)))

    def test_random_state_roundtrip_continues_exactly(self):
        _, _, space = _hw_setup()
        a = dse.RandomSearch(40, seed=9, chunk_size=10)
        a.bind(space, ("cycles",))
        seen_a = [a.ask(10) for _ in range(2)]
        state = a.state_dict()
        rest_a = []
        while len(chunk := a.ask(10)):
            rest_a.append(chunk)
        b = dse.RandomSearch(40, seed=9, chunk_size=10)
        b.bind(space, ("cycles",))
        b.load_state_dict(state)
        rest_b = []
        while len(chunk := b.ask(10)):
            rest_b.append(chunk)
        np.testing.assert_array_equal(np.concatenate(rest_a),
                                      np.concatenate(rest_b))
        assert all(len(c) for c in seen_a)


class TestHardwareExplore:
    def test_grid_explore_matches_search_bit_exactly(self):
        cfg, counts, space = _hw_setup()
        study = dse.explore(space, counts=counts, chunk_size=13)
        ref = dse.search(cfg, counts, space, chunk_size=13)
        assert study.mode == "hardware" and study.done
        assert study.n_evaluated == ref.n_evaluated == space.size
        np.testing.assert_array_equal(_rows(study.frontier),
                                      _rows(ref.frontier))
        # bit-exact, not just close
        for k in study.frontier.columns:
            assert study.frontier.columns[k].dtype == \
                ref.frontier.columns[k].dtype

    @pytest.mark.parametrize("make", [
        lambda seed: dse.RandomSearch(120, seed=seed),
        lambda seed: dse.EvolutionarySearch(population=16, generations=5,
                                            seed=seed)])
    def test_strategy_determinism_same_seed_same_frontier(self, make):
        cfg, counts, space = _hw_setup()
        a = dse.explore(space, counts=counts, strategy=make(3))
        b = dse.explore(space, counts=counts, strategy=make(3))
        assert a.n_evaluated == b.n_evaluated > 0
        np.testing.assert_array_equal(_rows(a.frontier), _rows(b.frontier))

    def test_chunking_does_not_change_strategy_results(self):
        """The driver owns chunking: splitting a population across many
        ask/tell rounds must not change the evolutionary trajectory."""
        cfg, counts, space = _hw_setup()
        make = lambda: dse.EvolutionarySearch(population=16, generations=4,
                                              seed=7)
        a = dse.explore(space, counts=counts, strategy=make(), chunk_size=5)
        b = dse.explore(space, counts=counts, strategy=make(),
                        chunk_size=4096)
        assert a.n_evaluated == b.n_evaluated == 16 * 4
        np.testing.assert_array_equal(_rows(a.frontier), _rows(b.frontier))

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_evolutionary_beats_random_on_best_point(self, seed):
        """Sanity: with an equal evaluation budget on a space too large to
        enumerate cheaply, the evolutionary loop finds a better best
        trade-off point (min normalized objective sum) than i.i.d.
        sampling.  Deterministic for the pinned seeds."""
        cfg = arch.from_layer_sizes(
            "q", (512, 256, 256, 128, 128, 64, 64), num_steps=3)
        counts = [np.full(3, 12.0)] * 6
        space = dse.SearchSpace.product_lhr(cfg, max_lhr=256)
        objectives = ("cycles", "lut")

        def best_sum(study, lo, hi):
            f = np.stack([np.asarray(study.frontier.columns[k], np.float64)
                          for k in objectives], axis=1)
            return ((f - lo) / (hi - lo)).sum(axis=1).min()

        evo = dse.explore(space, counts=counts, objectives=objectives,
                          strategy=dse.EvolutionarySearch(
                              population=32, generations=12, seed=seed))
        rnd = dse.explore(space, counts=counts, objectives=objectives,
                          strategy=dse.RandomSearch(32 * 12, seed=seed))
        assert evo.n_evaluated == rnd.n_evaluated == 32 * 12
        all_pts = np.concatenate([
            np.stack([np.asarray(s.frontier.columns[k], np.float64)
                      for k in objectives], axis=1) for s in (evo, rnd)])
        lo, hi = all_pts.min(axis=0), all_pts.max(axis=0)
        assert best_sum(evo, lo, hi) < best_sum(rnd, lo, hi)

    def test_explore_validates_like_search(self):
        cfg, counts, space = _hw_setup()
        with pytest.raises(ValueError, match="unknown objective"):
            dse.explore(space, counts=counts, objectives=("latency",))
        with pytest.raises(ValueError, match="unknown strategy name"):
            dse.explore(space, counts=counts, strategy="annealing")
        with pytest.raises(ValueError, match="counts"):
            dse.explore(space)
        with pytest.raises(ValueError, match="chunk_size"):
            dse.explore(space, counts=counts, chunk_size=0)
        # joint-only kwargs on a hardware-only space fail loudly instead of
        # being silently ignored
        with pytest.raises(ValueError, match="hardware-only"):
            dse.explore(space, counts=counts, workers=4)
        with pytest.raises(ValueError, match="hardware-only"):
            dse.explore(space, counts=counts, train_budget=3)
        with pytest.raises(ValueError, match="hardware-only"):
            dse.explore(space, counts=counts, max_lhr=8)


class TestJointBudgetedExplore:
    def test_evolutionary_joint_respects_train_budget(self, tmp_path):
        """The acceptance sweep: EvolutionarySearch over the full
        (num_steps x population x LHR x weight_bits) digit space with
        train_budget=2 trains at most 2 of the 4 cells — verified by the
        cache counters — and the frontier only contains trained cells."""
        wl = _tiny_wl()
        space = _joint_space(wl)
        cache = workloads.TraceCache(root=str(tmp_path / "cells"))
        study = dse.explore(
            space, workload=wl, cache=cache, train_budget=2, chunk_size=8,
            strategy=dse.EvolutionarySearch(population=8, generations=3,
                                            seed=0))
        assert study.mode == "joint" and study.done
        assert cache.misses <= 2
        assert study.summary["train_budget"]["spent"] == cache.misses
        assert study.summary["cache"]["misses"] == cache.misses
        assert len(study.cells) <= 2
        assert study.n_evaluated > 0
        fr = study.frontier
        trained = {(c.assignment["num_steps"], c.assignment["population"])
                   for c in study.cells}
        for i in range(len(fr)):
            r = fr.row(i)
            assert (r["num_steps"], r["population"]) in trained
        # frontier is mutually non-dominated and accuracy-aware
        obj = np.stack([np.asarray(fr.columns[k]) for k in study.objectives],
                       axis=1)
        assert dse.pareto_mask_k(obj).all()
        cells = {(c.assignment["num_steps"], c.assignment["population"]): c
                 for c in study.cells}
        for i in range(len(fr)):
            r = fr.row(i)
            c = cells[(r["num_steps"], r["population"])]
            assert r["accuracy"] == c.quant_acc[r["weight_bits"]]
        # once the budget is gone, encountered untrained cells are skipped
        if study.summary["train_budget"]["remaining"] == 0:
            assert study.summary["cells_skipped"] == len(study.skipped)

    def test_budget_zero_skips_everything(self, tmp_path):
        wl = _tiny_wl()
        cache = workloads.TraceCache(root=str(tmp_path / "cells"))
        study = dse.explore(
            _joint_space(wl), workload=wl, cache=cache, train_budget=0,
            strategy=dse.RandomSearch(32, seed=0))
        assert cache.misses == 0 and cache.hits == 0
        assert study.n_evaluated == 0
        assert len(study.frontier) == 0
        assert len(study.skipped) > 0

    def test_cache_hits_are_free_under_budget(self, shared_cache):
        """Cells already in the cache cost nothing: a zero budget still
        explores them (NAS semantics: the budget is *training* cost)."""
        wl = _tiny_wl()
        space = _joint_space(wl)
        warm = dse.explore(space, workload=wl, cache=shared_cache,
                           strategy=dse.RandomSearch(64, seed=1))
        assert len(warm.cells) == 4
        misses_before = shared_cache.misses
        study = dse.explore(space, workload=wl, cache=shared_cache,
                            train_budget=0,
                            strategy=dse.RandomSearch(64, seed=1))
        assert shared_cache.misses == misses_before
        assert len(study.cells) == 4 and not study.skipped
        assert study.n_evaluated == warm.n_evaluated
        np.testing.assert_array_equal(_rows(study.frontier),
                                      _rows(warm.frontier))

    def test_joint_strategies_need_declared_space(self, shared_cache):
        wl = _tiny_wl()
        with pytest.raises(ValueError, match="joint digit space"):
            dse.explore(workload=wl, num_steps=(2, 3), max_lhr=4,
                        cache=shared_cache,
                        strategy=dse.RandomSearch(16))
        space = _joint_space(wl)
        with pytest.raises(ValueError, match="joint digit space"):
            dse.explore(space, workload=wl, cache=shared_cache,
                        hw_space=lambda c: dse.SearchSpace.product_lhr(c),
                        strategy=dse.RandomSearch(16))
        tmpl = arch.from_snn_config(wl.build(2, 1.0))
        no_t = (dse.SearchSpace(tmpl)
                .add_model("population", (0.5, 1.0))
                .add_per_layer("lhr", [[1, 2] for _ in tmpl.layers]))
        with pytest.raises(ValueError, match="num_steps"):
            dse.explore(no_t, workload=wl, cache=shared_cache,
                        strategy=dse.RandomSearch(16))

    def test_coexplore_strategy_passthrough_matches_explore(self,
                                                            shared_cache):
        """coexplore(strategy=..., train_budget=...) is a thin wrapper over
        the same joint driver."""
        wl = _tiny_wl()
        space = _joint_space(wl)
        res = dse.coexplore(wl, space, cache=shared_cache,
                            strategy=dse.RandomSearch(64, seed=1))
        study = dse.explore(space, workload=wl, cache=shared_cache,
                            strategy=dse.RandomSearch(64, seed=1))
        assert res.n_evaluated == study.n_evaluated
        np.testing.assert_array_equal(_rows(res.frontier),
                                      _rows(study.frontier))
        assert res.summary["cache"]["hits"] >= 4


class TestStudyLifecycle:
    def test_hardware_checkpoint_resume_identical(self, tmp_path):
        cfg, counts, space = _hw_setup()
        ref = dse.explore(space, counts=counts, chunk_size=3)

        ck = str(tmp_path / "study")
        study = dse.explore(space, counts=counts, chunk_size=3,
                            checkpoint_dir=ck, run=False)
        for _ in range(3):
            assert study.step()
        study.checkpoint()
        resumed = dse.explore(space, counts=counts, chunk_size=3,
                              checkpoint_dir=ck, resume=True)
        assert resumed.done
        assert resumed.n_evaluated == ref.n_evaluated
        np.testing.assert_array_equal(_rows(resumed.frontier),
                                      _rows(ref.frontier))
        # dtypes survive the store round-trip exactly (int64/float64)
        for k, v in ref.frontier.columns.items():
            assert resumed.frontier.columns[k].dtype == v.dtype
        # the resumed run's final checkpoint (new step dir, old one pruned)
        # is itself resumable
        again = dse.explore(space, counts=counts, chunk_size=3,
                            checkpoint_dir=ck, resume=True)
        assert again.done and again.n_evaluated == ref.n_evaluated

    def test_cells_mode_checkpoint_resume(self, tmp_path):
        """Cells-mode studies checkpoint at cell boundaries: the outer
        strategy holds no state (each cell sweeps its own inner grid), only
        the cell cursor + records resume."""
        wl = _tiny_wl("explore-cells-ck")
        ref_cache = workloads.TraceCache(root=str(tmp_path / "ref"))
        ref = dse.explore(workload=wl, num_steps=(2, 3), max_lhr=4,
                          cache=ref_cache)
        assert ref.mode == "cells"

        root = str(tmp_path / "cells")
        ck = str(tmp_path / "ck")
        mid_cache = workloads.TraceCache(root=root)
        study = dse.explore(workload=wl, num_steps=(2, 3), max_lhr=4,
                            cache=mid_cache, checkpoint_dir=ck, run=False)
        assert study.step()                       # first cell swept
        study.checkpoint()
        assert mid_cache.misses == 1

        fresh = workloads.TraceCache(root=root)
        resumed = dse.explore(workload=wl, num_steps=(2, 3), max_lhr=4,
                              cache=fresh, checkpoint_dir=ck, resume=True)
        assert resumed.done
        assert fresh.misses == 1                  # only the 2nd cell trains
        assert resumed.n_evaluated == ref.n_evaluated
        assert [c.workload for c in resumed.cells] == \
            [c.workload for c in ref.cells]
        np.testing.assert_array_equal(_rows(resumed.frontier),
                                      _rows(ref.frontier))

    def test_joint_checkpoint_resume_no_retraining(self, tmp_path):
        """The acceptance flow: a budgeted evolutionary joint study is
        checkpointed mid-run and resumed — the resumed study retrains
        nothing (all cache hits) and finishes with the exact frontier of an
        uninterrupted run."""
        wl = _tiny_wl()
        space = _joint_space(wl)
        make = lambda: dse.EvolutionarySearch(population=8, generations=4,
                                              seed=1)

        # reference: uninterrupted run on its own fresh cache root
        ref_cache = workloads.TraceCache(root=str(tmp_path / "cells_ref"))
        ref = dse.explore(space, workload=wl, cache=ref_cache,
                          train_budget=2, chunk_size=8, strategy=make())
        assert ref_cache.misses <= 2

        # identically configured study on a second fresh root, interrupted
        # after 3 rounds (by then the 2-miss budget is spent)
        root = str(tmp_path / "cells_mid")
        ck = str(tmp_path / "study")
        mid_cache = workloads.TraceCache(root=root)
        study = dse.explore(space, workload=wl, cache=mid_cache,
                            train_budget=2, chunk_size=8, strategy=make(),
                            checkpoint_dir=ck, run=False)
        for _ in range(3):
            assert study.step()
        study.checkpoint()
        assert not study.done
        assert mid_cache.misses == 2              # budget spent pre-resume

        fresh_cache = workloads.TraceCache(root=root)
        resumed = dse.explore(space, workload=wl, cache=fresh_cache,
                              train_budget=2, chunk_size=8, strategy=make(),
                              checkpoint_dir=ck, resume=True)
        assert resumed.done
        assert fresh_cache.misses == 0            # no re-training
        assert resumed.n_evaluated == ref.n_evaluated
        assert resumed.summary["train_budget"] == \
            ref.summary["train_budget"]
        np.testing.assert_array_equal(_rows(resumed.frontier),
                                      _rows(ref.frontier))
        assert sorted(c.key for c in resumed.cells) == \
            sorted(c.key for c in ref.cells)

    def test_resume_refuses_different_study(self, tmp_path):
        cfg, counts, space = _hw_setup()
        ck = str(tmp_path / "study")
        dse.explore(space, counts=counts, checkpoint_dir=ck)
        with pytest.raises(ValueError, match="different study"):
            dse.explore(space, counts=counts, objectives=("cycles", "lut"),
                        checkpoint_dir=ck, resume=True)
        with pytest.raises(FileNotFoundError, match="checkpoint"):
            dse.explore(space, counts=counts,
                        checkpoint_dir=str(tmp_path / "nope"), resume=True)
        # same strategy CLASS with different hyperparameters also refuses
        ck2 = str(tmp_path / "study2")
        dse.explore(space, counts=counts,
                    strategy=dse.RandomSearch(50, seed=1),
                    checkpoint_dir=ck2)
        with pytest.raises(ValueError, match="different study"):
            dse.explore(space, counts=counts,
                        strategy=dse.RandomSearch(60, seed=1),
                        checkpoint_dir=ck2, resume=True)

    def test_checkpoint_keep_all_conflict(self, tmp_path):
        cfg, counts, space = _hw_setup()
        with pytest.raises(ValueError, match="keep_all"):
            dse.explore(space, counts=counts, keep_all=True,
                        checkpoint_dir=str(tmp_path / "s"))
        with pytest.raises(ValueError, match="checkpoint_dir"):
            dse.explore(space, counts=counts, resume=True)

    def test_summary_counters(self, shared_cache):
        wl = _tiny_wl()
        res = dse.coexplore(wl, num_steps=(2, 3), population=(0.5, 1.0),
                            max_lhr=4, weight_bits=(4, 8),
                            cache=shared_cache)
        s = res.summary
        assert s["mode"] == "cells" and s["done"]
        assert s["n_evaluated"] == res.n_evaluated
        assert s["cells_resolved"] == 4
        assert set(s["cache"]) == {"hits", "misses", "farmed_misses"}
        assert s["train_budget"] is None


class TestCellFarming:
    def test_coexplore_workers_matches_serial(self, tmp_path):
        """workers=N trains pending cells across processes into the shared
        content-addressed cache; the driver then resolves them as hits and
        the result equals the serial sweep."""
        wl = _tiny_wl("explore-farm-wl")
        serial_cache = workloads.TraceCache(root=str(tmp_path / "a"))
        serial = dse.coexplore(wl, num_steps=(2, 3), max_lhr=4,
                               cache=serial_cache)

        farm_cache = workloads.TraceCache(root=str(tmp_path / "b"))
        farmed = dse.coexplore(wl, num_steps=(2, 3), max_lhr=4,
                               cache=farm_cache, workers=2)
        assert farmed.study.farmed_misses == 2
        assert farm_cache.misses == 0             # parent only saw hits
        assert farm_cache.hits == 2
        assert farmed.summary["cache"]["farmed_misses"] == 2
        np.testing.assert_array_equal(_rows(farmed.frontier),
                                      _rows(serial.frontier))
