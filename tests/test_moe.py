"""MoE dispatch tests: gather-based dispatch must agree with the
GShard-faithful einsum dispatch wherever no token is dropped."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe


def _setup(E=4, d=32, ff=64, seed=0, dispatch="gather", cap=4.0):
    cfg = MoEConfig(num_experts=E, top_k=2, capacity_factor=cap,
                    dispatch=dispatch)
    p = moe.moe_init(jax.random.key(seed), d, ff, cfg, jnp.float32)
    return cfg, p


class TestDispatchEquivalence:
    @pytest.mark.parametrize("seed", range(4))
    def test_gather_equals_einsum_when_no_drops(self, seed):
        cfg_g, p = _setup(seed=seed, dispatch="gather", cap=8.0)
        cfg_e = dataclasses.replace(cfg_g, dispatch="einsum")
        x = jax.random.normal(jax.random.key(seed + 100), (2, 16, 32))
        out_g, _ = moe.moe_apply(p, cfg_g, x)
        out_e, _ = moe.moe_apply(p, cfg_e, x)
        np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_e),
                                   atol=1e-5, rtol=1e-5)

    def test_capacity_drops_tokens_identically(self):
        """With a tight capacity both paths drop the same overflow tokens."""
        cfg_g, p = _setup(dispatch="gather", cap=0.5)
        cfg_e = dataclasses.replace(cfg_g, dispatch="einsum")
        x = jax.random.normal(jax.random.key(5), (1, 32, 32))
        out_g, _ = moe.moe_apply(p, cfg_g, x)
        out_e, _ = moe.moe_apply(p, cfg_e, x)
        np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_e),
                                   atol=1e-5, rtol=1e-5)

    def test_gather_differentiable(self):
        cfg, p = _setup(dispatch="gather")
        x = jax.random.normal(jax.random.key(1), (2, 8, 32))

        def loss(p):
            out, aux = moe.moe_apply(p, cfg, x)
            return jnp.sum(out ** 2) + 0.01 * aux

        grads = jax.grad(loss)(p)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat)
        assert sum(float(jnp.abs(g).sum()) for g in flat) > 0

    def test_top1_gate_weights_sum(self):
        """Every kept token's output = sum of gate-weighted expert outputs;
        with identity-like experts the gates must appear in the output."""
        cfg, p = _setup(dispatch="gather", cap=8.0)
        x = jax.random.normal(jax.random.key(2), (1, 8, 32))
        out, _ = moe.moe_apply(p, cfg, x)
        assert out.shape == (1, 8, 32)
        assert bool(jnp.isfinite(out).all())

    def test_dense_residual_added(self):
        cfg, p = _setup(dispatch="gather")
        cfg_dr = dataclasses.replace(cfg, dense_residual=True, dense_d_ff=64)
        import jax.random as jr
        p_dr = moe.moe_init(jr.key(0), 32, 64, cfg_dr, jnp.float32)
        x = jax.random.normal(jax.random.key(3), (1, 8, 32))
        out_a, _ = moe.moe_apply(
            p_dr, dataclasses.replace(cfg_dr, dense_residual=False), x)
        out_b, _ = moe.moe_apply(p_dr, cfg_dr, x)
        assert not np.allclose(np.asarray(out_a), np.asarray(out_b))

    def test_expert_activation_stats_sum_to_one(self):
        cfg, p = _setup()
        x = jax.random.normal(jax.random.key(4), (2, 64, 32))
        stats = moe.expert_activation_stats(p, cfg, x)
        assert stats.shape == (4,)
        np.testing.assert_allclose(float(stats.sum()), 1.0, rtol=1e-5)
