"""Tests for the extended DSE dimensions (encodings, memory blocks,
weight precision, batch fixed-point)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dse, encoding, validate
from repro.core.accelerator import paper_nets


class TestTTFS:
    def test_single_spike_per_neuron(self):
        x = jnp.asarray([[0.1, 0.5, 0.9, 1.0]])
        spikes = encoding.ttfs_encode(x, 10)
        counts = np.asarray(spikes.sum(0))
        np.testing.assert_array_equal(counts, [[1, 1, 1, 1]])

    def test_brighter_spikes_earlier(self):
        x = jnp.asarray([[0.2, 0.8]])
        spikes = np.asarray(encoding.ttfs_encode(x, 10))
        t_dim = spikes[:, 0, 0].argmax()
        t_bright = spikes[:, 0, 1].argmax()
        assert t_bright < t_dim

    def test_zero_never_spikes(self):
        x = jnp.zeros((1, 5))
        assert float(encoding.ttfs_encode(x, 8).sum()) == 0.0

    def test_sparser_than_rate(self):
        x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (4, 16)),
                        jnp.float32)
        ttfs = encoding.ttfs_encode(x, 20)
        rate = encoding.rate_encode(jax.random.key(0), x, 20)
        assert float(ttfs.mean()) < float(rate.mean())


class TestBurst:
    def test_burst_length_scales_with_intensity(self):
        x = jnp.asarray([[0.0, 0.25, 0.5, 1.0]])
        spikes = np.asarray(encoding.burst_encode(jax.random.key(0), x, 10,
                                                  max_burst=4))
        np.testing.assert_array_equal(spikes.sum(0), [[0, 1, 2, 4]])

    def test_burst_is_leading_consecutive(self):
        x = jnp.asarray([[0.75]])
        s = np.asarray(encoding.burst_encode(jax.random.key(0), x, 8,
                                             max_burst=4))[:, 0, 0]
        np.testing.assert_array_equal(s, [1, 1, 1, 0, 0, 0, 0, 0])


class TestMemoryBlockSweep:
    def test_contention_monotone(self):
        cfg = paper_nets.build("net-1", lhr=(2, 2, 2))
        counts = paper_nets.paper_counts("net-1", cfg)
        cands = dse.sweep_memory_blocks(cfg, counts, divisors=(1, 2, 4))
        cycles = [c.cycles for c in cands]
        luts = [c.lut for c in cands]
        assert cycles[0] < cycles[1] < cycles[2]     # fewer blocks = slower
        assert luts[0] > luts[1] > luts[2]           # ... but smaller

    def test_weight_bits_scale_bram(self):
        cfg = paper_nets.build("net-1")
        brams = dse.sweep_weight_bits(cfg, (4, 8, 16))
        assert brams[4] < brams[8] < brams[16]
        assert brams[16] == pytest.approx(2 * brams[8], rel=0.05)


class TestBatchFixedPoint:
    @pytest.mark.parametrize("seed", range(3))
    def test_batch_matches_per_sample(self, seed):
        rng = np.random.default_rng(seed)
        sizes = (12, 8, 6)
        w = [rng.normal(0, 0.5, size=(sizes[i], sizes[i + 1]))
             for i in range(2)]
        b = [rng.normal(0, 0.1, size=(sizes[i + 1],)) for i in range(2)]
        net = validate.quantize(w, b, beta=0.9, threshold=1.0)
        spikes = (rng.random((5, 4, 12)) < 0.4).astype(np.int64)
        batch_out = validate.reference_apply_batch(net, spikes)
        for i in range(4):
            single = validate.reference_apply(net, spikes[:, i])
            np.testing.assert_array_equal(batch_out[:, i], single)
