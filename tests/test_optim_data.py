"""Optimizer + data-pipeline tests (property-style sweeps with seeds)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.data import pipeline


def _quadratic(dim=8, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((dim, dim))
    A = A @ A.T / dim + np.eye(dim)
    b = rng.standard_normal(dim)

    def loss(w):
        return 0.5 * w @ jnp.asarray(A) @ w - jnp.asarray(b) @ w

    w_star = np.linalg.solve(A, b)
    return loss, w_star


class TestOptimizers:
    @pytest.mark.parametrize("make_tx,lr,steps,tol", [
        (lambda lr: optim.sgd(lr), 0.1, 300, 1e-2),
        (lambda lr: optim.sgd(lr, momentum=0.9), 0.05, 300, 1e-2),
        (lambda lr: optim.adam(lr), 0.1, 500, 1e-2),
        (lambda lr: optim.adamw(lr, weight_decay=0.0), 0.1, 500, 5e-2),
        (lambda lr: optim.adafactor_lite(lr), 0.3, 800, 2e-1),
    ])
    def test_converges_on_quadratic(self, make_tx, lr, steps, tol):
        loss, w_star = _quadratic()
        tx = make_tx(lr)
        w = jnp.zeros(8)
        state = tx.init(w)
        g = jax.grad(loss)

        @jax.jit
        def step(w, state):
            updates, state = tx.update(g(w), state, w)
            return optim.apply_updates(w, updates), state

        for _ in range(steps):
            w, state = step(w, state)
        assert np.linalg.norm(np.asarray(w) - w_star) < tol * (
            1 + np.linalg.norm(w_star))

    def test_clip_by_global_norm(self):
        tx = optim.clip_by_global_norm(1.0)
        g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
        clipped, _ = tx.update(g, tx.init(g), None)
        assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5

    def test_weight_decay_changes_updates(self):
        loss, _ = _quadratic()
        w = jnp.ones(8)
        g = jax.grad(loss)(w)
        tx0 = optim.adamw(0.1, weight_decay=0.0)
        tx1 = optim.adamw(0.1, weight_decay=0.5)
        u0, _ = tx0.update(g, tx0.init(w), w)
        u1, _ = tx1.update(g, tx1.init(w), w)
        assert not np.allclose(np.asarray(u0), np.asarray(u1))

    def test_adafactor_state_is_factored(self):
        tx = optim.adafactor_lite(1e-2)
        params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
        state = tx.init(params)
        assert state.row["w"].shape == (64,)
        assert state.col["w"].shape == (32,)
        assert state.full["b"].shape == (32,)

    def test_schedules(self):
        s = optim.linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0, abs=1e-6)
        assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
        assert float(s(5)) == pytest.approx(0.5, abs=1e-6)


class TestDataPipeline:
    def test_deterministic_across_calls(self):
        cfg = pipeline.DataConfig(vocab=128, seq_len=16, global_batch=4)
        b1 = pipeline.synthetic_lm_batch(cfg, 5)
        b2 = pipeline.synthetic_lm_batch(cfg, 5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        cfg = pipeline.DataConfig(vocab=128, seq_len=16, global_batch=4)
        b1 = pipeline.synthetic_lm_batch(cfg, 1)
        b2 = pipeline.synthetic_lm_batch(cfg, 2)
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = pipeline.DataConfig(vocab=128, seq_len=16, global_batch=4)
        b = pipeline.synthetic_lm_batch(cfg, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_slice_partitions(self):
        cfg = pipeline.DataConfig(vocab=128, seq_len=8, global_batch=8)
        b = pipeline.synthetic_lm_batch(cfg, 0)
        parts = [pipeline.host_slice(b["tokens"], i, 4) for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])

    def test_markov_structure_learnable(self):
        """The chain has predictable transitions: bigram count entropy is
        well below uniform."""
        cfg = pipeline.DataConfig(vocab=64, seq_len=128, global_batch=16)
        b = pipeline.synthetic_lm_batch(cfg, 0)
        toks = np.asarray(b["tokens"])
        pairs = {}
        for row in toks:
            for a, c in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), []).append(int(c))
        # for contexts seen often, the mode should dominate vs 1/64 uniform
        # (the chain is order-2, so the bigram signal is diluted; uniform
        # would give ~0.04 here)
        rates = [max(np.bincount(v).max() / len(v), 0)
                 for v in pairs.values() if len(v) >= 20]
        assert np.mean(rates) > 0.08
