"""Gradient-parity harness for the spike_gemm training path.

The kernel route (``ops.spike_gemm_train``: block-skip Pallas forward,
dense-reference backward via custom_vjp) must be a drop-in replacement for
the pure-jnp matmul on the BPTT hot path: same forward values, same
cotangents, through surrogate gradients and ``lax.scan``.  These tests lock
that contract down at three levels — the custom_vjp itself
(``jax.test_util.check_grads``), single-gemm loss gradients across
non-tile-multiple shapes and degenerate spike trains, and full SNN loss
gradients under both LIF reset mechanisms.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core import snn, train_snn
from repro.core.lif import LIFParams
from repro.kernels import ops, ref


def _spikes(shape, density, seed=0, dtype=jnp.float32):
    if density == 0.0:
        return jnp.zeros(shape, dtype)
    if density == 1.0:
        return jnp.ones(shape, dtype)
    return (jax.random.uniform(jax.random.key(seed), shape) < density
            ).astype(dtype)


def _assert_tree_allclose(a, b, atol=1e-5, rtol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=atol, rtol=rtol), a, b)


class TestCustomVJP:
    """The custom_vjp contract on the gemm itself."""

    def test_check_grads_rev(self):
        """jax.test_util.check_grads on the custom_vjp (rev mode; the dense
        50% train keeps every occupancy flag stable under the numeric
        perturbations, so the block-skip forward stays the linear map)."""
        s = _spikes((16, 40), 0.5, seed=3)
        w = jax.random.normal(jax.random.key(4), (40, 12)) * 0.1
        check_grads(ops.spike_gemm_train, (s, w), order=1, modes=["rev"],
                    atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("shape", [(32, 100, 10), (8, 784, 128),
                                       (5, 64, 3)])
    @pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
    def test_gemm_grads_match_jnp(self, shape, density):
        """value_and_grad of a scalar loss through the kernel path equals
        the jnp path, including non-tile-multiple K/N and all-zero /
        all-one spike trains."""
        M, K, N = shape
        s = _spikes((M, K), density, seed=M)
        w = jax.random.normal(jax.random.key(K), (K, N)) * 0.1

        def loss(fn):
            return lambda s, w: jnp.sum(jnp.tanh(fn(s, w)))

        (va, ga) = jax.value_and_grad(loss(ops.spike_gemm_train),
                                      argnums=(0, 1))(s, w)
        (vb, gb) = jax.value_and_grad(loss(lambda s, w: s @ w),
                                      argnums=(0, 1))(s, w)
        np.testing.assert_allclose(float(va), float(vb), rtol=1e-6)
        # forward tile-order rounding shifts the tanh' factor slightly at
        # saturation; the cotangent math itself is the exact dense reference
        _assert_tree_allclose(ga, gb, atol=1e-4, rtol=1e-4)

    def test_zero_train_zero_weight_grad(self):
        """An all-zero train skips every tile, yet the backward still
        produces the exact dense cotangents (dW = S^T g = 0, dS = g W^T)."""
        s = jnp.zeros((16, 256), jnp.float32)
        w = jax.random.normal(jax.random.key(0), (256, 64))
        ds, dw = jax.grad(lambda s, w: ops.spike_gemm_train(s, w).sum(),
                          argnums=(0, 1))(s, w)
        np.testing.assert_array_equal(np.asarray(dw), 0.0)
        np.testing.assert_allclose(np.asarray(ds),
                                   np.broadcast_to(np.asarray(w.sum(1)),
                                                   (16, 256)),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_through_permutation(self):
        """The profiled permutation is applied outside the custom_vjp; the
        chain rule through the gathers must reproduce unpermuted grads."""
        s = _spikes((8, 200), 0.2, seed=9)
        w = jax.random.normal(jax.random.key(10), (200, 16)) * 0.1
        perm = ops.firing_rate_permutation(s.mean(0))

        def loss_perm(w):
            return ops.spike_gemm_train(s[:, perm], w[perm, :]).sum()

        g_perm = jax.grad(loss_perm)(w)
        g_ref = jax.grad(lambda w: (s @ w).sum())(w)
        np.testing.assert_allclose(np.asarray(g_perm), np.asarray(g_ref),
                                   atol=1e-6)


class TestLossGradParity:
    """Full surrogate-gradient BPTT through lax.scan, both backends."""

    def _cfg(self, reset="subtract", K=100, hidden=33, classes=10):
        lif = LIFParams(reset_mechanism=reset)
        side = int(np.sqrt(K))
        return snn.SNNConfig(
            name=f"g-{reset}", input_shape=(side, side),
            layers=(snn.Dense(hidden, lif=lif), snn.Dense(classes, lif=lif)),
            num_classes=classes, num_steps=5)

    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    def test_loss_grads_match(self, reset):
        cfg = self._cfg(reset)
        params = snn.init_params(jax.random.key(0), cfg)
        x = jax.random.uniform(jax.random.key(1), (16, 100))
        y = jax.random.randint(jax.random.key(2), (16,), 0, cfg.num_classes)
        key = jax.random.key(3)
        grads = {}
        vals = {}
        for backend in snn.MATMUL_BACKENDS:
            vals[backend], grads[backend] = jax.value_and_grad(
                lambda p: train_snn.loss_fn(cfg, p, key, x, y,
                                            matmul_backend=backend))(params)
        np.testing.assert_allclose(float(vals["jnp"]),
                                   float(vals["spike_gemm"]), rtol=1e-6)
        _assert_tree_allclose(grads["jnp"], grads["spike_gemm"],
                              atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("density", [0.0, 1.0])
    def test_degenerate_input_trains(self, density):
        """All-zero and all-one input spike trains through the full net."""
        cfg = self._cfg("subtract", K=64, hidden=24, classes=4)
        params = snn.init_params(jax.random.key(5), cfg)
        spikes_in = _spikes((cfg.num_steps, 8, 64), density)
        y = jnp.arange(8) % 4

        def loss(p, backend):
            out = snn.apply(cfg, p, spikes_in, matmul_backend=backend)
            from repro.core import encoding
            return encoding.rate_loss(out, y, cfg.num_classes)

        va, ga = jax.value_and_grad(loss)(params, "jnp")
        vb, gb = jax.value_and_grad(loss)(params, "spike_gemm")
        np.testing.assert_allclose(float(va), float(vb), rtol=1e-6)
        _assert_tree_allclose(ga, gb, atol=1e-6, rtol=1e-6)

    def test_forward_values_match(self):
        """Spike-for-spike identical forward trains (binary outputs make
        exact equality the right assertion)."""
        cfg = self._cfg("zero")
        params = snn.init_params(jax.random.key(7), cfg)
        x = jax.random.uniform(jax.random.key(8), (4, 100))
        from repro.core import encoding
        spikes_in = encoding.rate_encode(jax.random.key(9), x, cfg.num_steps)
        out_j = snn.apply(cfg, params, spikes_in, matmul_backend="jnp",
                          return_all_layers=True)
        out_k = snn.apply(cfg, params, spikes_in,
                          matmul_backend="spike_gemm",
                          return_all_layers=True)
        for a, b in zip(out_j, out_k):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
