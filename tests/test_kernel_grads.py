"""Gradient-parity harness for the spike_gemm training path.

The kernel routes (``ops.spike_gemm_train``: block-skip Pallas forward AND
backward via custom_vjp; ``ops.spike_gemm_lif_step``: the fused GEMM+LIF
scan-step kernel) must be drop-in replacements for the pure-jnp matmul on
the BPTT hot path: same forward values, same cotangents, through surrogate
gradients and ``lax.scan``.  These tests lock that contract down at four
levels — the custom_vjps themselves (``jax.test_util.check_grads``),
bit-for-bit skip-exactness of the sparse backward on grid-quantized
operands, single-gemm loss gradients across non-tile-multiple shapes and
degenerate spike trains, and full SNN loss gradients under both LIF reset
mechanisms for every backend in ``snn.MATMUL_BACKENDS``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core import snn, train_snn
from repro.core.lif import LIFParams
from repro.kernels import ops, ref


def _spikes(shape, density, seed=0, dtype=jnp.float32):
    if density == 0.0:
        return jnp.zeros(shape, dtype)
    if density == 1.0:
        return jnp.ones(shape, dtype)
    return (jax.random.uniform(jax.random.key(seed), shape) < density
            ).astype(dtype)


def _assert_tree_allclose(a, b, atol=1e-5, rtol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=atol, rtol=rtol), a, b)


class TestCustomVJP:
    """The custom_vjp contract on the gemm itself."""

    def test_check_grads_rev(self):
        """jax.test_util.check_grads on the custom_vjp (rev mode; the dense
        50% train keeps every occupancy flag stable under the numeric
        perturbations, so the block-skip forward stays the linear map)."""
        s = _spikes((16, 40), 0.5, seed=3)
        w = jax.random.normal(jax.random.key(4), (40, 12)) * 0.1
        check_grads(ops.spike_gemm_train, (s, w), order=1, modes=["rev"],
                    atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("shape", [(32, 100, 10), (8, 784, 128),
                                       (5, 64, 3)])
    @pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
    def test_gemm_grads_match_jnp(self, shape, density):
        """value_and_grad of a scalar loss through the kernel path equals
        the jnp path, including non-tile-multiple K/N and all-zero /
        all-one spike trains."""
        M, K, N = shape
        s = _spikes((M, K), density, seed=M)
        w = jax.random.normal(jax.random.key(K), (K, N)) * 0.1

        def loss(fn):
            return lambda s, w: jnp.sum(jnp.tanh(fn(s, w)))

        (va, ga) = jax.value_and_grad(loss(ops.spike_gemm_train),
                                      argnums=(0, 1))(s, w)
        (vb, gb) = jax.value_and_grad(loss(lambda s, w: s @ w),
                                      argnums=(0, 1))(s, w)
        np.testing.assert_allclose(float(va), float(vb), rtol=1e-6)
        # forward tile-order rounding shifts the tanh' factor slightly at
        # saturation; the cotangent math itself is the exact dense reference
        _assert_tree_allclose(ga, gb, atol=1e-4, rtol=1e-4)

    def test_zero_train_zero_weight_grad(self):
        """An all-zero train skips every dW tile, yet the backward still
        produces the exact dense cotangents (dW = S^T g = 0, dS = g W^T)."""
        s = jnp.zeros((16, 256), jnp.float32)
        w = jax.random.normal(jax.random.key(0), (256, 64))
        ds, dw = jax.grad(lambda s, w: ops.spike_gemm_train(s, w).sum(),
                          argnums=(0, 1))(s, w)
        np.testing.assert_array_equal(np.asarray(dw), 0.0)
        np.testing.assert_allclose(np.asarray(ds),
                                   np.broadcast_to(np.asarray(w.sum(1)),
                                                   (16, 256)),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_through_permutation(self):
        """The profiled permutation is applied outside the custom_vjp; the
        chain rule through the gathers must reproduce unpermuted grads."""
        s = _spikes((8, 200), 0.2, seed=9)
        w = jax.random.normal(jax.random.key(10), (200, 16)) * 0.1
        perm = ops.firing_rate_permutation(s.mean(0))

        def loss_perm(w):
            return ops.spike_gemm_train(s[:, perm], w[perm, :]).sum()

        g_perm = jax.grad(loss_perm)(w)
        g_ref = jax.grad(lambda w: (s @ w).sum())(w)
        np.testing.assert_allclose(np.asarray(g_perm), np.asarray(g_ref),
                                   atol=1e-6)


class TestSparseBackwardExactness:
    """Skipping is EXACT, not approximate: a skipped tile is all-zero and
    contributes exactly zero to the cotangent accumulate (DESIGN.md §12)."""

    @pytest.mark.parametrize("shape", [(32, 100, 10), (8, 784, 128),
                                       (5, 64, 3), (24, 333, 96)])
    @pytest.mark.parametrize("density", [0.0, 0.15, 1.0])
    def test_bwd_bitexact_vs_dense_on_grid(self, shape, density):
        """Block-skip dW/dS equal the dense jnp cotangents BIT-FOR-BIT
        across non-tile-multiple shapes.  Operands on a 1/256 grid make
        every accumulate an exact fp32 sum (the idiom of
        test_kernels.test_profiled_permutation_exact_equality), so
        summation order is irrelevant and any deviation could only come
        from a wrongly-skipped tile."""
        M, K, N = shape
        rng = np.random.default_rng(M + N)
        s = _spikes((M, K), density, seed=M)
        w = jnp.asarray(rng.integers(-64, 64, (K, N)) / 256.0,
                        dtype=jnp.float32)
        g = jnp.asarray(rng.integers(-64, 64, (M, N)) / 256.0,
                        dtype=jnp.float32)
        _, vjp = jax.vjp(
            lambda s, w: ops.spike_gemm_train(s, w, block_m=8), s, w)
        ds, dw = vjp(g)
        np.testing.assert_array_equal(np.asarray(dw),
                                      np.asarray(jnp.dot(s.T, g)))
        np.testing.assert_array_equal(np.asarray(ds),
                                      np.asarray(jnp.dot(g, w.T)))

    def test_flags_ride_the_residuals(self):
        """The forward's occupancy reduction happens once: the flags saved
        by the VJP forward are exactly ``ops.block_flags`` of the spike
        matrix, and the backward consumes them as-is."""
        s = _spikes((16, 300), 0.05, seed=2)
        w = jax.random.normal(jax.random.key(3), (300, 40)) * 0.1
        _, res = ops._spike_gemm_train_fwd((8, 128, 128, True), s, w)
        saved_s, saved_w, saved_flags = res
        np.testing.assert_array_equal(
            np.asarray(saved_flags),
            np.asarray(ops.block_flags(s, block_m=8, block_k=128)))


class TestConvVJP:
    """The conv custom_vjp (ops.spike_conv_train): patch-tiled block-skip
    forward, block-skip dW/dS backward on the forward's flags, col2im via
    the exact linear transpose of the im2col view."""

    @staticmethod
    def _inputs(shape=(2, 9, 9, 3), kernel=3, cout=5, density=0.5, seed=0):
        rng = np.random.default_rng(seed)
        s = _spikes(shape, density, seed=seed + 1)
        w = jnp.asarray(rng.integers(-64, 64,
                                     (kernel, kernel, shape[-1], cout))
                        / 256.0, dtype=jnp.float32)
        return s, w

    def test_check_grads_rev(self):
        """check_grads on the conv custom_vjp (rev mode; the dense 50% train
        keeps every patch-tile occupancy flag stable under the numeric
        perturbations, so the block-skip forward stays the linear map)."""
        s, w = self._inputs(density=0.5, seed=3)
        conv = lambda s, w: ops.spike_conv_train(s, w, block_m=8)
        check_grads(conv, (s, w), order=1, modes=["rev"],
                    atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                                (1, "VALID")])
    @pytest.mark.parametrize("density", [0.0, 0.15, 1.0])
    def test_bwd_bitexact_vs_dense_on_grid(self, stride, padding, density):
        """Block-skip conv dW/dS equal the dense ``lax.conv`` cotangents
        BIT-FOR-BIT on 1/256-grid operands (every accumulate is an exact
        fp32 sum, so any deviation could only come from a wrongly-skipped
        patch tile or a mis-scattered col2im overlap)."""
        rng = np.random.default_rng(17)
        s, w = self._inputs(shape=(2, 10, 9, 2), cout=4, density=density,
                            seed=5)
        out, vjp = jax.vjp(
            lambda s, w: ops.spike_conv_train(s, w, stride=stride,
                                              padding=padding, block_m=8),
            s, w)
        g = jnp.asarray(rng.integers(-64, 64, out.shape) / 256.0,
                        dtype=jnp.float32)
        ds, dw = vjp(g)
        _, vjp_dense = jax.vjp(
            lambda s, w: ref.spike_conv_ref(s, w, stride=stride,
                                            padding=padding), s, w)
        ds_ref, dw_ref = vjp_dense(g)
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))
        np.testing.assert_array_equal(np.asarray(ds), np.asarray(ds_ref))

    def test_flags_ride_the_residuals(self):
        """The forward's patch-occupancy reduction happens once: the flags
        saved by the VJP forward are exactly ``ops.block_flags`` of the
        im2col patch matrix, and the backward consumes them as-is (never
        recomputed)."""
        s, w = self._inputs(shape=(2, 12, 12, 2), cout=4, density=0.05,
                            seed=2)
        static = (1, "SAME", 8, 128, 128, True)
        _, res = ops._spike_conv_train_fwd(static, s, w)
        saved_s, saved_w, saved_flags = res
        patches = ops.conv_patches(s, 3, 3, 1, "SAME")
        np.testing.assert_array_equal(
            np.asarray(saved_flags),
            np.asarray(ops.block_flags(patches, block_m=8, block_k=128)))
        # the residual holds the raw spike tensor, not the patch matrix
        assert saved_s.shape == s.shape
        # and the backward driven by those residuals is the dense cotangent
        g = jnp.ones((2, 12, 12, 4), jnp.float32)
        ds, dw = ops._spike_conv_train_bwd(static, res, g)
        _, vjp_dense = jax.vjp(lambda s, w: ref.spike_conv_ref(s, w), s, w)
        ds_ref, dw_ref = vjp_dense(g)
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))
        np.testing.assert_array_equal(np.asarray(ds), np.asarray(ds_ref))

    def test_zero_train_zero_weight_grad(self):
        """An all-zero spike tensor skips every patch tile, yet the backward
        still produces the exact dense cotangents (dW = 0, dS = g * Wᵀ
        folded back through col2im)."""
        s = jnp.zeros((2, 8, 8, 2), jnp.float32)
        w = jax.random.normal(jax.random.key(0), (3, 3, 2, 4))
        ds, dw = jax.grad(
            lambda s, w: ops.spike_conv_train(s, w, block_m=8).sum(),
            argnums=(0, 1))(s, w)
        np.testing.assert_array_equal(np.asarray(dw), 0.0)
        ds_ref = jax.grad(
            lambda s: ref.spike_conv_ref(s, w).sum())(s)
        np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_ref),
                                   atol=1e-6)


class TestFusedKernelGrads:
    """ops.spike_gemm_lif_step: the fused GEMM+LIF scan step must carry the
    exact gradient contract of the unfused composition
    (spike_gemm_train + bias + lif.lif_step)."""

    def _inputs(self, seed=0, M=16, K=40, N=12):
        keys = jax.random.split(jax.random.key(seed), 4)
        s = _spikes((M, K), 0.5, seed=seed + 1)
        w = jax.random.normal(keys[0], (K, N)) * 0.1
        b = jax.random.normal(keys[1], (N,)) * 0.1
        u0 = jax.random.normal(keys[2], (M, N)) * 0.5
        s0 = _spikes((M, N), 0.3, seed=seed + 2)
        return s, w, b, u0, s0

    def test_check_grads_membrane_path(self):
        """check_grads (rev) through the fused kernel's membrane output —
        u is linear in (w, b, u_prev), so the numeric check is exact-ish.
        The spike output is a Heaviside whose surrogate gradient is
        deliberately NOT the numerical derivative (that is the point of
        surrogate training); its path is locked by the parity tests."""
        s, w, b, u0, s0 = self._inputs()

        def membrane(w, b, u0):
            u, _ = ops.spike_gemm_lif_step(s, w, b, u0, s0,
                                           beta=0.9, threshold=1.0)
            return u

        check_grads(membrane, (w, b, u0), order=1, modes=["rev"],
                    atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    def test_fused_vjp_matches_unfused(self, reset):
        """Full (gu, gs) cotangents through the fused custom_vjp equal the
        unfused composition's — including the fast-sigmoid surrogate on the
        spike output and the LIF chain rule on both reset mechanisms."""
        from repro.core.lif import LIFParams, lif_step as core_lif
        s, w, b, u0, s0 = self._inputs(seed=4)
        lif = LIFParams(beta=0.9, threshold=1.0, reset_mechanism=reset)
        kb = dict(block_m=8, block_n=128, block_k=128)

        def fused(w, b, u0, s0):
            return ops.spike_gemm_lif_step(
                s, w, b, u0, s0, beta=lif.beta, threshold=lif.threshold,
                slope=lif.slope, reset_mechanism=reset, **kb)

        def unfused(w, b, u0, s0):
            cur = ops.spike_gemm_train(s, w, **kb) + b
            return core_lif(u0, s0, cur, lif)

        gu = jax.random.normal(jax.random.key(10), u0.shape)
        gs = jax.random.normal(jax.random.key(11), u0.shape)
        outs_f, vjp_f = jax.vjp(fused, w, b, u0, s0)
        outs_u, vjp_u = jax.vjp(unfused, w, b, u0, s0)
        # identical spikes; membrane equal to fp rounding (the fused
        # epilogue and XLA's fused elementwise may associate differently)
        np.testing.assert_array_equal(np.asarray(outs_f[1]),
                                      np.asarray(outs_u[1]))
        np.testing.assert_allclose(np.asarray(outs_f[0]),
                                   np.asarray(outs_u[0]), atol=1e-6)
        _assert_tree_allclose(vjp_f((gu, gs)), vjp_u((gu, gs)),
                              atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    def test_fused_dw_bitexact_on_grid(self, reset):
        """The fused backward's dW is bit-for-bit the dense cotangent on
        grid-quantized operands, under both reset mechanisms — the skipped
        spike tiles contribute exactly zero through the fused path too."""
        rng = np.random.default_rng(7)
        M, K, N = 24, 300, 20
        s = _spikes((M, K), 0.1, seed=9)
        w = jnp.asarray(rng.integers(-64, 64, (K, N)) / 256.0,
                        dtype=jnp.float32)
        b = jnp.zeros((N,), jnp.float32)
        u0 = jnp.zeros((M, N), jnp.float32)
        s0 = jnp.zeros((M, N), jnp.float32)
        gu = jnp.asarray(rng.integers(-64, 64, (M, N)) / 256.0,
                         dtype=jnp.float32)

        def fused(w):
            return ops.spike_gemm_lif_step(
                s, w, b, u0, s0, beta=0.9, threshold=1.0,
                reset_mechanism=reset, block_m=8)

        _, vjp = jax.vjp(fused, w)
        # gs = 0 keeps the surrogate factor out so g stays on the grid
        (dw,) = vjp((gu, jnp.zeros_like(gu)))
        np.testing.assert_array_equal(np.asarray(dw),
                                      np.asarray(jnp.dot(s.T, gu)))


class TestLossGradParity:
    """Full surrogate-gradient BPTT through lax.scan, both backends."""

    def _cfg(self, reset="subtract", K=100, hidden=33, classes=10):
        lif = LIFParams(reset_mechanism=reset)
        side = int(np.sqrt(K))
        return snn.SNNConfig(
            name=f"g-{reset}", input_shape=(side, side),
            layers=(snn.Dense(hidden, lif=lif), snn.Dense(classes, lif=lif)),
            num_classes=classes, num_steps=5)

    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    def test_loss_grads_match(self, reset):
        cfg = self._cfg(reset)
        params = snn.init_params(jax.random.key(0), cfg)
        x = jax.random.uniform(jax.random.key(1), (16, 100))
        y = jax.random.randint(jax.random.key(2), (16,), 0, cfg.num_classes)
        key = jax.random.key(3)
        grads = {}
        vals = {}
        for backend in snn.MATMUL_BACKENDS:
            vals[backend], grads[backend] = jax.value_and_grad(
                lambda p: train_snn.loss_fn(cfg, p, key, x, y,
                                            matmul_backend=backend))(params)
        for backend in snn.MATMUL_BACKENDS[1:]:
            np.testing.assert_allclose(float(vals["jnp"]),
                                       float(vals[backend]), rtol=1e-6)
            _assert_tree_allclose(grads["jnp"], grads[backend],
                                  atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("density", [0.0, 1.0])
    def test_degenerate_input_trains(self, density):
        """All-zero and all-one input spike trains through the full net."""
        cfg = self._cfg("subtract", K=64, hidden=24, classes=4)
        params = snn.init_params(jax.random.key(5), cfg)
        spikes_in = _spikes((cfg.num_steps, 8, 64), density)
        y = jnp.arange(8) % 4

        def loss(p, backend):
            out = snn.apply(cfg, p, spikes_in, matmul_backend=backend)
            from repro.core import encoding
            return encoding.rate_loss(out, y, cfg.num_classes)

        va, ga = jax.value_and_grad(loss)(params, "jnp")
        for backend in snn.MATMUL_BACKENDS[1:]:
            vb, gb = jax.value_and_grad(loss)(params, backend)
            np.testing.assert_allclose(float(va), float(vb), rtol=1e-6)
            _assert_tree_allclose(ga, gb, atol=1e-6, rtol=1e-6)

    def test_forward_values_match(self):
        """Spike-for-spike identical forward trains (binary outputs make
        exact equality the right assertion)."""
        cfg = self._cfg("zero")
        params = snn.init_params(jax.random.key(7), cfg)
        x = jax.random.uniform(jax.random.key(8), (4, 100))
        from repro.core import encoding
        spikes_in = encoding.rate_encode(jax.random.key(9), x, cfg.num_steps)
        out_j = snn.apply(cfg, params, spikes_in, matmul_backend="jnp",
                          return_all_layers=True)
        for backend in snn.MATMUL_BACKENDS[1:]:
            out_k = snn.apply(cfg, params, spikes_in,
                              matmul_backend=backend,
                              return_all_layers=True)
            for a, b in zip(out_j, out_k):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
