"""Tests for the model-hardware co-exploration engine: model axes in the
declarative space, cell factorization, accuracy as a Pareto objective,
exact hardware-numerics equivalence with the PR-1 engine on a fixed model
cell, and train-exactly-once semantics via the trace cache."""
import dataclasses

import numpy as np
import pytest

from repro.core import dse, snn, workloads
from repro.core.accelerator import arch, cycle_model


def _tiny_wl():
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name="co-test-wl",
        layers=(snn.Dense(12),), pcr=1,
        n_train=128, n_test=64, train_steps=4, trace_samples=16)


def _tiny_conv():
    return dataclasses.replace(
        workloads.get("dvs-conv"), name="co-test-dvs",
        layers=(snn.Conv(2, 3), snn.MaxPool(2), snn.Dense(6)),
        num_classes=4, pcr=1, n_train=32, n_test=16, train_steps=2,
        batch_size=16, trace_samples=8)


@pytest.fixture(scope="module")
def shared_cache(tmp_path_factory):
    """One cache for the whole module so each cell trains exactly once."""
    return workloads.TraceCache(root=str(tmp_path_factory.mktemp("cells")))


class TestModelAxes:
    def test_add_model_and_factorization(self):
        cfg = arch.from_layer_sizes("t", (100, 50, 20), num_steps=4)
        space = (dse.SearchSpace(cfg)
                 .add_model("num_steps", (4, 8))
                 .add_model("population", (0.5, 1.0))
                 .add_per_layer("lhr", [[1, 2], [1, 2]]))
        assert space.size == 2 * 2 * 2 * 2
        assert [ax.name for ax in space.model_axes] == ["num_steps",
                                                        "population"]
        assert [ax.name for ax in space.hw_axes] == ["lhr", "lhr"]
        cells = list(space.model_cells())
        assert len(cells) == 4
        assert cells[0] == {"num_steps": 4, "population": 0.5}
        assert cells[-1] == {"num_steps": 8, "population": 1.0}

    def test_add_model_rejects_hardware_names(self):
        cfg = arch.from_layer_sizes("t", (100, 50), num_steps=2)
        with pytest.raises(ValueError, match="unknown model axis"):
            dse.SearchSpace(cfg).add_model("lhr", (1, 2))
        with pytest.raises(ValueError, match="model axis"):
            dse.SearchSpace(cfg).add_global("num_steps", (4, 8))

    def test_search_rejects_model_axes(self):
        cfg = arch.from_layer_sizes("t", (100, 50), num_steps=2)
        space = (dse.SearchSpace(cfg)
                 .add_per_layer("lhr", [[1, 2]])
                 .add_model("num_steps", (2, 4)))
        with pytest.raises(ValueError, match="coexplore"):
            dse.search(cfg, [np.ones(2)], space)

    def test_hardware_subspace_rebinds_and_clamps_lhr(self):
        big = arch.from_layer_sizes("big", (100, 64), num_steps=2)
        small = arch.from_layer_sizes("small", (100, 3), num_steps=2)
        space = (dse.SearchSpace(big)
                 .add_model("num_steps", (2, 4))
                 .add_per_layer("lhr", [[1, 4, 16, 64]])
                 .add_global("weight_bits", (4, 8)))
        sub = space.hardware_subspace(small)
        assert not sub.model_axes
        lhr_ax = [ax for ax in sub.axes if ax.name == "lhr"][0]
        assert lhr_ax.values == (1, 3)        # 4/16/64 clamp to 3, deduped
        assert sub.size == 2 * 2

    def test_hardware_subspace_joint_axes_checked_and_clamped(self):
        big = arch.from_layer_sizes("big", (100, 64, 32), num_steps=2)
        small = arch.from_layer_sizes("small", (100, 3, 2), num_steps=2)
        space = (dse.SearchSpace(big)
                 .add_model("num_steps", (2,))
                 .add_joint("lhr", [(1, 1), (64, 32)]))
        sub = space.hardware_subspace(small)
        assert sub.axes[0].values == ((1, 1), (3, 2))   # clamped per layer
        narrow = arch.from_layer_sizes("narrow", (100, 3), num_steps=2)
        with pytest.raises(ValueError, match="hw_space"):
            space.hardware_subspace(narrow)

    def test_no_model_axes_single_empty_cell(self):
        cfg = arch.from_layer_sizes("t", (100, 50), num_steps=2)
        space = dse.SearchSpace(cfg).add_per_layer("lhr", [[1, 2]])
        assert list(space.model_cells()) == [{}]


class TestCoExplore:
    def test_joint_sweep_accuracy_aware_frontier(self, shared_cache):
        """The acceptance sweep: (num_steps x population x per-layer LHR x
        weight_bits) in ONE call, accuracy-aware frontier out."""
        res = dse.coexplore(_tiny_wl(), num_steps=(2, 3),
                            population=(0.5, 1.0), max_lhr=4,
                            weight_bits=(4, 8), cache=shared_cache,
                            chunk_size=32)
        assert len(res.cells) == 4
        # 2 layers x 3 lhr options x 2 bits = 18 hw candidates per cell
        assert res.n_evaluated == 4 * (3 * 3 * 2)
        fr = res.frontier
        assert 0 < len(fr) <= res.n_evaluated
        for col in ("num_steps", "population", "lhr", "weight_bits",
                    "accuracy", "error", "cycles", "lut", "bram", "energy"):
            assert col in fr.columns, col
        np.testing.assert_allclose(fr.columns["error"],
                                   1.0 - fr.columns["accuracy"])
        # frontier is mutually non-dominated over the objectives
        obj = np.stack([fr.columns[k] for k in res.objectives], axis=1)
        assert dse.pareto_mask_k(obj).all()
        # accuracy column follows the cell's quantized table
        cell = {(c.assignment["num_steps"], c.assignment["population"]):
                c for c in res.cells}
        for i in range(len(fr)):
            r = fr.row(i)
            c = cell[(r["num_steps"], r["population"])]
            assert r["accuracy"] == c.quant_acc[r["weight_bits"]]

    def test_conv_cells_get_quantized_accuracy(self, shared_cache):
        """The unlocked path: a conv cell on the weight_bits axis reports
        the FIXED-POINT conv-datapath accuracy (per-bits quant_acc table),
        not the float-accuracy fallback the old rate-MLP-only gate forced."""
        res = dse.coexplore(_tiny_conv(), num_steps=(2,), max_lhr=2,
                            weight_bits=(4, 8), cache=shared_cache)
        (cell,) = res.cells
        assert set(cell.quant_acc) == {4, 8}        # measured, not skipped
        fr = res.frontier
        assert "weight_bits" in fr.columns
        for i in range(len(fr)):
            r = fr.row(i)
            assert r["accuracy"] == cell.quant_acc[r["weight_bits"]]

    def test_each_cell_trains_exactly_once(self, shared_cache):
        """Repeat of the acceptance sweep: zero new training, identical
        frontier."""
        misses_before = shared_cache.misses
        res = dse.coexplore(_tiny_wl(), num_steps=(2, 3),
                            population=(0.5, 1.0), max_lhr=4,
                            weight_bits=(4, 8), cache=shared_cache,
                            chunk_size=32)
        assert shared_cache.misses == misses_before
        assert all(c.cache_hit for c in res.cells)

    def test_fixed_cell_matches_hardware_only_engine_exactly(
            self, shared_cache):
        """With the model axes pinned, coexplore's hardware numerics equal
        dse.search on the same cell, row for row."""
        wl = _tiny_wl()
        res = dse.coexplore(wl, num_steps=(3,), population=(1.0,),
                            max_lhr=4, cache=shared_cache)
        art = shared_cache.resolve(wl, {"num_steps": 3, "population": 1.0})
        assert art.cache_hit
        accel = arch.from_snn_config(art.snn_cfg)
        counts = cycle_model.counts_from_traces(art.counts)
        ref = dse.search(accel, counts,
                         dse.SearchSpace.product_lhr(accel, max_lhr=4),
                         objectives=("cycles", "lut", "energy"))
        def rows(t):
            return sorted((tuple(t.columns["lhr"][i]), t.columns["cycles"][i],
                           t.columns["lut"][i], t.columns["energy"][i])
                          for i in range(len(t)))
        assert rows(res.frontier) == rows(ref.frontier)

    def test_declared_space_path(self, shared_cache):
        """Model + hardware axes declared in ONE SearchSpace."""
        wl = _tiny_wl()
        tmpl = arch.from_snn_config(wl.build(2, 1.0))
        space = (dse.SearchSpace(tmpl)
                 .add_model("num_steps", (2, 3))
                 .add_per_layer("lhr", [[1, 2, 4] for _ in tmpl.layers])
                 .add_global("weight_bits", (4, 8)))
        res = dse.coexplore(wl, space, cache=shared_cache)
        assert len(res.cells) == 2
        assert res.n_evaluated == 2 * (3 * 3 * 2)
        assert all(c.cache_hit for c in res.cells)   # cells shared w/ above

    def test_keep_all_and_best_under(self, shared_cache):
        res = dse.coexplore(_tiny_wl(), num_steps=(2, 3),
                            population=(1.0,), max_lhr=4,
                            cache=shared_cache, keep_all=True)
        assert len(res.table) == res.n_evaluated
        worst = float(np.max(res.table.columns["error"]))
        row = res.best_under("cycles", error=worst)
        assert row is not None
        ok = res.table.columns["error"] <= worst
        assert row["cycles"] == float(
            np.min(np.asarray(res.table.columns["cycles"])[ok]))
        assert res.best_under("cycles", error=-1.0) is None

    def test_objective_validation(self, shared_cache):
        with pytest.raises(ValueError, match="use 'error'"):
            dse.coexplore(_tiny_wl(), num_steps=(2,),
                          objectives=("accuracy", "cycles"),
                          cache=shared_cache)
        with pytest.raises(ValueError, match="unknown objective"):
            dse.coexplore(_tiny_wl(), num_steps=(2,),
                          objectives=("latency",), cache=shared_cache)
        with pytest.raises(ValueError, match="workload"):
            dse.coexplore(num_steps=(2,), cache=shared_cache)

    def test_cross_topology_dataset_axis(self, shared_cache):
        """End-to-end mixed-topology sweep: dataset axis, -1 padding of the
        narrower cell's per-layer columns, string dataset column surviving
        the frontier merge.  Workload instances pass straight through the
        ``datasets=`` kwarg without registry registration."""
        mlp = _tiny_wl()                               # 2 spiking layers
        conv = _tiny_conv()                            # 3 spiking layers
        res = dse.coexplore(datasets=(mlp, conv), num_steps=(2,),
                            max_lhr=2, cache=shared_cache)
        assert len(res.cells) == 2
        fr = res.frontier
        assert set(fr.columns["dataset"]) <= {"co-test-wl", "co-test-dvs"}
        lhr = np.asarray(fr.columns["lhr"])
        assert lhr.shape[1] == 3                       # widest cell
        is_mlp = np.asarray(fr.columns["dataset"]) == "co-test-wl"
        assert is_mlp.any() and (~is_mlp).any()        # both survive a tie
        assert (lhr[is_mlp, 2] == -1).all()            # absent layer padded
        assert (lhr[~is_mlp] >= 1).all()

    def test_dataset_axis_in_space_normalizes_instances(self, shared_cache):
        """Workload instances declared via add_model('dataset', ...) reach
        the frontier as names, same as the datasets= kwarg path."""
        mlp, conv = _tiny_wl(), _tiny_conv()
        tmpl = arch.from_snn_config(mlp.build(2, 1.0))
        space = (dse.SearchSpace(tmpl)
                 .add_model("dataset", (mlp, conv))
                 .add_model("num_steps", (2,)))
        res = dse.coexplore(space=space, max_lhr=2, cache=shared_cache)
        assert sorted(c.workload for c in res.cells) == ["co-test-dvs",
                                                         "co-test-wl"]
        assert set(res.frontier.columns["dataset"]) <= {"co-test-wl",
                                                        "co-test-dvs"}

    def test_mismatched_default_num_steps_rejected(self, shared_cache):
        """Omitting num_steps across workloads with different declared
        choices must raise, not silently sweep the first one's choices."""
        with pytest.raises(ValueError, match="num_steps_choices"):
            dse.coexplore(datasets=(_tiny_wl(), _tiny_conv()),
                          cache=shared_cache)

    def test_unknown_hw_axis_rejected_before_training(self, tmp_path):
        """A typo'd hardware axis name fails in the prepass, not after the
        first cell has trained."""
        wl = _tiny_wl()
        fresh = workloads.TraceCache(root=str(tmp_path))
        with pytest.raises(ValueError, match="evaluator"):
            dse.coexplore(
                wl, num_steps=(2,), cache=fresh,
                hw_space=lambda c: dse.SearchSpace(c).add_global(
                    "clock", (100, 200)))
        with pytest.raises(ValueError, match="no axes"):
            dse.coexplore(wl, num_steps=(2,), cache=fresh,
                          hw_space=lambda c: dse.SearchSpace(c))
        assert fresh.stats == {"hits": 0, "misses": 0}

    def test_inconsistent_hw_space_rejected_before_training(self, tmp_path):
        """A hw_space callable emitting different axis sets per cell fails
        upfront — before any cell trains."""
        wl = _tiny_wl()
        calls = []

        def hw(cfg):
            sub = dse.SearchSpace.product_lhr(cfg, max_lhr=2)
            if not calls:
                sub.add_global("weight_bits", (4,))
            calls.append(1)
            return sub

        fresh = workloads.TraceCache(root=str(tmp_path))
        with pytest.raises(ValueError, match="share axis names"):
            dse.coexplore(wl, num_steps=(2, 3), hw_space=hw, cache=fresh)
        assert fresh.stats == {"hits": 0, "misses": 0}

    def test_space_model_axes_and_kwargs_conflict(self, shared_cache):
        """Model axes may come from the space OR the kwargs, never both —
        mixing used to silently drop the kwargs."""
        wl = _tiny_wl()
        tmpl = arch.from_snn_config(wl.build(2, 1.0))
        space = (dse.SearchSpace(tmpl)
                 .add_model("num_steps", (2, 3))
                 .add_per_layer("lhr", [[1, 2] for _ in tmpl.layers]))
        with pytest.raises(ValueError, match="one declaration style"):
            dse.coexplore(wl, space, datasets=("mnist-mlp",),
                          cache=shared_cache)
        with pytest.raises(ValueError, match="one declaration style"):
            dse.coexplore(wl, space, population=(0.5,), cache=shared_cache)

    def test_hw_kwargs_and_custom_subspace_conflict(self, shared_cache):
        """max_lhr / weight_bits only shape the DEFAULT hardware subspace —
        next to a declared one they used to be silently dropped."""
        wl = _tiny_wl()
        tmpl = arch.from_snn_config(wl.build(2, 1.0))
        space = (dse.SearchSpace(tmpl)
                 .add_model("num_steps", (2,))
                 .add_per_layer("lhr", [[1, 2] for _ in tmpl.layers]))
        with pytest.raises(ValueError, match="one declaration style"):
            dse.coexplore(wl, space, weight_bits=(4, 8), cache=shared_cache)
        with pytest.raises(ValueError, match="one declaration style"):
            dse.coexplore(
                wl, num_steps=(2,), max_lhr=4, cache=shared_cache,
                hw_space=lambda c: dse.SearchSpace.product_lhr(c, max_lhr=2))
