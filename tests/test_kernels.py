"""Per-kernel correctness: sweep shapes/dtypes in interpret mode and assert
allclose against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _lif_inputs(shape, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    u = jax.random.normal(k1, shape, dtype)
    s = (jax.random.uniform(k2, shape) < 0.3).astype(dtype)
    c = jax.random.normal(k3, shape, dtype)
    return u, s, c


class TestLIFKernel:
    @pytest.mark.parametrize("shape", [(8, 512), (1, 100), (3, 700), (16, 2048),
                                       (5, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        u, s, c = _lif_inputs(shape, dtype)
        got_u, got_s = ops.lif_step(u, s, c, beta=0.9, threshold=1.0)
        want_u, want_s = ref.lif_step_ref(u, s, c, beta=0.9, threshold=1.0)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got_u, np.float32),
                                   np.asarray(want_u, np.float32), atol=tol)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))

    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    @pytest.mark.parametrize("beta,threshold", [(0.5, 1.0), (0.95, 0.5),
                                                (0.23, 2.0)])
    def test_parameter_sweep(self, reset, beta, threshold):
        u, s, c = _lif_inputs((4, 300), jnp.float32, seed=7)
        got = ops.lif_step(u, s, c, beta=beta, threshold=threshold,
                           reset_mechanism=reset)
        want = ref.lif_step_ref(u, s, c, beta=beta, threshold=threshold,
                                reset_mechanism=reset)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))

    def test_agrees_with_core_lif(self):
        """The kernel implements the same forward as repro.core.lif."""
        from repro.core.lif import LIFParams, lif_step as core_step
        u, s, c = _lif_inputs((2, 64), jnp.float32, seed=3)
        got_u, got_s = ops.lif_step(u, s, c, beta=0.9, threshold=1.0)
        want_u, want_s = core_step(u, s, c, LIFParams(beta=0.9, threshold=1.0))
        np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


class TestSpikeGemm:
    @pytest.mark.parametrize("shape", [(128, 128, 128), (256, 512, 128),
                                       (100, 333, 77), (8, 1024, 64),
                                       (1, 784, 500)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
    def test_matches_dense_ref(self, shape, dtype, density):
        M, K, N = shape
        k1, k2 = jax.random.split(jax.random.key(42))
        s = (jax.random.uniform(k1, (M, K)) < density).astype(dtype)
        w = (jax.random.normal(k2, (K, N)) * 0.1).astype(dtype)
        got = ops.spike_gemm(s, w)
        want = ref.spike_gemm_ref(s, w)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("blocks", [(128, 128, 128), (8, 128, 256)])
    def test_block_shape_sweep(self, blocks):
        bm, bk, bn = blocks
        k1, k2 = jax.random.split(jax.random.key(1))
        s = (jax.random.uniform(k1, (64, 300)) < 0.1).astype(jnp.float32)
        w = jax.random.normal(k2, (300, 200), jnp.float32)
        got = ops.spike_gemm(s, w, block_m=bm, block_k=bk, block_n=bn)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.spike_gemm_ref(s, w)),
                                   atol=1e-4)

    def test_all_zero_input_skips_everything(self):
        s = jnp.zeros((128, 256), jnp.float32)
        w = jnp.ones((256, 128), jnp.float32)
        out = ops.spike_gemm(s, w)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        assert ops.skip_fraction(s) == 1.0

    @pytest.mark.parametrize("seed", range(3))
    def test_flags_complete_and_sound(self, seed):
        """Property: a flag is 0 iff its tile holds no spikes."""
        rng = np.random.default_rng(seed)
        s = (rng.random((256, 512)) < 0.01).astype(np.float32)
        flags = np.asarray(ref.block_flags_ref(jnp.asarray(s), 128, 128))
        tiles = s.reshape(2, 128, 4, 128).sum((1, 3))
        np.testing.assert_array_equal(flags, (tiles > 0).astype(np.int32))

    def test_uniform_sparsity_rarely_skips(self):
        """Documenting the tile-granularity gap: uniformly-spread 1% firing
        leaves essentially no 8x128 tile empty (see ops.py commentary)."""
        rng = np.random.default_rng(0)
        s = (rng.random((8, 4096)) < 0.01).astype(np.float32)
        frac = ops.skip_fraction(jnp.asarray(s), block_m=8, block_k=128)
        assert frac < 0.05

    def test_profiled_permutation_unlocks_skips(self):
        """Heavy-tailed firing + profile-guided permutation -> real skips,
        with bit-exact results."""
        rng = np.random.default_rng(0)
        K = 4096
        rates = np.where(rng.random(K) < 0.85, 0.001, 0.15)  # heavy tail
        s = (rng.random((32, K)) < rates).astype(np.float32)
        w = rng.normal(size=(K, 256)).astype(np.float32) * 0.1
        base_skip = ops.skip_fraction(jnp.asarray(s), 8, 128)
        perm = ops.firing_rate_permutation(jnp.asarray(s.mean(0)))
        sp, wp = ops.apply_permutation(jnp.asarray(s), jnp.asarray(w), perm)
        perm_skip = ops.skip_fraction(sp, 8, 128)
        assert perm_skip > base_skip + 0.3, (base_skip, perm_skip)
        out = ops.spike_gemm_profiled(jnp.asarray(s), jnp.asarray(w), perm,
                                      block_m=8)
        np.testing.assert_allclose(np.asarray(out), s @ w, atol=1e-3)

    def test_gradient_path_via_ref(self):
        """The oracle's implicit gradient (what the custom_vjp backward
        reproduces — see tests/test_kernel_grads.py for the kernel side)."""
        s = (jax.random.uniform(jax.random.key(0), (16, 32)) < 0.3
             ).astype(jnp.float32)
        w = jax.random.normal(jax.random.key(1), (32, 8))
        g = jax.grad(lambda w: ref.spike_gemm_ref(s, w).sum())(w)
        np.testing.assert_allclose(np.asarray(g),
                                   np.asarray(jnp.broadcast_to(s.sum(0)[:, None],
                                                               (32, 8))))


class TestSpikeGemmBwdKernels:
    """Block-skip backward kernels (spike_gemm_bwd.py) vs the dense
    oracles: dW = Sᵀ·g on the forward's flags, dS = g·Wᵀ on any-nonzero
    cotangent occupancy."""

    @pytest.mark.parametrize("shape", [(128, 128, 128), (100, 333, 77),
                                       (8, 1024, 64), (1, 784, 500)])
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
    def test_dw_matches_dense_ref(self, shape, density):
        M, K, N = shape
        k1, k2 = jax.random.split(jax.random.key(13))
        s = (jax.random.uniform(k1, (M, K)) < density).astype(jnp.float32)
        g = jax.random.normal(k2, (M, N), jnp.float32)
        got = ops.spike_gemm_bwd_dw(s, g, block_m=8)
        _, want = ref.spike_gemm_bwd_ref(s, jnp.zeros((K, N)), g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("shape", [(128, 128, 128), (100, 333, 77),
                                       (8, 1024, 64)])
    def test_ds_matches_dense_ref(self, shape):
        M, K, N = shape
        k1, k2 = jax.random.split(jax.random.key(14))
        g = jax.random.normal(k1, (M, N), jnp.float32)
        w = jax.random.normal(k2, (K, N), jnp.float32) * 0.1
        got = ops.spike_gemm_bwd_ds(g, w, block_m=8)
        want, _ = ref.spike_gemm_bwd_ref(jnp.zeros((M, K)), w, g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_dw_skip_vs_dense_flags_bitident(self):
        """Property behind the sparse backward: running the dW kernel with
        the real (skipping) flags is bit-identical to running it with every
        flag forced on — a skipped tile contributes exactly zero."""
        k1, k2 = jax.random.split(jax.random.key(15))
        s = (jax.random.uniform(k1, (40, 700)) < 0.2).astype(jnp.float32)
        s = s.at[8:24, :].set(0.0).at[:, 256:512].set(0.0)
        g = jax.random.normal(k2, (40, 60), jnp.float32)
        flags = ops.block_flags(s, block_m=8, block_k=128)
        assert float(flags.mean()) < 1.0          # something is skipped
        a = ops.spike_gemm_bwd_dw(s, g, flags=flags, block_m=8)
        b = ops.spike_gemm_bwd_dw(s, g, flags=jnp.ones_like(flags),
                                  block_m=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ds_skip_vs_dense_flags_bitident(self):
        """Same property on the dS side, gated on cotangent occupancy:
        zero out whole (8, 128) tiles of g and the gated kernel matches the
        all-flags-on kernel bit-for-bit."""
        k1, k2 = jax.random.split(jax.random.key(16))
        g = jax.random.normal(k1, (24, 256), jnp.float32)
        g = g.at[8:16, :].set(0.0).at[:, 128:].set(0.0)
        w = jax.random.normal(k2, (300, 256), jnp.float32) * 0.1
        gflags = ops.cotangent_block_flags(g, block_m=8, block_n=128)
        assert float(gflags.mean()) < 1.0
        a = ops.spike_gemm_bwd_ds(g, w, gflags=gflags, block_m=8)
        b = ops.spike_gemm_bwd_ds(g, w, gflags=jnp.ones_like(gflags),
                                  block_m=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cancelling_cotangent_tile_not_skipped(self):
        """A signed tile whose entries sum to zero still holds work: the
        sum>0 spike-flag reduction would wrongly skip it, the any-nonzero
        cotangent reduction must not (and dS must stay exact)."""
        g = jnp.zeros((8, 256), jnp.float32)
        g = g.at[0, 0].set(1.0).at[1, 1].set(-1.0)   # tile sums to zero
        w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 256)),
                        dtype=jnp.float32)
        spike_style = np.asarray(ref.block_flags_ref(g, 8, 128))
        any_style = np.asarray(ref.block_flags_any_ref(g, 8, 128))
        assert spike_style[0, 0] == 0 and any_style[0, 0] == 1
        got = ops.spike_gemm_bwd_ds(g, w, block_m=8)
        want, _ = ref.spike_gemm_bwd_ref(jnp.zeros((8, 64)), w, g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_all_zero_cotangent_skips_everything(self):
        g = jnp.zeros((16, 128), jnp.float32)
        w = jnp.ones((256, 128), jnp.float32)
        out = ops.spike_gemm_bwd_ds(g, w, block_m=8)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        assert float(ops.cotangent_block_flags(g, block_m=8,
                                               block_n=128).sum()) == 0.0


class TestFusedGemmLifKernel:
    """spike_gemm_fused.py forward vs the composed oracle."""

    @pytest.mark.parametrize("shape", [(8, 128, 128), (16, 300, 50),
                                       (5, 100, 33), (1, 784, 500)])
    @pytest.mark.parametrize("reset", ["subtract", "zero"])
    def test_matches_composed_ref(self, shape, reset):
        M, K, N = shape
        keys = jax.random.split(jax.random.key(21), 5)
        s = (jax.random.uniform(keys[0], (M, K)) < 0.2).astype(jnp.float32)
        w = jax.random.normal(keys[1], (K, N)) * 0.1
        b = jax.random.normal(keys[2], (N,)) * 0.1
        u0 = jax.random.normal(keys[3], (M, N))
        s0 = (jax.random.uniform(keys[4], (M, N)) < 0.3).astype(jnp.float32)
        got_u, got_s = ops.spike_gemm_lif_step(
            s, w, b, u0, s0, beta=0.9, threshold=1.0,
            reset_mechanism=reset, block_m=8)
        want_u, want_s = ref.spike_gemm_lif_ref(
            s, w, b, u0, s0, beta=0.9, threshold=1.0,
            reset_mechanism=reset)
        np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))

    def test_all_zero_train_is_pure_lif(self):
        """Every spike tile skipped: the accumulate contributes nothing and
        the epilogue reduces to the bare LIF update on the bias current."""
        M, K, N = 8, 256, 64
        s = jnp.zeros((M, K), jnp.float32)
        w = jax.random.normal(jax.random.key(0), (K, N))
        b = jnp.full((N,), 0.3, jnp.float32)
        u0 = jax.random.normal(jax.random.key(1), (M, N))
        s0 = jnp.zeros((M, N), jnp.float32)
        got_u, got_s = ops.spike_gemm_lif_step(s, w, b, u0, s0,
                                               beta=0.9, threshold=1.0,
                                               block_m=8)
        want_u, want_s = ref.lif_step_ref(
            u0, s0, jnp.broadcast_to(b, (M, N)), beta=0.9, threshold=1.0)
        np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


class TestKernelPlumbing:
    """Property/edge tests for the wrapper layer the training path rides:
    padding, occupancy flags, skip_fraction caching, PENC edges, and the
    profiled permutation's exact-equality invariance."""

    @pytest.mark.parametrize("shape,mults", [((8, 128), (8, 128)),
                                             ((64, 512), (8, 128)),
                                             ((128, 256), (128, 128))])
    def test_pad_to_noop_on_aligned_shapes(self, shape, mults):
        x = jnp.ones(shape)
        assert ops._pad_to(x, mults) is x       # no copy, not even identity

    def test_pad_to_pads_with_zeros(self):
        x = jnp.ones((5, 100))
        padded = ops._pad_to(x, (8, 128))
        assert padded.shape == (8, 128)
        np.testing.assert_array_equal(np.asarray(padded[:5, :100]), 1.0)
        assert float(padded.sum()) == 500.0     # padding contributed nothing

    @pytest.mark.parametrize("seed", range(3))
    def test_skip_fraction_consistent_with_flags(self, seed):
        """skip_fraction (jitted) == 1 - mean(block_flags_ref) on the padded
        matrix, for ragged shapes."""
        rng = np.random.default_rng(seed)
        s = jnp.asarray((rng.random((37, 300)) < 0.02).astype(np.float32))
        flags = ref.block_flags_ref(ops._pad_to(s, (8, 128)), 8, 128)
        want = float(1.0 - np.asarray(flags, np.float32).mean())
        assert ops.skip_fraction(s, 8, 128) == pytest.approx(want, abs=1e-7)

    def test_spike_gemm_reuses_caller_flags(self):
        """Precomputed block_flags short-circuit the in-call reduction and
        give bit-identical output."""
        k1, k2 = jax.random.split(jax.random.key(5))
        s = (jax.random.uniform(k1, (40, 300)) < 0.05).astype(jnp.float32)
        w = jax.random.normal(k2, (300, 150), jnp.float32)
        flags = ops.block_flags(s, block_m=8, block_k=128)
        got = ops.spike_gemm(s, w, flags=flags, block_m=8)
        want = ops.spike_gemm(s, w, block_m=8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_spike_gemm_rejects_mismatched_flags(self):
        s = jnp.ones((16, 256), jnp.float32)
        w = jnp.ones((256, 128), jnp.float32)
        bad = jnp.ones((1, 1), jnp.int32)
        with pytest.raises(ValueError, match="tile grid"):
            ops.spike_gemm(s, w, flags=bad, block_m=8)

    def test_penc_empty_rows(self):
        """Rows with no spikes compact to all -1 addresses and count 0."""
        s = jnp.zeros((4, 96), jnp.float32)
        idx, cnt = ops.penc_compact(s, capacity=32)
        np.testing.assert_array_equal(np.asarray(idx), -1)
        np.testing.assert_array_equal(np.asarray(cnt), 0)

    def test_penc_mixed_overflow_and_empty(self):
        """Capacity overflow (dense row) and empty row side by side: the
        dense row keeps its first ``capacity`` addresses but reports the
        true spike count; the empty row stays untouched."""
        s = jnp.stack([jnp.ones(64, jnp.float32), jnp.zeros(64, jnp.float32)])
        idx, cnt = ops.penc_compact(s, capacity=8)
        np.testing.assert_array_equal(np.asarray(idx[0]), np.arange(8))
        assert int(cnt[0]) == 64
        np.testing.assert_array_equal(np.asarray(idx[1]), -1)
        assert int(cnt[1]) == 0

    @pytest.mark.parametrize("seed", range(3))
    def test_profiled_permutation_exact_equality(self, seed):
        """Permutation invariance holds EXACTLY, not just to tolerance:
        with weights on a 1/256 grid every accumulate is an exact fp32 sum,
        so reordering the heavy-tailed pre-synaptic axis cannot change a
        single bit of the output."""
        rng = np.random.default_rng(seed)
        K = 1024
        rates = np.where(rng.random(K) < 0.8, 0.002, 0.2)
        s = jnp.asarray((rng.random((24, K)) < rates).astype(np.float32))
        w = jnp.asarray(rng.integers(-64, 64, size=(K, 96)) / 256.0,
                        dtype=jnp.float32)
        perm = ops.firing_rate_permutation(s.mean(0))
        got = ops.spike_gemm_profiled(s, w, perm, block_m=8)
        want = ops.spike_gemm(s, w, block_m=8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and the permutation is a real permutation of the axis
        assert sorted(np.asarray(perm).tolist()) == list(range(K))


class TestSpikeConvKernel:
    """Patch-tiled block-skip convolution (spike_conv.py) vs the dense
    ``lax.conv`` oracle — bit-for-bit on 1/256-grid weights, because with
    grid operands every fp32 accumulate is exact and tile order (or
    skipping) cannot change a single bit."""

    @staticmethod
    def _inputs(shape, kernel, cout, density, seed=0):
        rng = np.random.default_rng(seed)
        B, H, W, C = shape
        s = jnp.asarray((rng.random((B, H, W, C)) < density)
                        .astype(np.float32))
        w = jnp.asarray(rng.integers(-64, 64, (kernel, kernel, C, cout))
                        / 256.0, dtype=jnp.float32)
        return s, w

    @pytest.mark.parametrize("shape", [(2, 9, 9, 3), (1, 12, 10, 2),
                                       (3, 8, 8, 1), (2, 7, 11, 2)])
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
    @pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                                (1, "VALID"), (2, "VALID")])
    def test_matches_dense_conv_oracle(self, shape, density, stride, padding):
        """Non-tile-multiple spatial shapes (M = B·OH·OW and K = KH·KW·C both
        ragged against the 8x128 grid): exact equality with XLA's conv."""
        s, w = self._inputs(shape, 3, 5, density)
        got = ops.spike_conv(s, w, stride=stride, padding=padding,
                             block_m=8)
        want = ref.spike_conv_ref(s, w, stride=stride, padding=padding)
        assert got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_skip_vs_all_ones_flags_bitident(self):
        """Running the conv kernel with the real (skipping) patch flags is
        bit-identical to forcing every flag on: an empty patch tile holds
        receptive fields that saw no spikes and contributes exactly zero."""
        rng = np.random.default_rng(3)
        s = (rng.random((4, 16, 16, 2)) < 0.2).astype(np.float32)
        s[:2] = 0.0                      # whole samples silent -> empty tiles
        s = jnp.asarray(s)
        w = jnp.asarray(rng.integers(-64, 64, (3, 3, 2, 6)) / 256.0,
                        dtype=jnp.float32)
        patches = ops.conv_patches(s, 3, 3, 1, "SAME")
        flags = ops.block_flags(patches, block_m=8, block_k=128)
        assert float(flags.mean()) < 1.0          # something is skipped
        a = ops.spike_conv(s, w, flags=flags, block_m=8)
        b = ops.spike_conv(s, w, flags=jnp.ones_like(flags), block_m=8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_all_zero_train_is_pure_bias(self):
        """Every patch tile skipped: the layer current reduces to the bias
        broadcast — checked on the routed snn path, not just the raw op."""
        from repro.core import snn
        s = jnp.zeros((3, 10, 10, 2), jnp.float32)
        w = jax.random.normal(jax.random.key(0), (3, 3, 2, 4))
        out = ops.spike_conv(s, w, block_m=8)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        patches = ops.conv_patches(s, 3, 3, 1, "SAME")
        assert ops.skip_fraction(patches, 8, 128) == 1.0
        spec = snn.Conv(4, 3)
        p = {"w": w, "b": jnp.full((4,), 0.25, jnp.float32)}
        cur = snn._layer_current(spec, p, s, matmul_backend="spike_gemm")
        np.testing.assert_array_equal(np.asarray(cur), 0.25)

    def test_rejects_mismatched_flags(self):
        s = jnp.ones((2, 8, 8, 2), jnp.float32)
        w = jnp.ones((3, 3, 2, 4), jnp.float32)
        bad = jnp.ones((1, 1), jnp.int32)
        with pytest.raises(ValueError, match="tile grid"):
            ops.spike_conv(s, w, flags=bad, block_m=8)

    @pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                                (1, "VALID"), (2, "VALID")])
    def test_patch_matrix_is_binary_and_flags_exact(self, stride, padding):
        """The im2col view of a {0,1} spike tensor is itself {0,1}, so the
        sum>0 occupancy gate stays exact on the patch matrix (DESIGN.md §13):
        a flag is 0 iff its tile holds no spikes."""
        rng = np.random.default_rng(11)
        s = jnp.asarray((rng.random((2, 11, 9, 3)) < 0.1).astype(np.float32))
        patches = np.asarray(ops.conv_patches(s, 3, 3, stride, padding))
        assert set(np.unique(patches)) <= {0.0, 1.0}
        padded = np.asarray(ops._pad_to(jnp.asarray(patches), (8, 128)))
        flags = np.asarray(ops.block_flags(jnp.asarray(patches),
                                           block_m=8, block_k=128))
        fm, fk = flags.shape
        tiles = padded.reshape(fm, 8, fk, 128).sum((1, 3))
        np.testing.assert_array_equal(flags, (tiles > 0).astype(np.int32))


class TestPENCCompact:
    """PENC address-extraction kernel vs oracle vs the serial validator."""

    @pytest.mark.parametrize("shape", [(8, 128), (3, 100), (16, 777)])
    @pytest.mark.parametrize("density", [0.0, 0.1, 0.9])
    def test_matches_ref(self, shape, density):
        B, N = shape
        s = (jax.random.uniform(jax.random.key(7), (B, N)) < density
             ).astype(jnp.float32)
        cap = min(N, 128)
        got_idx, got_cnt = ops.penc_compact(s, capacity=cap)
        want_idx, want_cnt = ref.penc_compact_ref(s, cap)
        np.testing.assert_array_equal(np.asarray(got_idx),
                                      np.asarray(want_idx))
        np.testing.assert_array_equal(np.asarray(got_cnt),
                                      np.asarray(want_cnt))

    def test_matches_serial_penc(self):
        """Same semantics as the hardware validator's chunked priority
        encoder when capacity covers the row."""
        from repro.core import validate
        rng = np.random.default_rng(3)
        bits = (rng.random((4, 250)) < 0.2).astype(np.float32)
        idx, cnt = ops.penc_compact(jnp.asarray(bits), capacity=250)
        for b in range(4):
            serial = validate.penc_compress(bits[b].astype(np.int64))
            got = [int(i) for i in np.asarray(idx[b]) if i >= 0]
            assert got == serial
            assert int(cnt[b]) == len(serial)

    def test_capacity_drops_overflow(self):
        s = jnp.ones((1, 64), jnp.float32)
        idx, cnt = ops.penc_compact(s, capacity=16)
        np.testing.assert_array_equal(np.asarray(idx[0]), np.arange(16))
        assert int(cnt[0]) == 64    # count reports true spikes
