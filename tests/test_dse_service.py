"""Tests for the multi-tenant DSE service (``repro.serve``): the typed
event protocol, admission control, the cooperative scheduler, cross-tenant
training dedup over one shared cache, checkpoint/eviction/restart, the
cellfarm fault containment it depends on, and the thread-safe training
budget that backs per-tenant quotas."""
import dataclasses
import json
import threading

import numpy as np
import pytest
import zlib

from repro.core import dse, snn, workloads
from repro.core.accelerator import arch
from repro.core.dse.pareto import ParetoAccumulator, any_dominates
from repro.core.dse.table import CandidateTable
from repro.distributed import cellfarm
from repro.serve import (DSEService, FrontierUpdate, Progress, StudyAccepted,
                         StudyCompleted, StudyEvicted, StudyFailed,
                         StudyHandle, StudyRejected, StudyStarted,
                         Submission, from_wire, is_terminal, to_wire)


def _tiny_wl(name="service-test-wl"):
    return dataclasses.replace(
        workloads.get("mnist-mlp"), name=name,
        layers=(snn.Dense(12),), pcr=1,
        n_train=128, n_test=64, train_steps=4, trace_samples=16)


def _hw_setup(max_lhr=4):
    cfg = arch.from_layer_sizes("t", (64, 32, 16), num_steps=3)
    counts = [np.full(3, 8.0)] * 2
    space = dse.SearchSpace.product_lhr(cfg, max_lhr=max_lhr)
    return cfg, counts, space


def _hw_submission(tenant, name, **over):
    cfg, counts, space = _hw_setup()
    kw = dict(tenant=tenant, name=name, space=space, config=cfg,
              counts=counts, chunk_size=64)
    kw.update(over)
    return Submission(**kw)


#: the tiny cells-mode grid both tenants submit: 2 T x 2 pop = 4 cells
CELL_GRID = dict(num_steps=(2, 3), population=(0.5, 1.0), max_lhr=2,
                 weight_bits=(4,))


def _cells_submission(tenant, name, wl, **over):
    kw = dict(tenant=tenant, name=name, workload=wl, **CELL_GRID)
    kw.update(over)
    return Submission(**kw)


def _rows(table_or_cols):
    """All columns flattened to sortable float rows (strings via crc32)."""
    columns = getattr(table_or_cols, "columns", table_or_cols)
    cols = []
    n = len(next(iter(columns.values())))
    for k in sorted(columns):
        v = np.asarray(columns[k])
        if v.dtype.kind in "USO":
            v = np.array([float(zlib.crc32(str(x).encode())) for x in v])
        cols.append(np.asarray(v, np.float64).reshape(n, -1))
    a = np.concatenate(cols, axis=1)
    return a[np.lexsort(a.T)]


def _objective_matrix(update: FrontierUpdate) -> np.ndarray:
    return np.stack([np.asarray(update.frontier[k], np.float64)
                     for k in update.objectives], axis=1)


def assert_monotone(updates):
    """Every point of each FrontierUpdate is still present in — or strictly
    dominated by — the next one (the streaming contract)."""
    assert updates, "study emitted no frontier updates"
    for prev, cur in zip(updates, updates[1:]):
        assert cur.round > prev.round
        a, b = _objective_matrix(prev), _objective_matrix(cur)
        for p in a:
            present = np.isclose(b, p).all(axis=1).any()
            assert present or any_dominates(b, p[None])[0], (
                f"frontier regressed between rounds {prev.round} and "
                f"{cur.round}: {p} vanished undominated")


@pytest.fixture(scope="module")
def shared_cache(tmp_path_factory):
    """One cache for the whole module so each cell trains exactly once."""
    return workloads.TraceCache(root=str(tmp_path_factory.mktemp("cells")))


# ---- protocol ---------------------------------------------------------------

class TestProtocol:
    EVENTS = [
        StudyAccepted("t/a", "t", position=2),
        StudyRejected("t/a", "t", reason="queue full"),
        StudyStarted("t/a", "t", resumed=True),
        FrontierUpdate("t/a", "t", round=3, n_evaluated=128,
                       frontier_size=2, objectives=("edp", "area_mm2"),
                       frontier={"edp": [1.0, 2.0], "area_mm2": [3.0, 1.5]}),
        Progress("t/a", "t", round=3, n_evaluated=128, frontier_size=2,
                 cells_resolved=4, cells_skipped=1,
                 cache={"hits": 3, "misses": 4},
                 budget={"limit": 8, "spent": 4, "remaining": 4}),
        StudyEvicted("t/a", "t", checkpoint_dir="/tmp/x"),
        StudyEvicted("t/a", "t", checkpoint_dir=None),
        StudyFailed("t/a", "t", error="ValueError: boom"),
        StudyCompleted("t/a", "t", summary={"mode": "cells", "rounds": 4}),
    ]

    def test_wire_round_trip_survives_json(self):
        for event in self.EVENTS:
            wire = json.loads(json.dumps(to_wire(event)))
            assert wire["event"] == type(event).__name__
            assert from_wire(wire) == event      # tuples re-tupled

    def test_unknown_kind_and_fields_rejected(self):
        with pytest.raises(ValueError, match="unknown event kind"):
            from_wire({"event": "Nope", "study_id": "a", "tenant": "t"})
        with pytest.raises(ValueError, match="does not take"):
            from_wire({"event": "StudyStarted", "study_id": "a",
                       "tenant": "t", "resumed": False, "bogus": 1})

    def test_terminal_classification(self):
        terminal = {type(e) for e in self.EVENTS if is_terminal(e)}
        assert terminal == {StudyRejected, StudyFailed, StudyCompleted}

    def test_submission_validates_ids(self):
        Submission(tenant="team-a", name="run_1.2")         # ok
        for bad in ("", "a/b", "a b", "x\n"):
            with pytest.raises(ValueError, match="non-empty"):
                Submission(tenant=bad, name="ok")
            with pytest.raises(ValueError, match="non-empty"):
                Submission(tenant="ok", name=bad)
        assert Submission(tenant="a", name="b").study_id == "a/b"


# ---- admission control ------------------------------------------------------

class TestAdmission:
    def test_duplicate_id_rejected_while_live(self):
        service = DSEService(max_active=1)
        h1 = service.submit(_hw_submission("t", "s"))
        h2 = service.submit(_hw_submission("t", "s"))
        assert h1.status == "pending" and h2.status == "rejected"
        [event] = [e for e in h2.events() if isinstance(e, StudyRejected)]
        assert "already pending" in event.reason
        # a different tenant may reuse the study *name*
        assert service.submit(_hw_submission("u", "s")).status == "pending"
        service.run_until_idle()
        assert h1.status == "completed"
        # ...and after the terminal state the id is reusable again
        assert service.submit(_hw_submission("t", "s")).status == "pending"
        service.run_until_idle()

    def test_queue_full_rejected(self):
        service = DSEService(max_active=1, max_pending=2)
        handles = [service.submit(_hw_submission("t", f"s{i}"))
                   for i in range(4)]
        # 2 queued; the rest bounced at the door
        statuses = [h.status for h in handles]
        assert statuses == ["pending", "pending", "rejected", "rejected"]
        [event] = [e for e in handles[2].events()
                   if isinstance(e, StudyRejected)]
        assert "queue is full" in event.reason
        assert service.stats["rejected"] == 2
        service.run_until_idle()
        assert [h.status for h in handles[:2]] == ["completed"] * 2

    def test_accepted_position_reflects_queue(self):
        service = DSEService(max_active=1)
        positions = []
        for i in range(3):
            h = service.submit(_hw_submission("t", f"p{i}"))
            [acc] = [e for e in h.events() if isinstance(e, StudyAccepted)]
            positions.append(acc.position)
        assert positions == [0, 1, 2]
        service.run_until_idle()

    def test_tenant_quota_mapping(self):
        service = DSEService(tenant_quota=5, tenant_quotas={"big": 100})
        assert service.budget("small").limit == 5
        assert service.budget("big").limit == 100
        # one budget object per tenant, shared across that tenant's studies
        assert service.budget("small") is service.budget("small")
        assert DSEService().budget("anyone") is None      # unmetered

    def test_reject_over_quota(self, shared_cache):
        wl = _tiny_wl()
        service = DSEService(shared_cache, tenant_quota=1,
                             reject_over_quota=True)
        service.budget("t").charge()                      # exhaust it
        h = service.submit(_cells_submission("t", "s", wl))
        assert h.status == "rejected"
        [event] = [e for e in h.events() if isinstance(e, StudyRejected)]
        assert "quota exhausted" in event.reason
        # without the flag the submission queues (cells may still be hits)
        lax = DSEService(shared_cache, tenant_quota=1)
        lax.budget("t").charge()
        assert lax.submit(_cells_submission("t", "s", wl)).status == "pending"


# ---- scheduling: hardware-only studies (fast, no training) ------------------

class TestScheduler:
    def test_hardware_study_lifecycle_events(self):
        service = DSEService()
        handle = service.submit(_hw_submission("t", "hw"))
        service.run_until_idle()
        events = handle.events()
        kinds = [type(e).__name__ for e in events]
        assert kinds[0] == "StudyAccepted"
        assert kinds[1] == "StudyStarted" and not events[1].resumed
        assert kinds[-1] == "StudyCompleted"
        assert any(isinstance(e, FrontierUpdate) for e in events)
        assert any(isinstance(e, Progress) for e in events)
        assert events[-1].summary["done"]
        # the handle's frontier matches a plain explore() of the same space
        cfg, counts, space = _hw_setup()
        solo = dse.explore(space, config=cfg, counts=counts, chunk_size=64)
        assert np.allclose(_rows(handle.frontier), _rows(solo.frontier))

    def test_interleaving_bounded_by_max_active(self):
        service = DSEService(max_active=2)
        seen = []
        handles = [service.submit(_hw_submission("t", f"i{i}"))
                   for i in range(3)]
        while service.tick():
            with service._lock:
                seen.append(tuple(h.study_id for h in service._active))
        assert all(len(s) <= 2 for s in seen)
        # the first two studies ran concurrently at some point
        assert any(len(s) == 2 for s in seen)
        assert all(h.status == "completed" for h in handles)

    def test_build_failure_is_contained(self):
        service = DSEService()
        cfg, counts, space = _hw_setup()
        # joint kwargs on a hardware-only space -> explore raises at build
        bad = Submission(tenant="t", name="bad", space=space, config=cfg,
                         counts=counts, num_steps=(2,))
        good = service.submit(_hw_submission("t", "good"))
        h = service.submit(bad)
        service.run_until_idle()
        assert h.status == "failed"
        [event] = [e for e in h.events() if isinstance(e, StudyFailed)]
        assert "ValueError" in event.error
        assert good.status == "completed"      # neighbor unaffected
        assert service.stats["failed"] == 1

    def test_threaded_stream_subscription(self):
        service = DSEService()
        service.start()
        try:
            handle = service.submit(_hw_submission("t", "bg"))
            events = list(handle.stream(timeout=30.0))
        finally:
            service.stop()
        assert isinstance(events[-1], StudyCompleted)
        assert handle.wait(timeout=1.0)
        assert handle.status == "completed"

    def test_frontier_before_activation_raises(self):
        handle = StudyHandle(_hw_submission("t", "x"))
        with pytest.raises(RuntimeError, match="never activated"):
            handle.frontier
        assert handle.summary == {"status": "pending"}


# ---- the acceptance E2E: two tenants, one shared cache ----------------------

class TestMultiTenantDedup:
    def test_overlapping_cells_train_once_and_frontiers_match_serial(
            self, shared_cache, tmp_path):
        wl = _tiny_wl("service-dedup-wl")
        service = DSEService(shared_cache, max_active=2)
        h_a = service.submit(_cells_submission("tenant-a", "sweep", wl))
        h_b = service.submit(_cells_submission("tenant-b", "sweep", wl))
        misses0, hits0 = shared_cache.misses, shared_cache.hits
        service.run_until_idle()
        assert h_a.status == h_b.status == "completed"

        n_cells = len(CELL_GRID["num_steps"]) * len(CELL_GRID["population"])
        # every overlapping cell trained exactly once...
        assert shared_cache.misses - misses0 <= n_cells
        # ...so at least one full grid's worth of resolutions were hits
        assert shared_cache.hits - hits0 >= n_cells
        # tenant-b (admitted second, round-robin behind a) was pure replay
        sb = h_b.study.summary
        assert sb["cells_resolved"] == n_cells

        # both streams were monotone
        for h in (h_a, h_b):
            assert_monotone([e for e in h.events()
                             if isinstance(e, FrontierUpdate)])

        # and both frontiers equal a serial explore() over a fresh cache
        solo = dse.explore(workload=wl, strategy="grid",
                           cache=workloads.TraceCache(
                               root=str(tmp_path / "solo")), **CELL_GRID)
        want = _rows(solo.frontier)
        assert np.allclose(_rows(h_a.frontier), want)
        assert np.allclose(_rows(h_b.frontier), want)

        stats = service.stats
        assert stats["completed"] == 2 and stats["cache"]["hit_rate"] > 0

    def test_second_tenant_all_hits_on_warm_cache(self, shared_cache):
        wl = _tiny_wl("service-dedup-wl")     # same cells as the test above
        service = DSEService(shared_cache)
        handle = service.submit(_cells_submission("tenant-c", "sweep", wl))
        misses0 = shared_cache.misses
        service.run_until_idle()
        assert handle.status == "completed"
        assert shared_cache.misses == misses0        # zero retraining
        assert handle.study.summary["cache"]["hits"] >= 4


# ---- eviction, restart, resume ----------------------------------------------

class TestRestart:
    def test_evict_then_resubmit_resumes(self, shared_cache, tmp_path):
        wl = _tiny_wl("service-dedup-wl")
        root = str(tmp_path / "svc")
        service = DSEService(shared_cache, checkpoint_root=root)
        sub = _cells_submission("t", "evicted", wl)
        handle = service.submit(sub)
        service.tick()                        # activate + one cell
        assert handle.status == "active"
        ck = service.evict(handle.study_id)
        assert ck and "t" in ck and "evicted" in ck
        [event] = [e for e in handle.events()
                   if isinstance(e, StudyEvicted)]
        assert event.checkpoint_dir == ck
        assert service.stats["evicted"] == 1 and service.stats["active"] == 0

        h2 = service.submit(sub)
        service.run_until_idle()
        assert h2.status == "completed"
        [started] = [e for e in h2.events() if isinstance(e, StudyStarted)]
        assert started.resumed

    def test_service_restart_resumes_with_zero_retraining(
            self, shared_cache, tmp_path):
        wl = _tiny_wl("service-restart-wl")   # fresh cells: must train once
        root = str(tmp_path / "svc")
        misses0 = shared_cache.misses
        service = DSEService(shared_cache, checkpoint_root=root,
                             tenant_quota=16, checkpoint_every=1)
        sub = _cells_submission("t", "restart", wl)
        h1 = service.submit(sub)
        for _ in range(3):                    # activate + two cells
            service.tick()
        assert h1.status == "active" and h1.study.rounds >= 2
        service.shutdown()                    # evicts + checkpoints
        assert h1.status == "evicted"
        spent = service.budget("t").spent
        assert spent == shared_cache.misses - misses0 >= 2

        revived = DSEService(shared_cache, checkpoint_root=root,
                             tenant_quota=16)
        # budget accounting round-tripped through service.json
        assert revived.budget("t").spent == spent
        h2 = revived.submit(sub)
        revived.run_until_idle()
        assert h2.status == "completed"
        [started] = [e for e in h2.events() if isinstance(e, StudyStarted)]
        assert started.resumed
        # zero retraining across the restart: each of this workload's cells
        # trained exactly once, whether before or after the kill
        n_cells = len(CELL_GRID["num_steps"]) * len(CELL_GRID["population"])
        assert shared_cache.misses - misses0 == n_cells
        # the resumed frontier is bit-for-bit the serial one
        solo = dse.explore(workload=wl, strategy="grid", cache=shared_cache,
                           **CELL_GRID)
        assert set(h2.frontier.columns) == set(solo.frontier.columns)
        for k, v in solo.frontier.columns.items():
            got = h2.frontier.columns[k]
            assert np.asarray(got).dtype == np.asarray(v).dtype
        assert np.allclose(_rows(h2.frontier), _rows(solo.frontier))

    def test_evict_without_checkpoint_root(self):
        service = DSEService()
        handle = service.submit(_hw_submission("t", "noroot",
                                               chunk_size=16))
        service.tick()
        ck = service.evict(handle.study_id)
        assert ck is None
        with pytest.raises(ValueError, match="not active"):
            service.evict(handle.study_id)


# ---- Study.load failure paths (satellite 3) ---------------------------------

class TestStudyLoadFailures:
    def test_missing_checkpoint_raises(self, tmp_path):
        cfg, counts, space = _hw_setup()
        study = dse.explore(space, config=cfg, counts=counts, run=False)
        with pytest.raises(FileNotFoundError, match="no study checkpoint"):
            study.load(str(tmp_path / "nowhere"))

    def test_signature_mismatch_raises_clear_error(self, tmp_path):
        cfg, counts, space = _hw_setup()
        ck = str(tmp_path / "ck")
        dse.explore(space, config=cfg, counts=counts, chunk_size=64,
                    checkpoint_dir=ck)
        # same checkpoint, differently-configured study: the guard names
        # what can differ and how to recover
        other = dse.explore(dse.SearchSpace.product_lhr(cfg, max_lhr=2),
                            config=cfg, counts=counts, run=False)
        with pytest.raises(ValueError,
                           match="written for a different study"):
            other.load(ck)
        # resume=True routes through the same guard
        with pytest.raises(ValueError, match="different study"):
            dse.explore(dse.SearchSpace.product_lhr(cfg, max_lhr=2),
                        config=cfg, counts=counts, checkpoint_dir=ck,
                        resume=True)


# ---- cellfarm fault containment (satellite 1) -------------------------------

class TestCellfarmFaults:
    def _job(self, wl=None):
        return cellfarm.CellJob(workload=wl or _tiny_wl("farm-fault-wl"),
                                assignment={"num_steps": 2,
                                            "population": 1.0})

    def test_resolve_job_returns_failure_not_raise(self, tmp_path,
                                                   monkeypatch):
        monkeypatch.setattr(cellfarm.TraceCache, "resolve",
                            lambda *a, **k: 1 / 0)
        out = cellfarm._resolve_job((self._job(), str(tmp_path)))
        assert out.trained is False
        assert "ZeroDivisionError" in out.error
        assert out.key == cellfarm._job_key(self._job())

    def test_pool_crash_marks_jobs_failed_and_rebuilds(self, monkeypatch,
                                                       tmp_path):
        class PoisonPool:
            def map(self, *a, **k):
                raise BrokenPipeError("worker died")
        teardowns = []
        # force the pool path even on a 1-CPU host
        monkeypatch.setattr(cellfarm, "_worker_count", lambda n, w: 2)
        monkeypatch.setattr(cellfarm, "_get_pool", lambda n: PoisonPool())
        monkeypatch.setattr(cellfarm, "shutdown_pool",
                            lambda: teardowns.append(1))
        jobs = [self._job(), self._job()]
        got = cellfarm._farm_attempt([(j, str(tmp_path)) for j in jobs],
                                     workers=2)
        assert len(got) == 2
        assert all("worker pool crashed" in o.error for o in got)
        assert teardowns           # the poisoned pool was torn down

    def test_resolve_cells_bounded_retry_then_error(self, monkeypatch,
                                                    tmp_path):
        calls = []
        def flaky(args):
            calls.append(1)
            job, _ = args
            # fails the first two resolution attempts, then succeeds
            if len(calls) <= 2:
                return cellfarm.CellOutcome(key="k", trained=False,
                                            error="RuntimeError: flake")
            return cellfarm.CellOutcome(key="k", trained=True)
        monkeypatch.setattr(cellfarm, "_resolve_job", flaky)
        out = cellfarm.resolve_cells([self._job()], str(tmp_path),
                                     workers=1, retries=2)
        assert [o.error for o in out] == [None] and out[0].trained
        assert len(calls) == 3

        calls.clear()
        out = cellfarm.resolve_cells([self._job()], str(tmp_path),
                                     workers=1, retries=1)
        assert out[0].error is not None       # gave up after 1 retry
        assert len(calls) == 2                # initial + one retry, no more

    def test_failed_farm_does_not_kill_study(self, monkeypatch, tmp_path):
        """One bad farm round degrades to in-process training — the study
        (and therefore a service loop driving it) still completes."""
        wl = _tiny_wl("farm-degrade-wl")
        def all_fail(jobs, root, **kw):
            return [cellfarm.CellOutcome(key=cellfarm._job_key(j),
                                         trained=False, error="boom")
                    for j in jobs]
        monkeypatch.setattr(
            "repro.core.dse.study.cellfarm.resolve_cells", all_fail)
        cache = workloads.TraceCache(root=str(tmp_path / "cells"))
        study = dse.explore(workload=wl, num_steps=(2,), population=(1.0,),
                            max_lhr=2, weight_bits=(4,), cache=cache,
                            workers=4, strategy="grid")
        assert study.done and len(study.frontier) > 0
        assert cache.misses == 1              # trained serially instead
        assert study.farmed_misses == 0       # nothing double-charged


# ---- thread-safe TrainingBudget (satellite 2) -------------------------------

class TestBudgetThreadSafety:
    def test_concurrent_try_charge_never_oversells(self):
        budget = workloads.TrainingBudget(100)
        wins = []
        def hammer():
            mine = 0
            for _ in range(200):
                if budget.try_charge():
                    mine += 1
            wins.append(mine)
        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert budget.spent == 100 == sum(wins)
        assert budget.remaining == 0
        assert not budget.can_spend()
        with pytest.raises(workloads.BudgetExceeded):
            budget.charge()

    def test_state_round_trips_without_lock(self):
        import pickle
        budget = workloads.TrainingBudget(7)
        budget.charge(3)
        state = budget.state_dict()
        assert state == {"limit": 7, "spent": 3}
        fresh = workloads.TrainingBudget(0)
        fresh.load_state_dict(state)
        assert (fresh.limit, fresh.spent, fresh.remaining) == (7, 3, 4)
        clone = pickle.loads(pickle.dumps(budget))
        assert (clone.limit, clone.spent) == (7, 3)
        assert clone.try_charge(4) and not clone.try_charge()


# ---- Study stepping hooks the service builds on -----------------------------

class TestStudyHooks:
    def test_listeners_fire_per_round_and_version_tracks_changes(self):
        cfg, counts, space = _hw_setup()
        study = dse.explore(space, config=cfg, counts=counts, chunk_size=32,
                            run=False)
        rounds_seen = []
        study.listeners.append(lambda s: rounds_seen.append(
            (s.rounds, s.frontier_version)))
        study.run()
        assert [r for r, _ in rounds_seen] == list(
            range(1, study.rounds + 1))
        versions = [v for _, v in rounds_seen]
        assert versions == sorted(versions)          # never regresses
        assert versions[0] >= 1                      # first chunk changed it
        assert study.frontier_version == versions[-1]

    def test_pareto_update_reports_change(self):
        acc = ParetoAccumulator(("x", "y"))
        assert acc.update(CandidateTable(
            {"x": np.array([1.0, 2.0]), "y": np.array([2.0, 1.0])}))
        # strictly dominated chunk: no change
        assert not acc.update(CandidateTable(
            {"x": np.array([5.0]), "y": np.array([5.0])}))
        # an improving chunk flips it back on
        assert acc.update(CandidateTable(
            {"x": np.array([0.5]), "y": np.array([0.5])}))
        assert not acc.update(CandidateTable({"x": np.empty(0),
                                              "y": np.empty(0)}))
