"""Unit + property tests for the SNN substrate (LIF, encodings, networks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding, snn
from repro.core.lif import LIFParams, lif_init_state, lif_step, spike_fn


class TestSpikeFn:
    def test_forward_is_heaviside(self):
        v = jnp.array([-1.0, -1e-6, 0.0, 1e-6, 3.0])
        out = spike_fn(v)
        np.testing.assert_array_equal(np.asarray(out), [0, 0, 0, 1, 1])

    def test_surrogate_gradient_shape_and_peak(self):
        g = jax.grad(lambda v: spike_fn(v).sum())(jnp.linspace(-2, 2, 101))
        g = np.asarray(g)
        assert g.argmax() == 50                      # peak at v == 0
        assert np.isclose(g.max(), 1.0)              # 1/(1+25*0)^2
        assert (g > 0).all()                         # smooth, everywhere positive

    @pytest.mark.parametrize("seed", range(5))
    def test_gradient_symmetric(self, seed):
        v = jax.random.normal(jax.random.key(seed), (64,))
        g = jax.grad(lambda x: spike_fn(x).sum())
        np.testing.assert_allclose(np.asarray(g(v)), np.asarray(g(-v)), rtol=1e-6)


class TestLIF:
    def test_integrates_to_threshold(self):
        p = LIFParams(beta=1.0, threshold=1.0)
        u, s = lif_init_state((1,))
        fired_at = None
        for t in range(10):
            u, s = lif_step(u, s, jnp.full((1,), 0.3), p)
            if fired_at is None and float(s[0]) == 1.0:
                fired_at = t
        assert fired_at == 3                         # 0.3*4 = 1.2 > 1.0

    def test_reset_subtract(self):
        p = LIFParams(beta=1.0, threshold=1.0, reset_mechanism="subtract")
        u, s = lif_init_state((1,))
        u, s = lif_step(u, s, jnp.full((1,), 1.5), p)
        assert float(s[0]) == 1.0
        u2, s2 = lif_step(u, s, jnp.zeros((1,)), p)
        # membrane was 1.5, reset subtracts threshold -> 0.5
        np.testing.assert_allclose(float(u2[0]), 0.5)

    def test_reset_zero(self):
        p = LIFParams(beta=0.5, threshold=1.0, reset_mechanism="zero")
        u, s = lif_init_state((1,))
        u, s = lif_step(u, s, jnp.full((1,), 2.0), p)
        u2, _ = lif_step(u, s, jnp.zeros((1,)), p)
        np.testing.assert_allclose(float(u2[0]), 0.0)

    def test_no_input_no_spikes(self):
        p = LIFParams()
        u, s = lif_init_state((8,))
        for _ in range(20):
            u, s = lif_step(u, s, jnp.zeros((8,)), p)
        assert float(s.sum()) == 0.0


class TestEncoding:
    @pytest.mark.parametrize("seed", range(3))
    def test_rate_encode_statistics(self, seed):
        x = jnp.full((4, 10), 0.3)
        spikes = encoding.rate_encode(jax.random.key(seed), x, 500)
        rate = float(spikes.mean())
        assert abs(rate - 0.3) < 0.02
        assert set(np.unique(np.asarray(spikes))) <= {0.0, 1.0}

    def test_rate_encode_extremes(self):
        x = jnp.stack([jnp.zeros(5), jnp.ones(5)])
        spikes = encoding.rate_encode(jax.random.key(0), x, 50)
        assert float(spikes[:, 0].sum()) == 0.0
        assert float(spikes[:, 1].mean()) == 1.0

    def test_population_pool_conservation(self):
        counts = jnp.arange(30.0).reshape(1, 30)
        pooled = encoding.population_pool(counts, 10)
        assert pooled.shape == (1, 10)
        np.testing.assert_allclose(float(pooled.sum()), float(counts.sum()))

    def test_population_decode_majority(self):
        # class 2's pool spikes the most
        train = np.zeros((5, 1, 12), np.float32)   # 4 classes x pcr 3
        train[:, 0, 6:9] = 1.0
        pred = encoding.population_decode(jnp.asarray(train), 4)
        assert int(pred[0]) == 2


class TestSNN:
    def _cfg(self, pcr=2):
        return snn.SNNConfig(
            name="t", input_shape=(6, 6), layers=(
                snn.Dense(16), snn.Dense(4 * pcr)),
            num_classes=4, pcr=pcr, num_steps=7)

    def test_shapes_and_binary_output(self):
        cfg = self._cfg()
        params = snn.init_params(jax.random.key(0), cfg)
        x = encoding.rate_encode(jax.random.key(1), jnp.ones((3, 6, 6)) * 0.8, 7)
        out = snn.apply(cfg, params, x)
        assert out.shape == (7, 3, 8)
        assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}

    def test_grad_flows_through_time(self):
        cfg = self._cfg()
        params = snn.init_params(jax.random.key(0), cfg)
        x = encoding.rate_encode(jax.random.key(1), jnp.ones((2, 6, 6)) * 0.9, 7)
        y = jnp.array([0, 1])

        def loss(p):
            return encoding.rate_loss(snn.apply(cfg, p, x), y, 4)

        grads = jax.grad(loss)(params)
        gn = sum(float(jnp.abs(g).sum()) for l in grads for g in l.values())
        assert gn > 0.0 and np.isfinite(gn)

    def test_conv_net_shapes(self):
        cfg = snn.SNNConfig(
            name="c", input_shape=(16, 16, 2), layers=(
                snn.Conv(4, 3), snn.MaxPool(2), snn.Dense(8)),
            num_classes=4, pcr=2, num_steps=5)
        assert cfg.layer_sizes() == [16 * 16 * 4, 8]
        params = snn.init_params(jax.random.key(0), cfg)
        x = (jax.random.uniform(jax.random.key(1), (5, 2, 16, 16, 2)) < 0.2
             ).astype(jnp.float32)
        out = snn.apply(cfg, params, x)
        assert out.shape == (5, 2, 8)

    @pytest.mark.parametrize("seed", range(3))
    def test_spike_counts_match_trains(self, seed):
        """Conservation: counts reported for layer l+1's input == spikes
        emitted by layer l (post-pool)."""
        cfg = self._cfg()
        params = snn.init_params(jax.random.key(seed), cfg)
        x = encoding.rate_encode(jax.random.key(seed + 1),
                                 jnp.ones((2, 6, 6)) * 0.7, 7)
        counts = snn.spike_counts_per_layer(cfg, params, x)
        all_spikes = snn.apply(cfg, params, x, return_all_layers=True)
        np.testing.assert_allclose(
            np.asarray(counts[0]), np.asarray(x.reshape(7, 2, -1).sum(-1)))
        np.testing.assert_allclose(
            np.asarray(counts[1]),
            np.asarray(all_spikes[0].reshape(7, 2, -1).sum(-1)))

    def test_more_steps_monotone_spike_budget(self):
        cfg = self._cfg()
        params = snn.init_params(jax.random.key(0), cfg)
        totals = []
        for T in (4, 8, 16):
            x = encoding.rate_encode(jax.random.key(1),
                                     jnp.ones((2, 6, 6)) * 0.6, T)
            out = snn.apply(cfg, params, x)
            totals.append(float(out.sum()))
        assert totals[0] <= totals[1] <= totals[2]


class TestTraining:
    def test_snn_learns_synthetic(self):
        from repro.core import train_snn
        from repro.data import synthetic
        data = synthetic.make_images(n_train=256, n_test=128, seed=3)
        cfg = snn.SNNConfig(
            name="learn", input_shape=(28, 28),
            layers=(snn.Dense(64), snn.Dense(10 * 3)),
            num_classes=10, pcr=3, num_steps=10)
        res = train_snn.train(cfg, data, steps=60, batch_size=64, lr=3e-3)
        assert res.train_loss[-1] < res.train_loss[0] * 0.5
        assert res.test_accuracy > 0.8
