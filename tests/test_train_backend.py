"""End-to-end training parity between the jnp and spike_gemm backends.

The kernel path must be a training-equivalent of the reference: same loss
trajectory and final accuracy from the same seed, identical spike traces
from the same params, and — because of that — one shared cache key per cell
regardless of which backend trained it (backend-invariant DSE cells).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import snn, train_snn, workloads
from repro.core.workloads import cache
from repro.data import synthetic


def _small_cfg(num_steps=5):
    return snn.SNNConfig(name="parity", input_shape=(12, 12),
                         layers=(snn.Dense(24), snn.Dense(10)),
                         num_classes=10, num_steps=num_steps)


@pytest.fixture(scope="module")
def small_data():
    return synthetic.make_images(name="synth-parity", seed=5, n_train=192,
                                 n_test=64, h=12, w=12)


class TestTrainingParity:
    def test_loss_trajectory_and_accuracy(self, small_data):
        cfg = _small_cfg()
        runs = {}
        for backend in snn.MATMUL_BACKENDS:
            runs[backend] = train_snn.train(
                cfg, small_data, steps=20, batch_size=32, seed=11,
                matmul_backend=backend)
        l_jnp = np.asarray(runs["jnp"].train_loss)
        for backend in snn.MATMUL_BACKENDS[1:]:
            l_ker = np.asarray(runs[backend].train_loss)
            np.testing.assert_allclose(l_jnp, l_ker, atol=1e-3, rtol=1e-3)
            assert abs(runs["jnp"].test_accuracy
                       - runs[backend].test_accuracy) <= 0.05

    def test_traces_backend_invariant(self, small_data):
        """Same params => bit-identical dump_traces/trace_counts under all
        backends (the property that makes cached cells backend-free)."""
        cfg = _small_cfg()
        res = train_snn.train(cfg, small_data, steps=10, batch_size=32,
                              seed=3)
        traces, counts = {}, {}
        for backend in snn.MATMUL_BACKENDS:
            traces[backend] = train_snn.dump_traces(
                cfg, res.params, small_data.x_test, max_samples=32,
                matmul_backend=backend)
            counts[backend] = train_snn.trace_counts(
                cfg, res.params, small_data.x_test, max_samples=32,
                matmul_backend=backend)
        for backend in snn.MATMUL_BACKENDS[1:]:
            for a, b in zip(traces["jnp"]["layer_input_spike_counts"],
                            traces[backend]["layer_input_spike_counts"]):
                np.testing.assert_array_equal(a, b)
            for a, b in zip(counts["jnp"], counts[backend]):
                np.testing.assert_array_equal(a, b)

    def test_evaluate_backend_invariant(self, small_data):
        cfg = _small_cfg()
        res = train_snn.train(cfg, small_data, steps=10, batch_size=32,
                              seed=3)
        acc_j = train_snn.evaluate(cfg, res.params, small_data.x_test,
                                   small_data.y_test, matmul_backend="jnp")
        for backend in snn.MATMUL_BACKENDS[1:]:
            acc_k = train_snn.evaluate(cfg, res.params, small_data.x_test,
                                       small_data.y_test,
                                       matmul_backend=backend)
            assert acc_j == acc_k


def _conv_cfg(num_steps=3):
    """Shrunk dvs-conv-style topology: Conv + OR-pool + Dense classifier on
    an 8x8x2 event retina (interpret-mode Pallas executes the patch grid
    serially, so B·OH·OW stays small)."""
    return snn.SNNConfig(name="conv-parity", input_shape=(8, 8, 2),
                         layers=(snn.Conv(3, 3), snn.MaxPool(2),
                                 snn.Dense(10)),
                         num_classes=10, num_steps=num_steps)


@pytest.fixture(scope="module")
def conv_data():
    return synthetic.make_events(name="synth-conv-parity", seed=6,
                                 num_classes=10, n_train=96, n_test=32,
                                 t=3, h=8, w=8)


class TestConvTrainingParity:
    """Same contract as TestTrainingParity, on the conv datapath: Conv
    layers route through the patch-tiled block-skip kernel on the
    spike_gemm/spike_gemm_fused backends (no lax.conv fallback), and the
    result is spike-for-spike the jnp reference."""

    def test_conv_layers_route_through_kernel(self, monkeypatch):
        """No lax.conv on the kernel backends: stub spike_conv_train to
        prove _layer_current actually calls it for Conv layers."""
        from repro.kernels import ops as kernel_ops
        calls = []
        real = kernel_ops.spike_conv_train

        def spy(*a, **kw):
            calls.append(kw)
            return real(*a, **kw)

        monkeypatch.setattr(kernel_ops, "spike_conv_train", spy)
        cfg = _conv_cfg()
        params = snn.init_params(jax.random.key(0), cfg)
        x = jnp.zeros((2, 8, 8, 2), jnp.float32)
        snn._layer_current(cfg.layers[0], params[0], x,
                           matmul_backend="jnp")
        assert not calls                      # jnp path: dense lax.conv
        for backend in snn.MATMUL_BACKENDS[1:]:
            snn._layer_current(cfg.layers[0], params[0], x,
                               matmul_backend=backend)
        assert len(calls) == len(snn.MATMUL_BACKENDS) - 1

    def test_traces_backend_invariant(self, conv_data):
        """Same-seed dvs-conv training, then bit-identical dump_traces /
        trace_counts under every backend in MATMUL_BACKENDS — the property
        that keeps conv cells backend-free in the cache."""
        cfg = _conv_cfg()
        res = train_snn.train(cfg, conv_data, steps=8, batch_size=16,
                              seed=3)
        traces, counts = {}, {}
        for backend in snn.MATMUL_BACKENDS:
            traces[backend] = train_snn.dump_traces(
                cfg, res.params, conv_data.x_test, max_samples=16,
                matmul_backend=backend)
            counts[backend] = train_snn.trace_counts(
                cfg, res.params, conv_data.x_test, max_samples=16,
                matmul_backend=backend)
        for backend in snn.MATMUL_BACKENDS[1:]:
            for a, b in zip(traces["jnp"]["layer_input_spike_counts"],
                            traces[backend]["layer_input_spike_counts"]):
                np.testing.assert_array_equal(a, b)
            for a, b in zip(counts["jnp"], counts[backend]):
                np.testing.assert_array_equal(a, b)

    def test_loss_and_grads_match(self, conv_data):
        """Surrogate-gradient BPTT through the conv custom_vjp: loss value
        and every parameter cotangent match the jnp reference."""
        cfg = _conv_cfg()
        params = snn.init_params(jax.random.key(1), cfg)
        x = jnp.asarray(conv_data.x_train[:16])
        y = jnp.asarray(conv_data.y_train[:16])
        key = jax.random.key(2)
        vals, grads = {}, {}
        for backend in snn.MATMUL_BACKENDS:
            vals[backend], grads[backend] = jax.value_and_grad(
                lambda p: train_snn.loss_fn(cfg, p, key, x, y,
                                            matmul_backend=backend))(params)
        for backend in snn.MATMUL_BACKENDS[1:]:
            np.testing.assert_allclose(float(vals["jnp"]),
                                       float(vals[backend]), rtol=1e-6)
            jax.tree.map(lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5),
                grads["jnp"], grads[backend])


class TestBackendResolution:
    def test_explicit_arg_wins(self, monkeypatch):
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend("jnp") == "jnp"

    def test_env_var_default(self, monkeypatch):
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend() == "spike_gemm"
        monkeypatch.delenv(snn.MATMUL_BACKEND_ENV)
        assert snn.resolve_matmul_backend() == "jnp"

    def test_unknown_backend_rejected(self, monkeypatch):
        with pytest.raises(ValueError, match="unknown matmul backend"):
            snn.resolve_matmul_backend("cuda")
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "bogus")
        with pytest.raises(ValueError, match="unknown matmul backend"):
            snn.resolve_matmul_backend()


class TestWorkloadRecipe:
    def _tiny(self, **kw):
        base = dict(name="tiny-backend", dataset="mnist", input_shape=(28, 28),
                    layers=(snn.Dense(8),), num_classes=10, pcr=1,
                    n_train=96, n_test=32, train_steps=3, trace_samples=8)
        base.update(kw)
        return workloads.Workload(**base)

    def test_backend_excluded_from_signature_and_key(self):
        wl_j = self._tiny()
        wl_k = self._tiny(matmul_backend="spike_gemm")
        assert wl_j.signature() == wl_k.signature()
        a = {"num_steps": 4, "population": 1.0}
        assert cache.cell_key(wl_j, a, 0) == cache.cell_key(wl_k, a, 0)

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown matmul backend"):
            self._tiny(matmul_backend="bogus")

    def test_default_recipe_defers_to_env(self, monkeypatch):
        """An unset recipe backend (None) falls through to the env var, so
        cellfarm workers can opt whole processes in (DESIGN.md §11)."""
        wl = self._tiny()
        assert wl.matmul_backend is None
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend(wl.matmul_backend) == "spike_gemm"
        monkeypatch.delenv(snn.MATMUL_BACKEND_ENV)
        assert snn.resolve_matmul_backend(wl.matmul_backend) == "jnp"
        # an explicit recipe choice pins the backend regardless of env
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend(
            self._tiny(matmul_backend="jnp").matmul_backend) == "jnp"

    def test_cell_trained_on_jnp_is_hit_for_kernel_recipe(self, tmp_path):
        """The shared key means a jnp-trained cell resolves as a cache hit
        for the spike_gemm recipe — no retraining, identical artifact."""
        tc = cache.TraceCache(root=str(tmp_path))
        a = {"num_steps": 4, "population": 1.0}
        cell_j = tc.resolve(self._tiny(), a, seed=0)
        assert not cell_j.cache_hit
        cell_k = tc.resolve(self._tiny(matmul_backend="spike_gemm"), a,
                            seed=0)
        assert cell_k.cache_hit
        for x, y in zip(cell_j.counts, cell_k.counts):
            np.testing.assert_array_equal(x, y)

    def test_conv_cell_trained_on_jnp_is_hit_for_kernel_recipe(self,
                                                               tmp_path):
        """Conv cells share the backend-free key too: a jnp-trained
        dvs-conv-style cell resolves as a cache hit for a spike_gemm
        recipe, with the identical trace artifact."""
        conv_wl = dataclasses.replace(
            workloads.get("dvs-conv"), name="tiny-conv-backend",
            input_shape=(8, 8, 2),
            layers=(snn.Conv(3, 3), snn.MaxPool(2), snn.Dense(10)),
            num_classes=10, pcr=1, n_train=64, n_test=16, train_steps=2,
            batch_size=16, trace_samples=8)
        conv_k = dataclasses.replace(conv_wl, matmul_backend="spike_gemm")
        assert conv_wl.signature() == conv_k.signature()
        tc = cache.TraceCache(root=str(tmp_path))
        a = {"num_steps": 3, "population": 1.0}
        cell_j = tc.resolve(conv_wl, a, seed=0)
        assert not cell_j.cache_hit
        cell_k = tc.resolve(conv_k, a, seed=0)
        assert cell_k.cache_hit
        for x, y in zip(cell_j.counts, cell_k.counts):
            np.testing.assert_array_equal(x, y)

    def test_kernel_recipe_trains_through_cache(self, tmp_path):
        """A spike_gemm-recipe cell trains end-to-end through TraceCache and
        produces the same artifact a jnp recipe would."""
        tc_k = cache.TraceCache(root=str(tmp_path / "k"))
        tc_j = cache.TraceCache(root=str(tmp_path / "j"))
        a = {"num_steps": 3, "population": 1.0}
        cell_k = tc_k.resolve(self._tiny(matmul_backend="spike_gemm"), a,
                              seed=1)
        cell_j = tc_j.resolve(self._tiny(), a, seed=1)
        assert not cell_k.cache_hit and not cell_j.cache_hit
        assert cell_k.key == cell_j.key
        np.testing.assert_allclose(cell_k.accuracy, cell_j.accuracy,
                                   atol=0.05)
        for x, y in zip(cell_k.counts, cell_j.counts):
            np.testing.assert_array_equal(x, y)
