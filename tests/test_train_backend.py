"""End-to-end training parity between the jnp and spike_gemm backends.

The kernel path must be a training-equivalent of the reference: same loss
trajectory and final accuracy from the same seed, identical spike traces
from the same params, and — because of that — one shared cache key per cell
regardless of which backend trained it (backend-invariant DSE cells).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import snn, train_snn, workloads
from repro.core.workloads import cache
from repro.data import synthetic


def _small_cfg(num_steps=5):
    return snn.SNNConfig(name="parity", input_shape=(12, 12),
                         layers=(snn.Dense(24), snn.Dense(10)),
                         num_classes=10, num_steps=num_steps)


@pytest.fixture(scope="module")
def small_data():
    return synthetic.make_images(name="synth-parity", seed=5, n_train=192,
                                 n_test=64, h=12, w=12)


class TestTrainingParity:
    def test_loss_trajectory_and_accuracy(self, small_data):
        cfg = _small_cfg()
        runs = {}
        for backend in snn.MATMUL_BACKENDS:
            runs[backend] = train_snn.train(
                cfg, small_data, steps=20, batch_size=32, seed=11,
                matmul_backend=backend)
        l_jnp = np.asarray(runs["jnp"].train_loss)
        for backend in snn.MATMUL_BACKENDS[1:]:
            l_ker = np.asarray(runs[backend].train_loss)
            np.testing.assert_allclose(l_jnp, l_ker, atol=1e-3, rtol=1e-3)
            assert abs(runs["jnp"].test_accuracy
                       - runs[backend].test_accuracy) <= 0.05

    def test_traces_backend_invariant(self, small_data):
        """Same params => bit-identical dump_traces/trace_counts under all
        backends (the property that makes cached cells backend-free)."""
        cfg = _small_cfg()
        res = train_snn.train(cfg, small_data, steps=10, batch_size=32,
                              seed=3)
        traces, counts = {}, {}
        for backend in snn.MATMUL_BACKENDS:
            traces[backend] = train_snn.dump_traces(
                cfg, res.params, small_data.x_test, max_samples=32,
                matmul_backend=backend)
            counts[backend] = train_snn.trace_counts(
                cfg, res.params, small_data.x_test, max_samples=32,
                matmul_backend=backend)
        for backend in snn.MATMUL_BACKENDS[1:]:
            for a, b in zip(traces["jnp"]["layer_input_spike_counts"],
                            traces[backend]["layer_input_spike_counts"]):
                np.testing.assert_array_equal(a, b)
            for a, b in zip(counts["jnp"], counts[backend]):
                np.testing.assert_array_equal(a, b)

    def test_evaluate_backend_invariant(self, small_data):
        cfg = _small_cfg()
        res = train_snn.train(cfg, small_data, steps=10, batch_size=32,
                              seed=3)
        acc_j = train_snn.evaluate(cfg, res.params, small_data.x_test,
                                   small_data.y_test, matmul_backend="jnp")
        for backend in snn.MATMUL_BACKENDS[1:]:
            acc_k = train_snn.evaluate(cfg, res.params, small_data.x_test,
                                       small_data.y_test,
                                       matmul_backend=backend)
            assert acc_j == acc_k


class TestBackendResolution:
    def test_explicit_arg_wins(self, monkeypatch):
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend("jnp") == "jnp"

    def test_env_var_default(self, monkeypatch):
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend() == "spike_gemm"
        monkeypatch.delenv(snn.MATMUL_BACKEND_ENV)
        assert snn.resolve_matmul_backend() == "jnp"

    def test_unknown_backend_rejected(self, monkeypatch):
        with pytest.raises(ValueError, match="unknown matmul backend"):
            snn.resolve_matmul_backend("cuda")
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "bogus")
        with pytest.raises(ValueError, match="unknown matmul backend"):
            snn.resolve_matmul_backend()


class TestWorkloadRecipe:
    def _tiny(self, **kw):
        base = dict(name="tiny-backend", dataset="mnist", input_shape=(28, 28),
                    layers=(snn.Dense(8),), num_classes=10, pcr=1,
                    n_train=96, n_test=32, train_steps=3, trace_samples=8)
        base.update(kw)
        return workloads.Workload(**base)

    def test_backend_excluded_from_signature_and_key(self):
        wl_j = self._tiny()
        wl_k = self._tiny(matmul_backend="spike_gemm")
        assert wl_j.signature() == wl_k.signature()
        a = {"num_steps": 4, "population": 1.0}
        assert cache.cell_key(wl_j, a, 0) == cache.cell_key(wl_k, a, 0)

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown matmul backend"):
            self._tiny(matmul_backend="bogus")

    def test_default_recipe_defers_to_env(self, monkeypatch):
        """An unset recipe backend (None) falls through to the env var, so
        cellfarm workers can opt whole processes in (DESIGN.md §11)."""
        wl = self._tiny()
        assert wl.matmul_backend is None
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend(wl.matmul_backend) == "spike_gemm"
        monkeypatch.delenv(snn.MATMUL_BACKEND_ENV)
        assert snn.resolve_matmul_backend(wl.matmul_backend) == "jnp"
        # an explicit recipe choice pins the backend regardless of env
        monkeypatch.setenv(snn.MATMUL_BACKEND_ENV, "spike_gemm")
        assert snn.resolve_matmul_backend(
            self._tiny(matmul_backend="jnp").matmul_backend) == "jnp"

    def test_cell_trained_on_jnp_is_hit_for_kernel_recipe(self, tmp_path):
        """The shared key means a jnp-trained cell resolves as a cache hit
        for the spike_gemm recipe — no retraining, identical artifact."""
        tc = cache.TraceCache(root=str(tmp_path))
        a = {"num_steps": 4, "population": 1.0}
        cell_j = tc.resolve(self._tiny(), a, seed=0)
        assert not cell_j.cache_hit
        cell_k = tc.resolve(self._tiny(matmul_backend="spike_gemm"), a,
                            seed=0)
        assert cell_k.cache_hit
        for x, y in zip(cell_j.counts, cell_k.counts):
            np.testing.assert_array_equal(x, y)

    def test_kernel_recipe_trains_through_cache(self, tmp_path):
        """A spike_gemm-recipe cell trains end-to-end through TraceCache and
        produces the same artifact a jnp recipe would."""
        tc_k = cache.TraceCache(root=str(tmp_path / "k"))
        tc_j = cache.TraceCache(root=str(tmp_path / "j"))
        a = {"num_steps": 3, "population": 1.0}
        cell_k = tc_k.resolve(self._tiny(matmul_backend="spike_gemm"), a,
                              seed=1)
        cell_j = tc_j.resolve(self._tiny(), a, seed=1)
        assert not cell_k.cache_hit and not cell_j.cache_hit
        assert cell_k.key == cell_j.key
        np.testing.assert_allclose(cell_k.accuracy, cell_j.accuracy,
                                   atol=0.05)
        for x, y in zip(cell_k.counts, cell_j.counts):
            np.testing.assert_array_equal(x, y)
