"""Tests for tools/bench_diff.py: regression flagging direction, threshold,
duplicate-name pairing, strict exit code, and --json output."""
import json
import os
import subprocess
import sys

TOOL = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "tools", "bench_diff.py")


def _write(path, records):
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


def _run(*args):
    return subprocess.run([sys.executable, TOOL, *args],
                          capture_output=True, text=True)


def test_flags_throughput_drop_and_latency_growth(tmp_path):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [{"name": "a", "cands_per_sec": 1000, "seconds": 1.0},
                 {"name": "b", "cands_per_sec": 1000, "seconds": 1.0}])
    _write(new, [{"name": "a", "cands_per_sec": 500, "seconds": 2.0},
                 {"name": "b", "cands_per_sec": 990, "seconds": 1.05}])
    out = _run(str(old), str(new), "--json")
    assert out.returncode == 0                    # report-only by default
    d = json.loads(out.stdout)
    flagged = {(r["name"], r["field"]) for r in d["regressions"]}
    assert flagged == {("a", "cands_per_sec"), ("a", "seconds")}
    # strict mode exits nonzero on regression
    assert _run(str(old), str(new), "--strict").returncode == 1


def test_improvements_and_info_fields_not_flagged(tmp_path):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [{"name": "a", "cands_per_sec": 1000, "seconds": 2.0,
                  "frontier": 10}])
    _write(new, [{"name": "a", "cands_per_sec": 2000, "seconds": 1.0,
                  "frontier": 99}])
    d = json.loads(_run(str(old), str(new), "--json").stdout)
    assert d["n_regressions"] == 0
    # frontier changed but it's informational, not a perf direction
    info = [c for c in d["changes"] if c["field"] == "frontier"]
    assert info and info[0]["direction"] == "info"
    assert _run(str(old), str(new), "--strict").returncode == 0


def test_suffix_matched_directions(tmp_path):
    """The BPTT kernel benchmark's fields are tracked by suffix:
    ``*_fwd_seconds`` / ``*_bwd_seconds`` / ``*_step_seconds`` regress on
    growth, ``speedup`` / ``fused_speedup`` on drop, ``skip_fraction`` /
    ``bwd_skip_fraction`` on drop (fewer tiles skipped = the sparsity-aware
    design buys less), and ``skip_fraction_profiled`` stays informational
    (its suffix is "_profiled")."""
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [{"name": "kernels/bptt/mnist-mlp/T4/p1",
                  "jnp_step_seconds": 1.0, "spike_gemm_step_seconds": 2.0,
                  "spike_gemm_bwd_seconds": 1.0,
                  "spike_gemm_fused_fwd_seconds": 1.0,
                  "speedup": 0.5, "fused_speedup": 0.5,
                  "skip_fraction": 0.4, "bwd_skip_fraction": 0.4,
                  "skip_fraction_profiled": 0.8}])
    _write(new, [{"name": "kernels/bptt/mnist-mlp/T4/p1",
                  "jnp_step_seconds": 1.0, "spike_gemm_step_seconds": 3.0,
                  "spike_gemm_bwd_seconds": 2.0,
                  "spike_gemm_fused_fwd_seconds": 2.0,
                  "speedup": 0.33, "fused_speedup": 0.33,
                  "skip_fraction": 0.1, "bwd_skip_fraction": 0.1,
                  "skip_fraction_profiled": 0.2}])
    d = json.loads(_run(str(old), str(new), "--json").stdout)
    flagged = {r["field"] for r in d["regressions"]}
    assert flagged == {"spike_gemm_step_seconds", "spike_gemm_bwd_seconds",
                       "spike_gemm_fused_fwd_seconds", "speedup",
                       "fused_speedup", "skip_fraction",
                       "bwd_skip_fraction"}
    info = [c for c in d["changes"]
            if c["field"] == "skip_fraction_profiled"]
    assert info and info[0]["direction"] == "info"


def test_cells_per_second_suffix(tmp_path):
    """The cellstack benchmark's throughput fields end in "_per_second"
    (singular) — they must regress on DROP like "_per_sec" fields, not be
    mistaken for the lower-is-better "seconds" latency suffix.  The same
    line's ``stacked_seconds`` / ``compile_seconds`` stay latency-like and
    ``stack_speedup`` rides the existing "speedup" suffix."""
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [{"name": "cellstack/grid", "cells_per_second": 4.0,
                  "farm_cells_per_second": 1.0, "stack_speedup": 4.0,
                  "stacked_seconds": 1.0, "compile_seconds": 1.0}])
    _write(new, [{"name": "cellstack/grid", "cells_per_second": 1.0,
                  "farm_cells_per_second": 0.25, "stack_speedup": 1.0,
                  "stacked_seconds": 4.0, "compile_seconds": 4.0}])
    d = json.loads(_run(str(old), str(new), "--json").stdout)
    by_field = {c["field"]: c for c in d["changes"]}
    assert by_field["cells_per_second"]["direction"] == "higher_better"
    assert by_field["farm_cells_per_second"]["direction"] == "higher_better"
    assert by_field["stack_speedup"]["direction"] == "higher_better"
    assert by_field["stacked_seconds"]["direction"] == "lower_better"
    assert by_field["compile_seconds"]["direction"] == "lower_better"
    assert {r["field"] for r in d["regressions"]} == {
        "cells_per_second", "farm_cells_per_second", "stack_speedup",
        "stacked_seconds", "compile_seconds"}
    # the mirror run (throughput up, latency down) flags nothing
    d2 = json.loads(_run(str(new), str(old), "--json").stdout)
    assert d2["n_regressions"] == 0


def test_hit_rate_suffix(tmp_path):
    """The service benchmark's cross-tenant ``cache_hit_rate`` ends in
    "_hit_rate" — a DROP regresses (tenants stopped deduplicating each
    other's training), while its sibling throughput fields keep their
    existing suffixes."""
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [{"name": "service/two_tenant", "cache_hit_rate": 0.5,
                  "studies_per_second": 2.0, "events_per_second": 50.0}])
    _write(new, [{"name": "service/two_tenant", "cache_hit_rate": 0.1,
                  "studies_per_second": 0.5, "events_per_second": 10.0}])
    d = json.loads(_run(str(old), str(new), "--json").stdout)
    by_field = {c["field"]: c for c in d["changes"]}
    assert by_field["cache_hit_rate"]["direction"] == "higher_better"
    assert by_field["studies_per_second"]["direction"] == "higher_better"
    assert by_field["events_per_second"]["direction"] == "higher_better"
    assert {r["field"] for r in d["regressions"]} == {
        "cache_hit_rate", "studies_per_second", "events_per_second"}
    # the mirror run (rate and throughput both up) flags nothing
    d2 = json.loads(_run(str(new), str(old), "--json").stdout)
    assert d2["n_regressions"] == 0


def test_threshold_and_duplicate_names(tmp_path):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    # duplicated names pair up in order; count mismatch is skipped w/ a note
    _write(old, [{"name": "cell", "seconds": 1.0},
                 {"name": "cell", "seconds": 1.0},
                 {"name": "odd", "seconds": 1.0},
                 {"name": "odd", "seconds": 1.0}])
    _write(new, [{"name": "cell", "seconds": 1.1},
                 {"name": "cell", "seconds": 3.0},
                 {"name": "odd", "seconds": 9.0}])
    d = json.loads(_run(str(old), str(new), "--json",
                        "--threshold", "0.5").stdout)
    regs = [(r["name"], r["index"]) for r in d["regressions"]]
    assert regs == [("cell", 1)]                  # 10% < 50% threshold
    assert any("odd" in n for n in d["notes"])
