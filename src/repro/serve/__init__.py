"""Serving layer: the LM serving engine (``repro.serve.engine``) and the
multi-tenant DSE service (``repro.serve.dse_service`` — DESIGN.md §15).

The engine stays a submodule import (``from repro.serve import engine``)
because it pulls the full model registry; the DSE service surface is
re-exported here.
"""
from repro.serve.dse_service import DSEService, StudyHandle
from repro.serve.protocol import (EVENT_KINDS, TERMINAL_EVENTS, Event,
                                  FrontierUpdate, Progress, StudyAccepted,
                                  StudyCompleted, StudyEvicted, StudyFailed,
                                  StudyRejected, StudyStarted, Submission,
                                  from_wire, is_terminal, to_wire)

__all__ = [
    "DSEService", "EVENT_KINDS", "Event", "FrontierUpdate", "Progress",
    "StudyAccepted", "StudyCompleted", "StudyEvicted", "StudyFailed",
    "StudyHandle", "StudyRejected", "StudyStarted", "Submission",
    "TERMINAL_EVENTS", "from_wire", "is_terminal", "to_wire",
]
