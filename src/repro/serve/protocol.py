"""Typed protocol for the DSE service: submissions in, events out.

The service (``repro.serve.dse_service``) is transport-agnostic: clients
hand it a :class:`Submission` and read a stream of event dataclasses from
the returned handle.  Every event is **plain data** — frozen dataclasses of
ints/floats/strings/dicts — so the in-process queue transport used today
and a network transport later (JSON over a socket, a log stream, a pub/sub
topic) serialize the exact same objects: ``to_wire`` flattens an event to a
``{"event": kind, ...}`` dict and ``from_wire`` parses it back, round-trip
exact (tests/test_dse_service.py).

Event lifecycle of one submission::

    StudyAccepted ─┬─> StudyStarted ──> (FrontierUpdate | Progress)* ─┐
                   │                                                  │
    StudyRejected ─┘          StudyEvicted <── evict() ───────────────┤
      (terminal)                (resubmit to resume)                  │
                                          StudyCompleted | StudyFailed
                                                   (terminal)

``FrontierUpdate`` events are **monotone**: the driver's incremental
Pareto merge only ever improves the frontier, so in any two successive
updates every earlier point is either still present or dominated by a
newer one — clients can render each snapshot as-is, no reconciliation.
``Progress`` events carry the evaluation/cache/budget counters
(cross-tenant dedup shows up here as hits on cells another tenant
trained).

The :class:`Submission` mirrors ``dse.explore``'s surface.  In-process it
carries live objects (``SearchSpace``, ``Workload``, strategy); a network
transport would serialize these — the *event* side needs no such work.
``strategy`` may be a zero-arg factory: the service calls it per study
construction, so a resubmission after a service restart gets the fresh,
identically-configured instance ``Study.load``'s signature guard demands.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Union


@dataclasses.dataclass(frozen=True)
class Submission:
    """One tenant's study request: which space to explore, under what
    training quota accounting (the service attaches the tenant's shared
    ``TrainingBudget``), against the service-wide shared trace cache.

    ``(tenant, name)`` identifies the study; resubmitting the same pair
    after an eviction or a service restart resumes from its checkpoint.
    """
    tenant: str
    name: str
    # the exploration definition (mirrors dse.explore)
    space: Any = None                      # SearchSpace | None
    workload: Any = None                   # str | Workload | None
    datasets: Optional[Sequence] = None
    num_steps: Optional[Sequence[int]] = None
    population: Optional[Sequence[float]] = None
    max_lhr: Optional[int] = None
    weight_bits: Optional[Sequence[int]] = None
    # hardware-only evaluation context
    config: Any = None                     # AcceleratorConfig | None
    counts: Optional[Sequence] = None
    # search
    strategy: Union[str, Callable, Any] = "grid"   # instance | factory | name
    objectives: Optional[tuple[str, ...]] = None
    chunk_size: int = 65536
    seed: int = 0

    def __post_init__(self):
        for field in ("tenant", "name"):
            value = getattr(self, field)
            if not value or not str(value).replace("-", "").replace(
                    "_", "").replace(".", "").isalnum():
                raise ValueError(
                    f"{field} must be a non-empty [A-Za-z0-9._-] string "
                    f"(it names the study's checkpoint directory), "
                    f"got {value!r}")

    @property
    def study_id(self) -> str:
        return f"{self.tenant}/{self.name}"


# ---- events ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: every event names the study and tenant it belongs to."""
    study_id: str
    tenant: str


@dataclasses.dataclass(frozen=True)
class StudyAccepted(Event):
    """Admission control let the submission in; ``position`` is its place
    in the pending queue (0 = will activate on the next scheduling turn)."""
    position: int


@dataclasses.dataclass(frozen=True)
class StudyRejected(Event):
    """Admission control bounced the submission (queue full, duplicate id,
    or tenant over quota with ``reject_over_quota``).  Terminal."""
    reason: str


@dataclasses.dataclass(frozen=True)
class StudyStarted(Event):
    """The study was activated; ``resumed`` means it restored a checkpoint
    (service restart / readmission after eviction) instead of starting
    fresh — resumed studies retrain nothing (content-addressed cache)."""
    resumed: bool


@dataclasses.dataclass(frozen=True)
class FrontierUpdate(Event):
    """The study's Pareto frontier changed this round.  ``frontier`` is the
    full snapshot (column name -> list of values; per-layer columns nest).
    Successive snapshots are monotone — see the module docstring."""
    round: int
    n_evaluated: int
    frontier_size: int
    objectives: tuple[str, ...]
    frontier: dict


@dataclasses.dataclass(frozen=True)
class Progress(Event):
    """Periodic bookkeeping: evaluation counters plus the shared-cache and
    training-budget accounting (``cache`` holds hits/misses/farmed_misses;
    ``budget`` holds limit/spent/remaining or None when unmetered)."""
    round: int
    n_evaluated: int
    frontier_size: int
    cells_resolved: int
    cells_skipped: int
    cache: dict
    budget: Optional[dict]


@dataclasses.dataclass(frozen=True)
class StudyEvicted(Event):
    """The study was checkpointed and deactivated (capacity reclaim or
    service shutdown).  Resubmit the same (tenant, name) to resume from
    ``checkpoint_dir``; None means there was no checkpoint_root and the
    in-flight progress (not the trained cells — those live in the cache)
    was dropped."""
    checkpoint_dir: Optional[str]


@dataclasses.dataclass(frozen=True)
class StudyFailed(Event):
    """The study raised; other tenants' studies are unaffected.  Terminal."""
    error: str


@dataclasses.dataclass(frozen=True)
class StudyCompleted(Event):
    """The study ran to completion; ``summary`` is ``Study.summary``
    (mode, counters, cache/budget accounting).  Terminal."""
    summary: dict


#: event classes that end a submission's stream
TERMINAL_EVENTS = (StudyRejected, StudyFailed, StudyCompleted)

#: wire-kind -> event class (the "event" discriminator of ``to_wire``)
EVENT_KINDS = {cls.__name__: cls for cls in
               (StudyAccepted, StudyRejected, StudyStarted, FrontierUpdate,
                Progress, StudyEvicted, StudyFailed, StudyCompleted)}


#: wire kind for a spooled fleet training job (repro.distributed.fleet):
#: not an event, but it rides the same ``to_wire``/``from_wire`` envelope so
#: the job spool and a future network transport share one serializer
_JOB_KIND = "CellJob"


def is_terminal(event: Event) -> bool:
    return isinstance(event, TERMINAL_EVENTS)


def to_wire(obj) -> dict:
    """Event (or ``cellfarm.CellJob``) -> flat JSON-safe dict with an
    ``"event"`` kind discriminator (what a network transport would
    serialize, e.g. ``json.dumps``)."""
    if isinstance(obj, Event):
        return {"event": type(obj).__name__, **dataclasses.asdict(obj)}
    from repro.distributed.cellfarm import CellJob   # lazy: pulls jax
    if isinstance(obj, CellJob):
        return {"event": _JOB_KIND,
                "workload": _workload_to_wire(obj.workload),
                "assignment": {k: (int(v) if k == "num_steps" else float(v))
                               for k, v in obj.assignment.items()},
                "seed": int(obj.seed),
                "quant_bits": [int(b) for b in obj.quant_bits]}
    raise TypeError(f"to_wire takes an Event or a CellJob, "
                    f"got {type(obj).__name__}")


def from_wire(wire: dict) -> "Event":
    """Inverse of :func:`to_wire` (tuple fields re-tupled so the round
    trip survives a JSON hop, which turns tuples into lists)."""
    wire = dict(wire)
    kind = wire.pop("event")
    if kind == _JOB_KIND:
        from repro.distributed.cellfarm import CellJob
        return CellJob(workload=_workload_from_wire(wire["workload"]),
                       assignment=dict(wire["assignment"]),
                       seed=int(wire["seed"]),
                       quant_bits=tuple(int(b) for b in wire["quant_bits"]))
    cls = EVENT_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown event kind {kind!r}; "
                         f"known: {sorted(EVENT_KINDS) + [_JOB_KIND]}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = set(wire) - set(fields)
    if unknown:
        raise ValueError(f"{kind} does not take fields {sorted(unknown)}")
    for name, value in wire.items():
        if fields[name].type.startswith("tuple") and isinstance(value, list):
            wire[name] = tuple(value)
    return cls(**wire)


# ---- workload wire format ---------------------------------------------------
# A Workload is all primitives except ``layers`` (snn.Dense/Conv/MaxPool
# dataclasses), which serialize with a "kind" tag.  Exact round trip:
# frozen-dataclass equality holds across the JSON hop.

def _workload_to_wire(wl) -> dict:
    from repro.core import snn
    d = dataclasses.asdict(wl)
    d["layers"] = [_layer_to_wire(spec, snn) for spec in wl.layers]
    return d


def _layer_to_wire(spec, snn) -> dict:
    if isinstance(spec, snn.MaxPool):
        return {"kind": "pool", "window": spec.window}
    kind = "dense" if isinstance(spec, snn.Dense) else "conv"
    d = {"kind": kind, **dataclasses.asdict(spec)}
    return d


def _workload_from_wire(d: dict):
    from repro.core import snn
    from repro.core.workloads.registry import Workload
    d = dict(d)
    d["layers"] = tuple(_layer_from_wire(ld, snn) for ld in d["layers"])
    for name in ("input_shape", "num_steps_choices", "population_choices"):
        d[name] = tuple(d[name])
    return Workload(**d)


def _layer_from_wire(ld: dict, snn):
    ld = dict(ld)
    kind = ld.pop("kind")
    if kind == "pool":
        return snn.MaxPool(**ld)
    if "lif" in ld:
        ld["lif"] = snn.LIFParams(**ld["lif"])
    return {"dense": snn.Dense, "conv": snn.Conv}[kind](**ld)
