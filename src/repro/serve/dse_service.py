"""DSE-as-a-service: a long-running, multi-tenant exploration service.

``dse.explore`` is a blocking library call on one interpreter; this module
is the service layer the ROADMAP's "heavy traffic" story needs on top of
it, built entirely from existing library contracts:

* **Concurrency** — every admitted study is driven cooperatively through
  incremental ``Study.step()`` rounds on one scheduler loop (round-robin,
  one round per study per turn), so N tenants' studies interleave without
  thread-per-study state.  Pending cell training still fans out over the
  shared ``cellfarm`` process pool / ``cellstack`` vmapped stacks when the
  service is constructed with ``workers``/``stack`` — and over *hosts*
  with ``workers="cluster"``: a service whose ``TraceCache`` root sits on
  an NFS-style mount spools every study's pending cells to the root's job
  queue, where lease-holding ``fleet.FleetWorker`` processes on every
  enrolled machine drain them (``repro.distributed.fleet``), saturating
  the whole fleet from one scheduler.
* **Dedup for free** — all tenants share one content-addressed
  ``TraceCache``: the first study to reach a model cell trains it, every
  later study (any tenant) resolves it as a hit.  Overlapping cells across
  concurrent studies train at most once — the cross-tenant ``hit_rate`` in
  ``Progress`` events and ``benchmarks/bench_service.py`` measures exactly
  this.
* **Admission control** — a bounded pending queue (past ``max_pending``:
  rejected), at most ``max_active`` concurrently stepping studies (past
  capacity: queued), and per-tenant training quotas mapped onto shared
  ``TrainingBudget`` objects (all of one tenant's studies charge the same
  budget; ``reject_over_quota`` optionally bounces submissions from
  exhausted tenants at the door).  Budgets are thread-safe, so tenant
  studies stepping from other drivers share them safely.
* **Streaming** — each handle owns a thread-safe event queue fed by the
  scheduler: monotone ``FrontierUpdate`` snapshots (the incremental Pareto
  merge never regresses) plus ``Progress`` cache/budget counters, typed per
  ``repro.serve.protocol`` so a network transport is a serialization away.
* **Restart** — with a ``checkpoint_root``, studies checkpoint on eviction,
  on completion, and every ``checkpoint_every`` rounds (through ``Study``'s
  atomic sidecar protocol); resubmitting the same ``(tenant, name)`` —
  after an eviction or a full service restart — resumes via
  ``explore(..., resume=True)`` with **zero retraining**, and the tenant
  budgets round-trip through a ``service.json`` sidecar (written after
  study checkpoints, so it is always at least as fresh as any per-study
  budget copy).

See DESIGN.md §15 and ``examples/serve_dse.py``.
"""
from __future__ import annotations

import collections
import json
import os
import queue
import threading
import time
from typing import Iterator, Optional, Union

from repro.core import dse
from repro.core.workloads import TraceCache, TrainingBudget
from repro.serve import protocol
from repro.serve.protocol import (Event, FrontierUpdate, Progress,
                                  StudyAccepted, StudyCompleted,
                                  StudyEvicted, StudyFailed, StudyRejected,
                                  StudyStarted, Submission, is_terminal)

_SERVICE_SIDECAR = "service.json"


class StudyHandle:
    """A client's view of one submitted study: its status, its event
    stream, and (after completion) the frontier/result surface."""

    def __init__(self, submission: Submission):
        self.submission = submission
        self.study_id = submission.study_id
        self.tenant = submission.tenant
        self.status = "pending"      # pending|active|completed|failed|
        #                              evicted|rejected
        self.study: Optional[dse.Study] = None
        self.error: Optional[str] = None
        self._events: "queue.Queue[Event]" = queue.Queue()
        self._seen_frontier_version = 0
        self._terminal = threading.Event()

    # ---- event stream ------------------------------------------------------
    def events(self) -> list[Event]:
        """Drain every event queued so far (non-blocking)."""
        out = []
        while True:
            try:
                out.append(self._events.get_nowait())
            except queue.Empty:
                return out

    def next_event(self, timeout: Optional[float] = None) -> Optional[Event]:
        """Next event, waiting up to ``timeout`` seconds (None = forever);
        None on timeout."""
        try:
            return self._events.get(timeout=timeout)
        except queue.Empty:
            return None

    def stream(self, timeout: Optional[float] = None) -> Iterator[Event]:
        """Yield events until (and including) a terminal event.  With the
        scheduler on a background thread this blocks like a subscription;
        ``timeout`` bounds each wait, raising on silence."""
        while True:
            event = self.next_event(timeout)
            if event is None:
                raise TimeoutError(
                    f"no event from {self.study_id} within {timeout}s")
            yield event
            if is_terminal(event):
                return

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the study reaches a terminal state."""
        return self._terminal.wait(timeout)

    # ---- results -----------------------------------------------------------
    @property
    def frontier(self):
        if self.study is None:
            raise RuntimeError(f"study {self.study_id} was never activated "
                               f"(status: {self.status})")
        return self.study.frontier

    @property
    def summary(self) -> dict:
        if self.study is None:
            return {"status": self.status}
        return {"status": self.status, **self.study.summary}

    # ---- service-side ------------------------------------------------------
    def _emit(self, event: Event) -> None:
        self._events.put(event)
        if is_terminal(event):
            self._terminal.set()


class DSEService:
    """The multi-tenant exploration service.  Drive it cooperatively
    (``tick()`` / ``run_until_idle()``) or on a background thread
    (``start()`` / ``stop()``); both paths share the same scheduler."""

    def __init__(self, cache: Optional[TraceCache] = None, *,
                 checkpoint_root: Optional[str] = None,
                 max_active: int = 2,
                 max_pending: int = 64,
                 tenant_quota: Optional[int] = None,
                 tenant_quotas: Optional[dict[str, int]] = None,
                 reject_over_quota: bool = False,
                 workers: Union[int, str] = 0,
                 stack: bool = False,
                 checkpoint_every: Optional[int] = None,
                 progress_every: int = 1):
        if max_active < 1:
            raise ValueError(f"max_active must be >= 1, got {max_active}")
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        self.cache = cache if cache is not None else TraceCache()
        self.checkpoint_root = checkpoint_root
        self.max_active = max_active
        self.max_pending = max_pending
        self.tenant_quota = tenant_quota
        self.tenant_quotas = dict(tenant_quotas or {})
        self.reject_over_quota = reject_over_quota
        self.workers = workers
        self.stack = stack
        self.checkpoint_every = checkpoint_every
        self.progress_every = max(1, int(progress_every))

        self._budgets: dict[str, Optional[TrainingBudget]] = {}
        self._handles: dict[str, StudyHandle] = {}
        self._pending: collections.deque[StudyHandle] = collections.deque()
        self._active: list[StudyHandle] = []
        self._lock = threading.Lock()        # guards queues + registries
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._counters = collections.Counter()
        self._persisted_budgets = self._read_sidecar()

    # ---- submission / admission -------------------------------------------
    def submit(self, submission: Submission) -> StudyHandle:
        """Admission control: reject on duplicate id, full queue, or (with
        ``reject_over_quota``) an exhausted tenant; otherwise queue.  The
        scheduler activates queued studies as slots free up."""
        handle = StudyHandle(submission)
        with self._lock:
            self._counters["submitted"] += 1
            reason = self._admission_reason(submission)
            if reason is not None:
                handle.status = "rejected"
                self._counters["rejected"] += 1
                self._handles.setdefault(handle.study_id, handle)
                self._emit(handle, StudyRejected(
                    handle.study_id, handle.tenant, reason=reason))
                return handle
            self._handles[handle.study_id] = handle
            self._pending.append(handle)
            self._emit(handle, StudyAccepted(
                handle.study_id, handle.tenant,
                position=len(self._pending) - 1))
        return handle

    def _admission_reason(self, sub: Submission) -> Optional[str]:
        live = self._handles.get(sub.study_id)
        if live is not None and live.status in ("pending", "active"):
            return f"study {sub.study_id} is already {live.status}"
        if len(self._pending) >= self.max_pending:
            return (f"pending queue is full "
                    f"({len(self._pending)}/{self.max_pending})")
        if self.reject_over_quota:
            budget = self._budget_for(sub.tenant)
            if budget is not None and budget.remaining <= 0:
                return (f"tenant {sub.tenant!r} training quota exhausted "
                        f"({budget.spent}/{budget.limit} misses)")
        return None

    def handle(self, study_id: str) -> StudyHandle:
        return self._handles[study_id]

    def budget(self, tenant: str) -> Optional[TrainingBudget]:
        """The tenant's shared training budget (None = unmetered)."""
        return self._budget_for(tenant)

    def _budget_for(self, tenant: str) -> Optional[TrainingBudget]:
        if tenant not in self._budgets:
            quota = self.tenant_quotas.get(tenant, self.tenant_quota)
            budget = None if quota is None else TrainingBudget(int(quota))
            if budget is not None and tenant in self._persisted_budgets:
                budget.load_state_dict(self._persisted_budgets[tenant])
            self._budgets[tenant] = budget
        return self._budgets[tenant]

    # ---- scheduling --------------------------------------------------------
    def tick(self) -> bool:
        """One scheduling turn: admit from the queue into free slots, then
        step every active study one round (emitting events).  Returns False
        when there is nothing active and nothing pending — idle."""
        self._admit()
        with self._lock:
            turn = list(self._active)
        for handle in turn:
            self._step_one(handle)
        self._admit()
        with self._lock:
            return bool(self._active or self._pending)

    def run_until_idle(self) -> None:
        """Drive the scheduler inline until every submitted study reached a
        terminal state (the cooperative single-thread mode)."""
        while self.tick():
            pass

    def start(self) -> None:
        """Run the scheduler on a background thread until ``stop()``."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="dse-service", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            if not self.tick():
                time.sleep(0.005)          # idle: poll the submission queue

    def _admit(self) -> None:
        while True:
            with self._lock:
                if not self._pending or len(self._active) >= self.max_active:
                    return
                handle = self._pending.popleft()
                self._active.append(handle)
            self._activate(handle)

    def _activate(self, handle: StudyHandle) -> None:
        sub = handle.submission
        ck_dir = self._study_dir(sub)
        resume = (ck_dir is not None
                  and os.path.exists(os.path.join(ck_dir, "study.json")))
        try:
            strategy = (sub.strategy() if callable(sub.strategy)
                        else sub.strategy)
            kwargs = dict(strategy=strategy, objectives=sub.objectives,
                          chunk_size=sub.chunk_size, checkpoint_dir=ck_dir,
                          resume=resume, run=False)
            if self._is_joint(sub):
                kwargs.update(
                    workload=sub.workload, datasets=sub.datasets,
                    num_steps=sub.num_steps, population=sub.population,
                    max_lhr=sub.max_lhr, weight_bits=sub.weight_bits,
                    cache=self.cache, seed=sub.seed,
                    train_budget=self._budget_for(sub.tenant),
                    workers=self.workers, stack=self.stack)
            else:
                kwargs.update(config=sub.config, counts=sub.counts)
            study = dse.explore(sub.space, **kwargs)
        except Exception as e:                           # noqa: BLE001
            self._fail(handle, e)
            return
        if resume:
            # Study.load restored the checkpoint's budget copy into the
            # shared tenant budget; the service sidecar (written after
            # every study checkpoint) is at least as fresh — reapply it.
            self._restore_tenant_budget(sub.tenant)
        handle.study = study
        handle._seen_frontier_version = study.frontier_version
        handle.status = "active"
        handle._emit(StudyStarted(handle.study_id, handle.tenant,
                                  resumed=resume))
        if resume and study.frontier_version:
            self._emit_frontier(handle)     # restored frontier, first event
        if study.done:                      # resumed an already-done study
            self._complete(handle)

    @staticmethod
    def _is_joint(sub: Submission) -> bool:
        return (sub.workload is not None or sub.datasets is not None
                or sub.num_steps is not None or sub.population is not None
                or (sub.space is not None and bool(sub.space.model_axes)))

    def _step_one(self, handle: StudyHandle) -> None:
        if handle.status != "active":
            return
        study = handle.study
        try:
            advanced = study.step()
        except Exception as e:                           # noqa: BLE001
            self._fail(handle, e)
            return
        if not advanced:
            self._complete(handle)
            return
        if study.frontier_version != handle._seen_frontier_version:
            self._emit_frontier(handle)
        if study.rounds % self.progress_every == 0:
            self._emit_progress(handle)
        if (self.checkpoint_every and study.checkpoint_dir
                and study.rounds % self.checkpoint_every == 0):
            study.checkpoint()
            self._write_sidecar()

    # ---- lifecycle transitions --------------------------------------------
    def _complete(self, handle: StudyHandle) -> None:
        study = handle.study
        if study.checkpoint_dir:
            study.checkpoint()
        handle.status = "completed"
        with self._lock:
            self._deactivate(handle)
            self._counters["completed"] += 1
        self._emit(handle, StudyCompleted(handle.study_id, handle.tenant,
                                          summary=study.summary))
        self._write_sidecar()

    def _fail(self, handle: StudyHandle, error: Exception) -> None:
        handle.status = "failed"
        handle.error = f"{type(error).__name__}: {error}"
        with self._lock:
            self._deactivate(handle)
            self._counters["failed"] += 1
        self._emit(handle, StudyFailed(handle.study_id, handle.tenant,
                                       error=handle.error))

    def evict(self, study_id: str) -> Optional[str]:
        """Checkpoint and deactivate one active study, freeing its slot
        (capacity reclaim / shutdown).  Resubmitting the same (tenant,
        name) resumes it with zero retraining.  Returns the checkpoint
        directory (None when the service has no checkpoint_root — progress
        beyond the trained cells is dropped)."""
        handle = self._handles[study_id]
        if handle.status != "active":
            raise ValueError(f"study {study_id} is not active "
                             f"(status: {handle.status})")
        ck_dir = None
        if handle.study is not None and handle.study.checkpoint_dir:
            ck_dir = handle.study.checkpoint()
        handle.status = "evicted"
        with self._lock:
            self._deactivate(handle)
            self._counters["evicted"] += 1
        self._emit(handle, StudyEvicted(handle.study_id, handle.tenant,
                                        checkpoint_dir=ck_dir))
        self._write_sidecar()
        return ck_dir

    def shutdown(self) -> None:
        """Stop the scheduler and evict every active study (each one
        checkpoints when a checkpoint_root is set); pending studies stay
        pending in their handles but are dropped from the queue.  A new
        service on the same checkpoint_root + cache resumes resubmitted
        studies without retraining."""
        self.stop()
        with self._lock:
            active = list(self._active)
            self._pending.clear()
        for handle in active:
            self.evict(handle.study_id)
        self._write_sidecar()

    def _deactivate(self, handle: StudyHandle) -> None:
        if handle in self._active:
            self._active.remove(handle)

    # ---- event emission ----------------------------------------------------
    def _emit(self, handle: StudyHandle, event: Event) -> None:
        self._counters["events_emitted"] += 1
        handle._emit(event)

    def _emit_frontier(self, handle: StudyHandle) -> None:
        study = handle.study
        handle._seen_frontier_version = study.frontier_version
        frontier = {k: (v.tolist() if hasattr(v, "tolist") else list(v))
                    for k, v in study.frontier.columns.items()}
        self._emit(handle, FrontierUpdate(
            handle.study_id, handle.tenant, round=study.rounds,
            n_evaluated=study.n_evaluated,
            frontier_size=len(study.frontier),
            objectives=study.objectives, frontier=frontier))

    def _emit_progress(self, handle: StudyHandle) -> None:
        study = handle.study
        s = study.summary
        self._emit(handle, Progress(
            handle.study_id, handle.tenant, round=study.rounds,
            n_evaluated=study.n_evaluated,
            frontier_size=len(study.frontier),
            cells_resolved=s.get("cells_resolved", 0),
            cells_skipped=s.get("cells_skipped", 0),
            cache=s.get("cache", {}),
            budget=s.get("train_budget")))

    # ---- persistence -------------------------------------------------------
    def _study_dir(self, sub: Submission) -> Optional[str]:
        if self.checkpoint_root is None:
            return None
        return os.path.join(self.checkpoint_root, sub.tenant, sub.name)

    def _restore_tenant_budget(self, tenant: str) -> None:
        budget = self._budgets.get(tenant)
        if budget is not None and tenant in self._persisted_budgets:
            budget.load_state_dict(self._persisted_budgets[tenant])

    def _write_sidecar(self) -> None:
        """Persist the tenant budget states (atomically, after any study
        checkpoints) so a restarted service resumes quota accounting."""
        if self.checkpoint_root is None:
            return
        with self._lock:
            state = {"tenants": {t: b.state_dict()
                                 for t, b in self._budgets.items()
                                 if b is not None}}
            self._persisted_budgets.update(state["tenants"])
        os.makedirs(self.checkpoint_root, exist_ok=True)
        path = os.path.join(self.checkpoint_root, _SERVICE_SIDECAR)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, path)

    def _read_sidecar(self) -> dict:
        if self.checkpoint_root is None:
            return {}
        path = os.path.join(self.checkpoint_root, _SERVICE_SIDECAR)
        if not os.path.exists(path):
            return {}
        with open(path) as f:
            return dict(json.load(f).get("tenants", {}))

    # ---- introspection -----------------------------------------------------
    @property
    def stats(self) -> dict:
        """Service-level counters + the shared cache's hit/miss accounting
        (``hit_rate`` is the cross-tenant deduplication measure)."""
        with self._lock:
            out = {k: self._counters[k]
                   for k in ("submitted", "rejected", "completed", "failed",
                             "evicted", "events_emitted")}
            out["active"] = len(self._active)
            out["pending"] = len(self._pending)
        cache = dict(self.cache.stats)
        total = cache.get("hits", 0) + cache.get("misses", 0)
        cache["hit_rate"] = cache.get("hits", 0) / total if total else 0.0
        out["cache"] = cache
        return out
