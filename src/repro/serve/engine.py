"""Serving engine: batched prefill + decode steps with sharded KV/state
caches, greedy/temperature sampling, and simple continuous-batching
bookkeeping on the host side.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding
from repro.models import registry

PyTree = Any


def build_prefill_step(cfg: ArchConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return registry.prefill(params, cfg, batch, max_len)

    return prefill_step


def build_decode_step(cfg: ArchConfig) -> Callable:
    """serve_step: one new token for every sequence in the batch."""

    def decode_step(params, batch):
        logits, cache = registry.decode_step(params, cfg, batch["token"],
                                             batch["cache"])
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return {"logits": logits, "next_token": next_token, "cache": cache}

    return decode_step


def serve_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    mode: str = "decode"):
    """(params, decode-batch) NamedShardings for the serve_step.

    decode: 2D-TP weights (no FSDP all-gathers; see sharding.serve_param_specs)
    prefill: training-style sharding incl. FSDP — a 32k-token prefill
    amortizes the per-layer weight gathers, and FSDP keeps the per-device
    resident weights 16x smaller (qwen iter 5).
    """
    params_s = jax.eval_shape(
        lambda: registry.init_params(jax.random.key(0), cfg))
    if mode == "decode":
        p_specs = sharding.serve_param_specs(cfg, params_s, mesh)
    else:
        p_specs = sharding.param_specs(cfg, params_s, mesh)
    cache_s = jax.eval_shape(
        lambda: registry.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_specs = sharding.cache_specs(cfg, cache_s, mesh, shape.global_batch)
    tok_spec = sharding.batch_specs(
        cfg, {"token": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                            jnp.int32)}, mesh)["token"]
    batch_specs = {"token": tok_spec, "cache": c_specs}
    return (sharding.to_named(p_specs, mesh),
            sharding.to_named(batch_specs, mesh), params_s, cache_s)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Minimal batched serving loop (single host): pads requests into a
    fixed decode batch, runs prefill once and decode steps until done.
    Demonstrates the serving substrate end-to-end on CPU."""

    def __init__(self, cfg: ArchConfig, params, batch_size: int,
                 max_len: int):
        self.cfg, self.params = cfg, params
        self.batch_size, self.max_len = batch_size, max_len
        self._prefill = jax.jit(build_prefill_step(cfg, max_len))
        self._decode = jax.jit(build_decode_step(cfg))

    def run(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.batch_size
        prompts = [r.prompt for r in requests]
        plen = max(len(p) for p in prompts)
        toks = np.zeros((self.batch_size, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p                     # left-pad
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        token = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        steps = max(r.max_new_tokens for r in requests)
        for _ in range(steps):
            for i, r in enumerate(requests):
                if not r.done:
                    r.generated.append(int(token[i, 0]))
                    r.done = len(r.generated) >= r.max_new_tokens
            if all(r.done for r in requests):
                break
            out = self._decode(self.params, {"token": token, "cache": cache})
            token, cache = out["next_token"][:, None], out["cache"]
        return requests
