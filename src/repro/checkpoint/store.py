"""Sharded, atomic, async-capable checkpointing (no orbax in this container —
msgpack + zstandard + numpy are the wire format).

Layout:  <dir>/step_<N>/manifest.msgpack   (treedef-ordered leaf metadata
                                            + compression codec)
         <dir>/step_<N>/leaves.bin.zst     (concatenated raw leaf bytes;
                                            zstd, or zlib where the
                                            zstandard package is missing —
                                            the manifest records which)

Guarantees:
  * atomic publish — data is written to ``.tmp-<N>`` and ``os.replace``d,
    so a crash mid-save never corrupts the latest checkpoint;
  * restore onto a DIFFERENT mesh / sharding (elastic scaling): leaves are
    loaded on host and ``device_put`` with the new shardings;
  * async save — the host copy is snapshotted synchronously (cheap), the
    compression+IO runs on a background thread;
  * ``keep_last`` retention.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

import zlib

try:
    import zstandard
except ImportError:          # container without zstd bindings: zlib fallback
    zstandard = None


class _ZlibWriter:
    def __init__(self, f, level):
        self._f = f
        self._c = zlib.compressobj(level)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._f.write(self._c.flush())

    def write(self, b):
        self._f.write(self._c.compress(b))


class _ZlibReader:
    def __init__(self, f):
        self._f = f
        self._d = zlib.decompressobj()
        self._buf = b""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass

    def read(self, n):
        while len(self._buf) < n:
            chunk = self._f.read(1 << 20)
            if not chunk:
                self._buf += self._d.flush()
                break
            self._buf += self._d.decompress(chunk)
        out, self._buf = self._buf[:n], self._buf[n:]
        return out


def _writer(f):
    """Best available compressor + its codec tag (recorded in the manifest
    so restore never guesses)."""
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).stream_writer(f), "zstd"
    return _ZlibWriter(f, 3), "zlib"


def _reader(f, codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the zstandard package "
                "is not installed in this environment")
        return zstandard.ZstdDecompressor().stream_reader(f)
    if codec == "zlib":
        return _ZlibReader(f)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


PyTree = Any

_MANIFEST = "manifest.msgpack"
_DATA = "leaves.bin.zst"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree: PyTree,
         keep_last: Optional[int] = None) -> str:
    """Synchronous checkpoint save.  Returns the published path."""
    leaves = jax.tree.leaves(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    return _write(directory, step, host, keep_last)


def save_async(directory: str, step: int, tree: PyTree,
               keep_last: Optional[int] = None) -> threading.Thread:
    """Snapshot to host now; compress+write on a background thread."""
    leaves = jax.tree.leaves(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    t = threading.Thread(target=_write, args=(directory, step, host, keep_last),
                         daemon=True)
    t.start()
    return t


def _write(directory: str, step: int, host: list[np.ndarray],
           keep_last: Optional[int]) -> str:
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta, blobs = [], []
    for arr in host:
        # NB: np.ascontiguousarray promotes 0-d -> 1-d; record shape first
        shape = list(arr.shape)
        data = np.ascontiguousarray(arr)
        meta.append({"shape": shape, "dtype": str(data.dtype),
                     "nbytes": data.nbytes})
        blobs.append(data.tobytes())
    with open(os.path.join(tmp, _DATA), "wb") as f:
        w, codec = _writer(f)
        with w:
            for b in blobs:
                w.write(b)
    with open(os.path.join(tmp, _MANIFEST), "wb") as f:
        f.write(msgpack.packb({"step": step, "codec": codec, "leaves": meta}))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    if keep_last:
        for old in all_steps(directory)[:-keep_last]:
            shutil.rmtree(_step_dir(directory, old), ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None,
            device: bool = True) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    pass the new mesh's shardings to reshard on restore (elastic restart on
    a different topology).

    ``device=False`` returns host NumPy arrays at the *exact* ``like``
    dtypes instead of ``jnp`` arrays — required for consumers that must
    round-trip float64/int64 bit-exactly (e.g. ``dse.Study`` frontier
    checkpoints), since ``jnp.asarray`` truncates those to 32-bit when
    x64 is disabled.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = _step_dir(directory, step)
    with open(os.path.join(path, _MANIFEST), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_like, treedef = jax.tree.flatten(like)
    meta = manifest["leaves"]
    assert len(meta) == len(leaves_like), (
        f"checkpoint has {len(meta)} leaves, target tree has "
        f"{len(leaves_like)}")
    codec = manifest.get("codec", "zstd")     # pre-codec checkpoints: zstd
    host = []
    with open(os.path.join(path, _DATA), "rb") as f:
        with _reader(f, codec) as r:
            for m, want in zip(meta, leaves_like):
                buf = r.read(m["nbytes"])
                arr = np.frombuffer(buf, dtype=np.dtype(m["dtype"])
                                    ).reshape(m["shape"])
                assert tuple(arr.shape) == tuple(want.shape), (
                    arr.shape, want.shape)
                host.append(arr)
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "memory_kind"))
        if shardings is not None else [None] * len(host))
    out = []
    for arr, wanted, shard in zip(host, leaves_like, shard_leaves):
        if not device:
            out.append(np.asarray(arr, dtype=wanted.dtype))
            continue
        x = jnp.asarray(arr, dtype=wanted.dtype)
        if shard is not None:
            x = jax.device_put(x, shard)
        out.append(x)
    return treedef.unflatten(out)
