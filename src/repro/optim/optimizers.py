"""Pytree optimizers.

Protocol (optax-compatible shape):

    tx = adamw(lr=..., ...)
    state  = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees of arrays, so they shard exactly like the parameters
they track (ZeRO-1 falls out of passing sharded ``params`` at init).
``adafactor_lite`` provides a factored second moment for very large models
where full Adam state would not fit HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GradientTransform:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.asarray(0.0, jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


# ---------------------------------------------------------------------------
# Elementary transforms
# ---------------------------------------------------------------------------

class ScaleState(NamedTuple):
    count: jax.Array


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> GradientTransform:
    def init(params):
        del params
        return ScaleState(count=jnp.zeros([], jnp.int32))

    def update(updates, state, params=None):
        del params
        lr = schedule(state.count)
        updates = jax.tree.map(lambda u: -lr * u, updates)
        return updates, ScaleState(count=state.count + 1)

    return GradientTransform(init, update)


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransform:
    def init(params):
        del params
        return ClipState()

    def update(updates, state, params=None):
        del params
        norm = global_norm(updates)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        updates = jax.tree.map(lambda u: u * scale, updates)
        return updates, state

    return GradientTransform(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def _scale_by_adam(b1: float, b2: float, eps: float,
                   state_dtype: jnp.dtype) -> GradientTransform:
    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype), params)
        return AdamState(count=jnp.zeros([], jnp.int32), mu=mu, nu=nu)

    def update(updates, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(state_dtype),
                          state.mu, updates)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(state_dtype)), state.nu, updates)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return upd, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransform(init, update)


class WeightDecayState(NamedTuple):
    pass


def _add_decayed_weights(weight_decay: float,
                         mask_fn: Optional[Callable] = None) -> GradientTransform:
    def init(params):
        del params
        return WeightDecayState()

    def update(updates, state, params=None):
        assert params is not None, "weight decay needs params"
        if mask_fn is None:
            updates = jax.tree.map(
                lambda u, p: u + weight_decay * p.astype(u.dtype), updates, params)
        else:
            mask = mask_fn(params)
            updates = jax.tree.map(
                lambda u, p, m: u + (weight_decay * p.astype(u.dtype) if m else 0.0),
                updates, params, mask)
        return updates, state

    return GradientTransform(init, update)


def chain(*transforms: GradientTransform) -> GradientTransform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransform(init, update)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------

def sgd(learning_rate, momentum: float = 0.0) -> GradientTransform:
    schedule = learning_rate if callable(learning_rate) else (lambda _: jnp.float32(learning_rate))

    class MomState(NamedTuple):
        count: jax.Array
        trace: PyTree

    def init(params):
        trace = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return MomState(count=jnp.zeros([], jnp.int32), trace=trace)

    def update(updates, state, params=None):
        del params
        if momentum:
            trace = jax.tree.map(lambda t, g: momentum * t + g, state.trace, updates)
            updates = trace
        else:
            trace = None
        lr = schedule(state.count)
        updates = jax.tree.map(lambda u: -lr * u, updates)
        return updates, MomState(count=state.count + 1, trace=trace)

    return GradientTransform(init, update)


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         state_dtype=jnp.float32) -> GradientTransform:
    schedule = learning_rate if callable(learning_rate) else constant(learning_rate)
    return chain(_scale_by_adam(b1, b2, eps, state_dtype), scale_by_schedule(schedule))


def adamw(learning_rate, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0,
          state_dtype=jnp.float32,
          decay_mask_fn: Optional[Callable] = None) -> GradientTransform:
    """AdamW with optional global-norm clipping — the LM-training default."""
    schedule = learning_rate if callable(learning_rate) else constant(learning_rate)
    parts = []
    if clip_norm is not None:
        parts.append(clip_by_global_norm(clip_norm))
    parts.append(_scale_by_adam(b1, b2, eps, state_dtype))
    parts.append(_add_decayed_weights(weight_decay, decay_mask_fn))
    parts.append(scale_by_schedule(schedule))
    return chain(*parts)


class AdafactorState(NamedTuple):
    count: jax.Array
    row: PyTree    # factored second moment, rows   (for >=2D params)
    col: PyTree    # factored second moment, cols
    full: PyTree   # unfactored second moment       (for <2D params)


def adafactor_lite(learning_rate, decay: float = 0.8, eps: float = 1e-30,
                   clip_threshold: float = 1.0) -> GradientTransform:
    """Factored second-moment optimizer for very large models (no first moment).

    Memory: O(rows + cols) per matrix instead of O(rows*cols) — keeps the
    optimizer state of e.g. arctic-480b inside HBM budgets (see EXPERIMENTS.md
    §Dry-run memory notes).
    """
    schedule = learning_rate if callable(learning_rate) else constant(learning_rate)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def rowinit(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros([], jnp.float32)

        def colinit(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros([], jnp.float32))

        def fullinit(p):
            return jnp.zeros([], jnp.float32) if _factored(p) else jnp.zeros_like(p, jnp.float32)

        return AdafactorState(
            count=jnp.zeros([], jnp.int32),
            row=jax.tree.map(rowinit, params),
            col=jax.tree.map(colinit, params),
            full=jax.tree.map(fullinit, params),
        )

    def update(updates, state, params=None):
        count = state.count + 1
        beta = 1.0 - (count.astype(jnp.float32)) ** (-decay)

        def upd_one(g, r, c, f):
            g32 = g.astype(jnp.float32)
            sq = jnp.square(g32) + eps
            if g.ndim >= 2:
                r = beta * r + (1 - beta) * jnp.mean(sq, axis=-1)
                c = beta * c + (1 - beta) * jnp.mean(sq, axis=-2)
                rmean = jnp.mean(r, axis=-1, keepdims=True)
                vhat = (r / jnp.maximum(rmean, eps))[..., None] * c[..., None, :]
                u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
            else:
                f = beta * f + (1 - beta) * sq
                u = g32 / jnp.sqrt(jnp.maximum(f, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return u, r, c, f

        flat_g, treedef = jax.tree.flatten(updates)
        flat_r = treedef.flatten_up_to(state.row)
        flat_c = treedef.flatten_up_to(state.col)
        flat_f = treedef.flatten_up_to(state.full)
        out = [upd_one(g, r, c, f) for g, r, c, f in zip(flat_g, flat_r, flat_c, flat_f)]
        us, rs, cs, fs = zip(*out) if out else ((), (), (), ())
        lr = schedule(count - 1)
        us = [(-lr * u).astype(g.dtype) for u, g in zip(us, flat_g)]
        return (treedef.unflatten(us),
                AdafactorState(count=count, row=treedef.unflatten(rs),
                               col=treedef.unflatten(cs), full=treedef.unflatten(fs)))

    return GradientTransform(init, update)


def constant(value: float):
    def schedule(_):
        return jnp.asarray(value, jnp.float32)

    return schedule
