"""Learning-rate schedules as pure ``step -> lr`` callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    def schedule(step):
        return jnp.asarray(value, dtype=jnp.float32)

    return schedule


def linear_schedule(init_value: float, end_value: float, transition_steps: int):
    def schedule(step):
        frac = jnp.clip(step / max(transition_steps, 1), 0.0, 1.0)
        return jnp.asarray(init_value + frac * (end_value - init_value), jnp.float32)

    return schedule


def cosine_decay_schedule(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(step):
        frac = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(init_value * ((1 - alpha) * cosine + alpha), jnp.float32)

    return schedule


def linear_warmup_cosine(peak_value: float, warmup_steps: int, total_steps: int,
                         end_value: float = 0.0):
    """Linear warmup from 0 to ``peak_value`` then cosine decay to ``end_value``."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_value * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = end_value + (peak_value - end_value) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)

    return schedule
