"""Optimizers, schedules, and gradient transforms (optax-style, self-contained).

The container has no optax; this subpackage implements the pieces the
framework needs: SGD/Adam/AdamW/Adafactor-lite on arbitrary pytrees,
global-norm clipping, LR schedules, and chaining.  All transforms follow the
``init(params) -> state`` / ``update(grads, state, params) -> (updates, state)``
protocol so the trainer stays agnostic.
"""
from repro.optim.optimizers import (
    GradientTransform,
    adam,
    adamw,
    adafactor_lite,
    sgd,
    chain,
    clip_by_global_norm,
    scale_by_schedule,
    apply_updates,
    global_norm,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_decay_schedule,
    linear_warmup_cosine,
    linear_schedule,
)

__all__ = [
    "GradientTransform",
    "adam",
    "adamw",
    "adafactor_lite",
    "sgd",
    "chain",
    "clip_by_global_norm",
    "scale_by_schedule",
    "apply_updates",
    "global_norm",
    "constant_schedule",
    "cosine_decay_schedule",
    "linear_warmup_cosine",
    "linear_schedule",
]
