import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production meshes and dump memory/cost/collective statistics.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_3b \
#         --shape train_4k --mesh single --out artifacts/dryrun
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#
# Success criterion (deliverable e): ``.lower().compile()`` succeeds for the
# 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for every supported
# cell; the JSON artifacts feed EXPERIMENTS.md §Dry-run and §Roofline.
# NOTE: the XLA_FLAGS assignment above must stay the first statement — jax
# locks the device count on first init (hence also no `from __future__`
# import, which Python requires to be first).
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ShapeConfig, shape_supported
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.roofline import analysis
from repro.serve import engine
from repro.train import steps


def lower_cell(arch_id: str, shape_name: str, mesh,
               settings: steps.TrainSettings | None = None):
    """Lower one (arch x shape) cell.  Returns (lowered, meta)."""
    cfg = registry.load_arch(arch_id)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise analysis.CellSkipped(why)
    settings = settings or default_settings(arch_id, shape)
    # microbatches beyond global_batch / batch_shards leave fractional rows
    # per device — GSPMD replicates the whole microbatch across pods
    # (EXPERIMENTS.md §Multi-pod).  Clamp to the mesh.
    import dataclasses as _dc
    batch_shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            batch_shards *= mesh.shape[ax]
    max_micro = max(1, shape.global_batch // batch_shards)
    if settings.microbatches > max_micro:
        settings = _dc.replace(settings, microbatches=max_micro)

    if shape.kind == "train":
        train_step = steps.build_train_step(cfg, settings, mesh)
        p_shard, o_shard, params_s, opt_s = steps.state_shardings(
            cfg, settings, mesh)
        batch = registry.train_input_specs(cfg, shape)
        b_specs = sharding.batch_specs(cfg, batch, mesh)
        b_shard = sharding.to_named(b_specs, mesh)
        jitted = jax.jit(train_step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, batch)
    elif shape.kind == "prefill":
        prefill_step = engine.build_prefill_step(cfg, shape.seq_len)
        p_shard, b_shard, params_s, cache_s = engine.serve_shardings(
            cfg, shape, mesh, mode="prefill")
        batch = registry.prefill_input_specs(cfg, shape)
        bs = sharding.to_named(sharding.batch_specs(cfg, batch, mesh), mesh)
        jitted = jax.jit(prefill_step, in_shardings=(p_shard, bs),
                         out_shardings=(None, b_shard["cache"]))
        lowered = jitted.lower(params_s, batch)
    else:  # decode
        decode_step = engine.build_decode_step(cfg)
        p_shard, b_shard, params_s, cache_s = engine.serve_shardings(
            cfg, shape, mesh)
        batch = {"token": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32),
                 "cache": cache_s}
        jitted = jax.jit(decode_step, in_shardings=(p_shard, b_shard),
                         out_shardings=None,
                         donate_argnums=(1,))
        lowered = jitted.lower(params_s, batch)
    return lowered, {"arch": arch_id, "shape": shape_name,
                     "kind": shape.kind}


def default_settings(arch_id: str, shape: ShapeConfig) -> steps.TrainSettings:
    """Per-arch training settings sized so the per-device footprint fits a
    16 GB v5e chip (microbatching bounds stashed activations; adafactor
    bounds optimizer state for the two largest models)."""
    micro = {"arctic_480b": 16, "qwen2_vl_72b": 16, "mixtral_8x7b": 16,
             "chatglm3_6b": 4, "granite_3_2b": 4, "llama3_2_3b": 4,
             "tinyllama_1_1b": 2, "zamba2_2_7b": 4, "mamba2_780m": 2,
             "seamless_m4t_large_v2": 8}.get(arch_id, 2)
    opt = "adafactor" if arch_id in ("arctic_480b", "qwen2_vl_72b") else "adamw"
    return steps.TrainSettings(microbatches=micro, optimizer=opt, remat=True)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
              "devices": int(mesh.devices.size)}
    try:
        with mesh:
            lowered, meta = lower_cell(arch_id, shape_name, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            record.update(meta, status="ok",
                          lower_s=round(t_lower, 1),
                          compile_s=round(t_compile, 1))
            record["memory"] = analysis.memory_summary(compiled)
            record["cost"] = analysis.cost_summary(compiled)
            record["collectives"] = analysis.collective_summary(
                compiled, lowered)
            print(compiled.memory_analysis())
            print({k: v for k, v in record["cost"].items()})
    except analysis.CellSkipped as e:
        record.update(status="skipped", reason=str(e))
    except Exception as e:                                  # noqa: BLE001
        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    record["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch_id}__{shape_name}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already reports ok/skipped")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_kind}.json")
                if args.resume and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[ resume] {arch} x {shape} x {mesh_kind}",
                              flush=True)
                        continue
                rec = run_cell(arch, shape, mesh_kind, args.out)
                status = rec["status"]
                extra = (rec.get("reason") or rec.get("error") or
                         f"{rec.get('compile_s', 0)}s compile")
                print(f"[{status:>7}] {arch} x {shape} x {mesh_kind}: {extra}",
                      flush=True)
                n_fail += status == "failed"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
