"""Serving launcher: bring up the batched serving loop for an arch.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --batch 4 --max-len 128 --requests 6
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.launch.train import small_config
from repro.models import registry
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    base = registry.load_arch(args.arch)
    cfg = base if args.full else small_config(base, args.d_model, args.layers,
                                              args.vocab)
    params = registry.init_params(jax.random.key(0), cfg)
    loop = engine.ServeLoop(cfg, params, batch_size=args.batch,
                            max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [engine.Request(
        uid=i,
        prompt=rng.integers(1, cfg.vocab, size=int(rng.integers(4, 12))
                            ).astype(np.int32),
        max_new_tokens=args.max_new_tokens)
        for i in range(args.requests)]
    for start in range(0, len(reqs), args.batch):
        batch = reqs[start:start + args.batch]
        for r in loop.run(batch):
            print(f"req {r.uid}: {len(r.generated)} tokens")
    print("done")


if __name__ == "__main__":
    main()
