"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init, and the
test suite keeps the default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips over ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for subprocess-based distributed tests."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """The axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"


def num_chips(mesh) -> int:
    return mesh.devices.size
