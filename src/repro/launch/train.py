"""Training launcher: build the sharded train step for an (arch x mesh),
run it under checkpoint/restart supervision with the deterministic data
pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --steps 100 --batch 8 --seq 256 --mesh none

``--mesh none`` runs on the host's default devices (CPU smoke / examples);
``single``/``multi`` build the production meshes (requires the dry-run's
512 host devices or real hardware).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import pipeline
from repro.distributed import sharding
from repro.distributed.fault_tolerance import SupervisorConfig, TrainSupervisor
from repro.models import registry
from repro.train import steps


def small_config(base: ArchConfig, d_model: int, layers: int,
                 vocab: int) -> ArchConfig:
    """Scale an arch config down (same family wiring) for host-side runs."""
    heads = max(4, base.n_heads * d_model // max(base.d_model, 1))
    heads = min(heads, d_model // 16)
    n_kv = max(1, min(base.n_kv, heads))
    while heads % n_kv:
        n_kv -= 1
    hd = d_model // heads
    sections = base.mrope_sections
    if base.rope == "mrope":
        half = hd // 2
        a = half // 4
        b = (half - a) // 2
        sections = (a, b, half - a - b)
    return dataclasses.replace(
        base, num_layers=layers, d_model=d_model, n_heads=heads, n_kv=n_kv,
        d_ff=d_model * 4 if base.d_ff else 0, vocab=vocab,
        head_dim=hd, dtype="float32", mrope_sections=sections)


def run_training(cfg: ArchConfig, *, steps_n: int, global_batch: int,
                 seq_len: int, lr: float = 3e-4, mesh=None,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 100, microbatches: int = 1,
                 log_every: int = 10, seed: int = 0,
                 data_vocab: int | None = None) -> dict:
    settings = steps.TrainSettings(learning_rate=lr, microbatches=microbatches,
                                   remat=True, z_loss=1e-4)
    tx = steps.make_optimizer(settings)
    params = registry.init_params(jax.random.key(seed), cfg)
    opt_state = tx.init(params)
    # data_vocab may be smaller than the model vocab so short demo runs can
    # actually learn the synthetic chain (token ids stay in-range)
    dcfg = pipeline.DataConfig(vocab=data_vocab or cfg.vocab,
                               seq_len=seq_len,
                               global_batch=global_batch, seed=seed)

    if mesh is not None:
        p_sh, o_sh, _, _ = steps.state_shardings(cfg, settings, mesh)
        bspec = sharding.batch_specs(
            cfg, {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                                 jnp.int32),
                  "labels": jax.ShapeDtypeStruct((global_batch, seq_len),
                                                 jnp.int32)}, mesh)
        b_sh = sharding.to_named(bspec, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(steps.build_train_step(cfg, settings, mesh),
                          in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
        batch_shardings = b_sh
    else:
        step_fn = jax.jit(steps.build_train_step(cfg, settings),
                          donate_argnums=(0, 1))
        batch_shardings = None

    losses = []
    state = {"params": params, "opt": opt_state}

    def one_step(state, i):
        batch = pipeline.synthetic_lm_batch(dcfg, i)
        if batch_shardings is not None:
            batch = {k: jax.device_put(jnp.asarray(v), batch_shardings[k])
                     for k, v in batch.items()}
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % log_every == 0:
            tokens = global_batch * seq_len
            print(f"step {i:5d}  loss {loss:8.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):8.3f}  "
                  f"{tokens/(time.time()-t0):9.0f} tok/s", flush=True)
        return {"params": params, "opt": opt}

    if checkpoint_dir:
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=checkpoint_dir,
                             checkpoint_every=checkpoint_every), state)
        state = sup.run(one_step, steps_n)
    else:
        for i in range(steps_n):
            state = one_step(state, i)
    return {"state": state, "losses": losses}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=256,
                    help="host-run width (full config via --full)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (needs a real pod)")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    base = registry.load_arch(args.arch)
    cfg = base if args.full else small_config(base, args.d_model, args.layers,
                                              args.vocab)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    out = run_training(cfg, steps_n=args.steps, global_batch=args.batch,
                       seq_len=args.seq, lr=args.lr, mesh=mesh,
                       checkpoint_dir=args.checkpoint_dir or None)
    losses = out["losses"]
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
