"""repro: sparsity-aware SNN accelerator DSE (Aliyev et al. 2023) rebuilt as
a production multi-pod JAX framework.

Subpackages:
  core         the paper's contribution: SNN substrate + cycle-accurate
               accelerator model + DSE engine + spike-to-spike validation
  kernels      Pallas TPU kernels (fused LIF, block-skip spike GEMM)
  models       10-architecture LM zoo (dense/MoE/SSM/hybrid/enc-dec/VLM)
  configs      assigned architecture configs + shape grid
  distributed  sharding rules, fault tolerance, compression, pipeline-parallel
  train/serve  step builders, serving engine
  checkpoint   sharded elastic checkpoints
  launch       mesh, dry-run, train/serve CLIs
  roofline     loop-corrected HLO analysis + roofline reporting
"""
__version__ = "1.0.0"
