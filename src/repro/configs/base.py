"""Architecture + shape configuration dataclasses.

One ``ArchConfig`` per assigned architecture lives in
``repro/configs/<id>.py`` (exact public-literature dims); smoke tests build
``reduced()`` variants of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    dense_d_ff: int = 0
    # "gather": tokens routed into expert slots via gather/scatter (cheap,
    # the optimized path); "einsum": GShard-style one-hot dispatch matmuls
    # (the faithful baseline — costs 2*S*E*C*d extra FLOPs per group).
    dispatch: str = "gather"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128             # N
    head_dim: int = 64               # P
    expand: int = 2                  # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256                 # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # transformer | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    norm: str = "rms"
    mlp_kind: str = "swiglu"
    rope: str = "1d"                 # 1d | 2d | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()
    window: int = 0                  # sliding-window attention (mixtral)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention block every k mamba blocks
    shared_attn_every: int = 0
    # enc-dec (seamless)
    encoder_layers: int = 0
    # modality frontend stub: "none" | "vision" | "audio"
    frontend: str = "none"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context support class, used for shape-skip decisions:
    # "full" (quadratic attn) | "window" | "ssm" | "hybrid"
    context_class: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding shards
        evenly on a 16-way model axis."""
        return -(-self.vocab // 256) * 256


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention state (DESIGN.md §4)."""
    if shape.name == "long_500k" and arch.context_class == "full":
        return False, ("skip: full-attention architecture — 500k-token KV "
                       "state is the quadratic-attention regime the "
                       "assignment excludes")
    return True, ""
