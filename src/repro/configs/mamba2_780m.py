"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) vocab=50280
ssm_state=128, SSD  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, n_heads=48, n_kv=0, d_ff=0,
    vocab=50280, head_dim=64, rope="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    context_class="ssm",
)
