"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, sliding-window attention  [arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=32000, head_dim=128, rope="1d", rope_theta=1e6,
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    context_class="window",
)
