"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128, rope="1d", rope_theta=10000.0,
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True, dense_d_ff=4864),
    context_class="full",
)
