"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000  [arXiv:2401.02385; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="transformer",
    num_layers=22, d_model=2048, n_heads=32, n_kv=4, d_ff=5632,
    vocab=32000, head_dim=64, rope="1d", rope_theta=10000.0,
    context_class="full",
)
