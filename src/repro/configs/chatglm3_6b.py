"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2D RoPE  [arXiv:2406.12793; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="transformer",
    num_layers=28, d_model=4096, n_heads=32, n_kv=2, d_ff=13696,
    vocab=65024, head_dim=128, rope="2d", rope_theta=10000.0,
    context_class="full",
)
