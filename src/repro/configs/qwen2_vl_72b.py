"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution (vision frontend stubbed)
[arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="transformer",
    num_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568,
    vocab=152064, head_dim=128, rope="mrope",
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision", context_class="full",
)
