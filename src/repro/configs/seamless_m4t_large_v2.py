"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206, enc-dec (speech frontend stubbed: precomputed frame
embeddings)  [arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, head_dim=64, rope="1d",
    frontend="audio", context_class="full",
)
