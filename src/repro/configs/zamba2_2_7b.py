"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
    vocab=32000, head_dim=80, rope="1d",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    shared_attn_every=6, context_class="hybrid",
)
