"""SPMD pipeline parallelism (GPipe schedule over a mesh axis).

Layers are partitioned into S stages; stage s's parameters live on the
devices of mesh axis ``stage`` index s (leading-dim sharding).  Microbatches
stream through: at step t, stage s processes microbatch t-s while
``ppermute`` rotates activations to the next stage — the classic GPipe
pipeline with S-1 bubble steps, expressed as a single SPMD program
(no per-stage processes).

Intended for depth-dominated models at node counts where a 2D (data, model)
mesh runs out of useful tensor-parallel width; at the assignment's 16x16
mesh none of the ten archs needs it, so it ships as a first-class optional
feature with its own correctness tests (tests/test_pipeline.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

PyTree = Any


def pipeline_apply(stage_fn: Callable, stage_params: PyTree,
                   micro_inputs: jax.Array, mesh,
                   axis: str = "stage") -> jax.Array:
    """Run ``stage_fn`` as an S-stage pipeline.

    stage_fn(params_slice, x) -> y with x.shape == y.shape (the activation
    that flows between stages).
    stage_params: pytree whose leaves lead with dim S (one slice per stage).
    micro_inputs: (n_micro, ...) microbatched inputs.
    Returns (n_micro, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = micro_inputs.shape[0]
    steps = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, micro):
        # params leaves: (1, ...) local stage slice; micro: (n_micro, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        carry = jnp.zeros_like(micro[0])
        outputs = jnp.zeros_like(micro)
        for t in range(steps):
            mb_in = micro[min(t, n_micro - 1)]
            x = jnp.where(stage == 0, mb_in, carry)
            y = stage_fn(params, x)
            out_idx = t - (n_stages - 1)
            if out_idx >= 0:
                # only the LAST stage's value is meaningful here; other
                # stages write garbage that their shard of `outputs` keeps
                # locally and is discarded by the out_spec (last stage owns
                # the gather below)
                outputs = outputs.at[out_idx].set(
                    jnp.where(stage == n_stages - 1, y, outputs[out_idx]))
            carry = jax.lax.ppermute(y, axis, perm)
        # broadcast the last stage's outputs to every shard so the
        # replicated out_spec is consistent
        last = jax.lax.ppermute(
            outputs, axis, [((n_stages - 1 + i) % n_stages, i)
                            for i in range(n_stages)])
        return last

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, micro_inputs)


def stack_stages(layer_params: PyTree, n_stages: int) -> PyTree:
    """Regroup per-layer stacked params (L, ...) into (S, L/S, ...)."""
    def regroup(leaf):
        L = leaf.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return leaf.reshape(n_stages, L // n_stages, *leaf.shape[1:])

    return jax.tree.map(regroup, layer_params)
