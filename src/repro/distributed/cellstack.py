"""Stacked-cell training: vmap same-shape cells into one device batch.

The DSE's expensive leg is training model cells, and ``cellfarm`` trains
each cell in its own spawned process — a fresh interpreter, a fresh JAX
import, and a fresh jit compile per cell.  But many pending cells are the
*same compiled program*: identical topology shapes and ``num_steps``
(so params, spike trains, and the BPTT ``lax.scan`` length all stack),
differing only in seed or dataset shard.  This module groups such jobs by
**stack signature**, stacks their params/optimizer state/RNG keys along a
leading cell axis, and trains the whole stack with one
``jit(vmap(train_step))`` loop: the cell axis folds into the M dimension of
the block-skip ``spike_gemm``/fused kernels (Pallas batching tiles
``(C·B·T)`` rows instead of ``(B·T)``), and each cell's ``block_flags``
derive from its own spike rows, so per-cell sparsity skipping survives
stacking intact.

Bit-exactness contract (DESIGN.md §14): every published cell must be a
cache hit for a later *solo*-trained recipe, traces bit-identical.  Three
rules make that hold:

* **Init stays host-side and per-cell** (``train_snn.init_cell`` then
  ``jnp.stack``): ``jax.random.normal`` under ``vmap`` draws different
  bits than the solo call — the one leg of the loop that is NOT
  vmap-exact.  Everything downstream (matmuls, ``rate_encode``, key
  splits, value_and_grad, adam) is.
* **Key chains replicate the solo driver exactly**: per-cell training keys
  split *inside* the jitted step (``jax.vmap(jax.random.split)``);
  evaluation (seed 1234) and trace-dump (seed 7) keys are seed-independent
  constants in ``train_snn`` and therefore *shared* across the stack
  (``in_axes=None``).
* **Data batching stays host-side and per-cell**: one
  ``synthetic.batches(..., seed=job.seed)`` iterator per cell, stacked
  per step — the same numpy permutation stream the solo loop consumes.

When the host exposes multiple devices and the cell count divides them,
the cell axis shards over a 1-D ``"cells"`` mesh using the config-driven
rules idiom from ``distributed/sharding.py`` (here the rule table collapses
to one rule — every stacked leaf leads with the cell axis); single-device
CPU is the fallback.  Cells are independent, so partitioning the vmapped
program over the cell axis needs no collectives.

Results unstack and publish per cell through the content-addressed
``TraceCache`` (``TraceCache.publish``), so stacking is invisible to every
consumer: cache keys never mention the stack (a cell's artifact must not
depend on which batch it happened to train in), and ``Study``/``explore``
only see ordinary hits afterwards.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import optim
from repro.core import snn, train_snn
from repro.core.workloads.cache import CellArtifact, TraceCache
from repro.data import synthetic
from repro.distributed.cellfarm import CellJob, CellOutcome
from repro.distributed.sharding import to_named

#: cells per training slab: bounds device memory (C× params + batches) and
#: keeps compile shapes reusable across slabs of one big group
MAX_STACK = 16

#: evaluation batch size / key seeds — must mirror train_snn.evaluate /
#: dump_traces defaults exactly (the bit-exactness contract)
_EVAL_BATCH = 256
_EVAL_SEED = 1234
_TRACE_SEED = 7


# ---------------------------------------------------------------------------
# Stack signatures
# ---------------------------------------------------------------------------

def stack_signature(job: CellJob) -> str:
    """Hash of everything the stacked program *shares* across cells.

    Two jobs with equal signatures compile to the same jitted stack step
    and may train together: the built topology (layer types, shapes,
    LIF parameters — the whole ``SNNConfig`` minus its display name), the
    encoding, the training recipe baked into the compiled step
    (``train_steps``/``batch_size``/``lr``), the test-set geometry the
    stacked evaluate/trace legs iterate (``n_test``/``trace_samples``),
    and the resolved matmul backend.  Deliberately EXCLUDED: workload
    name, ``seed``, ``data_seed``, ``noise``, ``n_train`` — per-cell
    degrees of freedom (seed / dataset shard) that live in host-side
    iterators, never in the compiled program.  mnist-mlp and fmnist-mlp
    cells at the same (T, population) therefore stack.
    """
    T = int(job.assignment["num_steps"])
    pop = float(job.assignment.get("population", 1.0))
    wl = job.workload
    cfg = wl.build(T, pop)
    payload = {
        "cfg": dataclasses.asdict(dataclasses.replace(cfg, name="")),
        "layer_types": [type(l).__name__ for l in cfg.layers],
        "encoding": wl.encoding,
        "n_test": wl.n_test,
        "train_steps": wl.train_steps,
        "batch_size": wl.batch_size,
        "lr": wl.lr,
        "trace_samples": wl.trace_samples,
        "backend": snn.resolve_matmul_backend(wl.matmul_backend),
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def group_jobs(jobs: Sequence[CellJob]) -> dict[str, list[int]]:
    """Job indices grouped by stack signature, order-preserving."""
    groups: dict[str, list[int]] = {}
    for i, job in enumerate(jobs):
        groups.setdefault(stack_signature(job), []).append(i)
    return groups


# ---------------------------------------------------------------------------
# Cell-axis sharding (the sharding.py rules idiom, one rule)
# ---------------------------------------------------------------------------

def stack_mesh(n_cells: int) -> Optional[Mesh]:
    """A 1-D ``"cells"`` mesh over every local device, when the stack
    divides evenly; ``None`` falls back to single-device placement."""
    devices = jax.devices()
    if len(devices) > 1 and n_cells % len(devices) == 0:
        return Mesh(np.array(devices), ("cells",))
    return None


def cell_specs(tree):
    """Spec rule table for stacked-cell state: every leaf leads with the
    cell axis, so the single rule shards dim 0 over ``"cells"`` and
    replicates the rest (``P`` entries beyond rank are implicit-None)."""
    return jax.tree.map(lambda _: P("cells"), tree)


def _shard(tree, mesh: Optional[Mesh]):
    if mesh is None:
        return tree
    return jax.device_put(tree, to_named(cell_specs(tree), mesh))


# ---------------------------------------------------------------------------
# Stacked training
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _train_slab(jobs: Sequence[CellJob],
                stats: Optional[dict] = None) -> list[tuple]:
    """Train one slab of same-signature jobs as a single vmapped stack.
    Returns per-job ``(params numpy, counts, accuracy)`` tuples in job
    order.  ``stats`` (optional) accumulates ``compile_seconds`` (first,
    compiling stack-step call) and ``train_seconds``."""
    job0 = jobs[0]
    wl0 = job0.workload
    T = int(job0.assignment["num_steps"])
    pop = float(job0.assignment.get("population", 1.0))
    cfg = wl0.build(T, pop)
    backend = snn.resolve_matmul_backend(wl0.matmul_backend)
    tx = optim.adam(wl0.lr)
    C = len(jobs)

    datas = [j.workload.make_data(int(j.assignment["num_steps"]))
             for j in jobs]
    # per-cell host-side init — the one non-vmap-exact leg (module docstring)
    inits = [train_snn.init_cell(cfg, tx, j.seed) for j in jobs]
    mesh = stack_mesh(C)
    params = _shard(_stack([i[0] for i in inits]), mesh)
    opt_state = _shard(_stack([i[1] for i in inits]), mesh)
    keys = _shard(jnp.stack([i[2] for i in inits]), mesh)

    step_fn = train_snn.make_train_step(cfg, tx, backend)

    @jax.jit
    def stack_step(params, opt_state, keys, x, y):
        split = jax.vmap(jax.random.split)(keys)
        next_keys, subs = split[:, 0], split[:, 1]
        params, opt_state, loss = jax.vmap(step_fn)(
            params, opt_state, subs, x, y)
        return params, opt_state, next_keys, loss

    iters = [synthetic.batches(d.x_train, d.y_train, wl0.batch_size,
                               seed=j.seed, epochs=10_000)
             for d, j in zip(datas, jobs)]
    t0 = time.perf_counter()
    compile_seconds = None
    for _ in range(wl0.train_steps):
        batches = [next(it) for it in iters]
        x = _shard(jnp.asarray(np.stack([b[0] for b in batches])), mesh)
        y = _shard(jnp.asarray(np.stack([b[1] for b in batches])), mesh)
        params, opt_state, keys, loss = stack_step(
            params, opt_state, keys, x, y)
        if compile_seconds is None:
            jax.block_until_ready(loss)
            compile_seconds = time.perf_counter() - t0
    jax.block_until_ready(params)
    if stats is not None:
        stats["compile_seconds"] = (stats.get("compile_seconds", 0.0)
                                    + (compile_seconds or 0.0))
        stats["train_seconds"] = (stats.get("train_seconds", 0.0)
                                  + time.perf_counter() - t0)
        stats["cells"] = stats.get("cells", 0) + C

    accuracy = _evaluate_stack(cfg, backend, params, datas, mesh)
    counts = _trace_stack(cfg, backend, params, datas, wl0.trace_samples,
                          mesh)

    host_params = jax.tree.map(np.asarray, params)
    out = []
    for c in range(C):
        out.append((jax.tree.map(lambda t: t[c], host_params),
                    [np.asarray(layer[c], np.float32) for layer in counts],
                    float(accuracy[c])))
    return out


def _evaluate_stack(cfg, backend, params, datas, mesh) -> np.ndarray:
    """Per-cell test accuracy, replicating ``train_snn.evaluate`` bit for
    bit: same batch size, same seed-independent key chain — shared across
    cells (``in_axes=None``) because the solo chain never involves the
    cell's seed."""
    xs = np.stack([d.x_test for d in datas])
    ys = np.stack([d.y_test for d in datas])
    predict = jax.jit(jax.vmap(
        lambda p, k, x: train_snn._predict(cfg, backend, p, k, x),
        in_axes=(0, None, 0)))
    n = xs.shape[1]
    correct = np.zeros(len(datas), np.int64)
    key = jax.random.key(_EVAL_SEED)
    for i in range(0, n, _EVAL_BATCH):
        key, sub = jax.random.split(key)
        xb = _shard(jnp.asarray(xs[:, i:i + _EVAL_BATCH]), mesh)
        pred = np.asarray(predict(params, sub, xb))
        correct += (pred == ys[:, i:i + _EVAL_BATCH]).sum(axis=1)
    return correct / max(n, 1)


def _trace_stack(cfg, backend, params, datas, trace_samples: int,
                 mesh) -> list[np.ndarray]:
    """Per-cell spike traces, replicating ``train_snn.dump_traces``: shared
    seed-7 encode key, first ``trace_samples`` test samples per cell.
    Returns one (C, T, S) array per spiking layer."""
    key = jax.random.key(_TRACE_SEED)
    xs = _shard(jnp.asarray(
        np.stack([d.x_test[:trace_samples] for d in datas])), mesh)
    counts_fn = jax.jit(jax.vmap(
        lambda p, x: snn.spike_counts_per_layer(
            cfg, p, train_snn._encode_input(key, x, cfg.num_steps),
            matmul_backend=backend)))
    return [np.asarray(c) for c in counts_fn(params, xs)]


# ---------------------------------------------------------------------------
# Front end
# ---------------------------------------------------------------------------

def resolve_stacked(jobs: Sequence[CellJob], root: str,
                    cache: Optional[TraceCache] = None,
                    max_stack: int = MAX_STACK,
                    stats: Optional[dict] = None) -> list[CellOutcome]:
    """Resolve ``jobs`` against the cache at ``root``, training pending
    cells as vmapped same-signature stacks (in slabs of ``max_stack``).
    Jobs need not share a signature — they are grouped internally, and a
    singleton group still trains in-process as a C=1 stack (bit-exact, no
    process spawn).  Returns one outcome per job, in job order; already-
    published cells resolve as hits exactly like the process farm."""
    cache = cache if cache is not None else TraceCache(root=root)
    outcomes: list[Optional[CellOutcome]] = [None] * len(jobs)
    for _sig, idxs in group_jobs(jobs).items():
        pending = []
        for i in idxs:
            job = jobs[i]
            if cache.contains(job.workload, job.assignment, seed=job.seed):
                art = cache.resolve(job.workload, job.assignment,
                                    seed=job.seed,
                                    quant_bits=job.quant_bits)
                outcomes[i] = CellOutcome(key=art.key, trained=False)
            else:
                pending.append(i)
        for s in range(0, len(pending), max_stack):
            slab = pending[s:s + max_stack]
            results = _train_slab([jobs[i] for i in slab], stats=stats)
            for i, (params, counts, acc) in zip(slab, results):
                job = jobs[i]
                art = cache.publish(job.workload, job.assignment,
                                    seed=job.seed, params=params,
                                    counts=counts, accuracy=acc,
                                    quant_bits=job.quant_bits)
                outcomes[i] = CellOutcome(key=art.key,
                                          trained=not art.cache_hit)
    return outcomes


__all__ = ["MAX_STACK", "CellArtifact", "cell_specs", "group_jobs",
           "resolve_stacked", "stack_mesh", "stack_signature"]
