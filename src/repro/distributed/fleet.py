"""Elastic multi-host cell farm: workers coordinate through the cache root.

``cellfarm`` scales cell training to one machine's process pool; this
module scales it to a *fleet*.  The only shared substrate is the trace
cache root (``repro.core.workloads.cache``) — an NFS-style directory every
enrolled host mounts — and every coordination primitive lives inside it:

* **Job spool** — ``<root>/queue/<key>.job`` holds one wire-format
  :class:`~repro.distributed.cellfarm.CellJob` (``serve.protocol.to_wire``
  JSON, atomically published via tmp + ``os.replace``).  Submitting studies
  spool their pending cells; any worker on any host may pick one up.  A
  ``<key>.error`` sidecar in the same directory carries a training failure
  back to the submitter.
* **Lease** — ``<root>/<key>/.lease``, created with ``O_CREAT | O_EXCL``
  (atomic on POSIX and on NFSv3+ for exclusive create), carries the worker
  id; its **mtime is the heartbeat**, renewed by the holder every
  ``ttl / 4``.  Exactly one claimant wins a cell.  Any party — another
  worker or the submitting study — may *break* a lease whose heartbeat is
  older than ``lease_ttl()`` (``REPRO_FLEET_LEASE_TTL``, seconds) and
  reclaim the cell: this is the ``fault_tolerance.TrainSupervisor`` restart
  idiom (missing heartbeat => restore + retry) lifted from one training
  loop to the fleet.
* **Publish** — unchanged: the content-addressed ``TraceCache`` write path
  (checkpoint first, ``meta.msgpack`` last, both atomic).  A published cell
  is the *commit record*; leases and spool files are advisory and may be
  lost at any time without corrupting anything, because duplicate training
  is deterministic and the last atomic publish wins.

``FleetWorker.run()`` is the worker loop (claim -> heartbeat -> train ->
publish -> release); ``resolve_cluster`` is the submitter side
(``cellfarm.resolve_cells(..., workers="cluster")`` delegates here): spool
pending jobs, block on lease/publish progress, break stale leases, and
fall back to in-process training for any cell the fleet shows no progress
on within ``timeout`` seconds — so ``explore(workers="cluster")`` always
completes even with zero live workers.

Failure matrix (DESIGN.md §16): worker killed mid-train -> heartbeat goes
stale -> lease broken -> cell reclaimed; two claimants race -> ``O_EXCL``
picks one; torn meta on the network store -> quarantined as missing
(``TraceCache._read_meta``); submitter dies -> spool files remain and any
worker (or the resubmitted study) drains them.
"""
from __future__ import annotations

import json
import logging
import os
import socket
import threading
import time
import uuid
from typing import Optional, Sequence

from repro.core.workloads.cache import TraceCache
from repro.distributed.cellfarm import CellJob, CellOutcome, _job_key
from repro.serve import protocol

log = logging.getLogger(__name__)

_LEASE = ".lease"
_QUEUE = "queue"
_JOB_SUFFIX = ".job"
_ERROR_SUFFIX = ".error"


def lease_ttl() -> float:
    """Seconds without a heartbeat before a lease is breakable
    (``REPRO_FLEET_LEASE_TTL``; resolved per call so tests and deployments
    can retune a running process)."""
    return float(os.environ.get("REPRO_FLEET_LEASE_TTL", "30"))


def poll_interval() -> float:
    """Queue/progress polling period (``REPRO_FLEET_POLL``)."""
    return float(os.environ.get("REPRO_FLEET_POLL", "0.1"))


def cluster_timeout(ttl: float) -> float:
    """Submitter-side no-progress window before the in-process fallback
    (``REPRO_FLEET_TIMEOUT``; default twice the lease TTL so a live
    worker's heartbeat always lands inside it)."""
    env = os.environ.get("REPRO_FLEET_TIMEOUT")
    return float(env) if env else 2.0 * ttl


def default_worker_id() -> str:
    return f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"


# ---------------------------------------------------------------------------
# Leases
# ---------------------------------------------------------------------------

class Lease:
    """A held claim on one cell.  The file's mtime is the heartbeat;
    ``renew`` touches it.  ``lost`` flips when a renewal finds the file
    gone — someone judged us dead and broke the lease.  The holder keeps
    training anyway: publish is atomic and training deterministic, so the
    worst case is duplicate work, never corruption."""

    def __init__(self, path: str, worker_id: str):
        self.path = path
        self.worker_id = worker_id
        self.lost = False

    def renew(self) -> bool:
        try:
            with open(self.path) as f:
                if f.read() != self.worker_id:
                    self.lost = True     # broken and re-claimed: the file
                    return False         # at this path is someone else's
            os.utime(self.path)
            return True
        except FileNotFoundError:
            self.lost = True
            return False

    def release(self) -> None:
        try:
            with open(self.path) as f:
                if f.read() != self.worker_id:
                    return               # re-claimed: not ours to unlink
            os.unlink(self.path)
        except FileNotFoundError:
            pass


def _lease_path(root: str, key: str) -> str:
    return os.path.join(root, key, _LEASE)


def _try_break(path: str, ttl: float) -> bool:
    """Break the lease at ``path`` iff its heartbeat is older than ``ttl``.
    The steal is a rename to a unique name, so concurrent breakers race on
    ``os.rename`` and exactly one wins; the winner re-checks the stolen
    file's mtime to shrink the stat->rename TOCTOU window from the full TTL
    to microseconds.  Returns True when the named lease no longer exists
    (broken here or already gone)."""
    try:
        st = os.stat(path)
    except FileNotFoundError:
        return True
    if time.time() - st.st_mtime < ttl:
        return False
    steal = f"{path}.stale-{uuid.uuid4().hex[:8]}"
    try:
        os.rename(path, steal)
    except FileNotFoundError:
        return True                     # another breaker won the race
    fresh = False
    try:
        fresh = time.time() - os.stat(steal).st_mtime < ttl
    except FileNotFoundError:
        pass
    os.unlink(steal)
    if fresh:
        # the holder renewed between our stat and rename; its lease file is
        # gone now (it will see lost=True and keep training — benign
        # duplicate work at worst), but do NOT claim we broke a dead lease
        log.warning("stole a live lease %s; holder demoted to leaseless "
                    "(duplicate training possible, publish stays atomic)",
                    path)
        return False
    return True


def acquire(root: str, key: str, worker_id: str,
            ttl: Optional[float] = None) -> Optional[Lease]:
    """Atomically claim the cell ``key``: create ``<root>/<key>/.lease``
    with ``O_CREAT | O_EXCL``.  A stale existing lease (heartbeat older
    than ``ttl``) is broken first.  Returns the held lease, or None when a
    live claimant holds it."""
    ttl = lease_ttl() if ttl is None else ttl
    path = _lease_path(root, key)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    for _ in range(2):                   # once, plus once after a break
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            if not _try_break(path, ttl):
                return None
            continue
        with os.fdopen(fd, "w") as f:
            f.write(worker_id)
        return Lease(path, worker_id)
    return None


class _Heartbeat(threading.Thread):
    """Renew a lease every ``ttl / 4`` until stopped (daemon thread, so a
    hung training step cannot outlive the process and keep the lease
    fresh forever)."""

    def __init__(self, lease: Lease, ttl: float):
        super().__init__(name=f"lease-heartbeat-{lease.worker_id}",
                         daemon=True)
        self.lease = lease
        self.period = max(ttl / 4.0, 0.01)
        # NB: not named _stop — threading.Thread has a private _stop method
        # that join() calls internally
        self._halt = threading.Event()

    def run(self) -> None:
        while not self._halt.wait(self.period):
            if not self.lease.renew():
                return                   # lease broken under us; stop

    def stop(self) -> None:
        self._halt.set()
        self.join()


# ---------------------------------------------------------------------------
# Job spool
# ---------------------------------------------------------------------------

def _queue_dir(root: str) -> str:
    return os.path.join(root, _QUEUE)


def _spool_path(root: str, key: str) -> str:
    return os.path.join(_queue_dir(root), key + _JOB_SUFFIX)


def _error_path(root: str, key: str) -> str:
    return os.path.join(_queue_dir(root), key + _ERROR_SUFFIX)


def _unlink(path: str) -> None:
    try:
        os.unlink(path)
    except FileNotFoundError:
        pass


def spool(root: str, jobs: Sequence[CellJob]) -> list[str]:
    """Publish ``jobs`` into ``<root>/queue/`` (idempotent: an already
    spooled key is left alone; a stale ``.error`` sidecar from a previous
    attempt is cleared).  Returns the job keys, in job order."""
    qdir = _queue_dir(root)
    os.makedirs(qdir, exist_ok=True)
    keys = []
    for job in jobs:
        key = _job_key(job)
        keys.append(key)
        _unlink(_error_path(root, key))
        path = _spool_path(root, key)
        if os.path.exists(path):
            continue
        tmp = f"{path}.tmp-{uuid.uuid4().hex[:8]}"
        with open(tmp, "w") as f:
            json.dump(protocol.to_wire(job), f)
        os.replace(tmp, path)
    return keys


def _read_job(path: str) -> Optional[CellJob]:
    try:
        with open(path) as f:
            return protocol.from_wire(json.load(f))
    except FileNotFoundError:
        return None
    except (json.JSONDecodeError, ValueError, TypeError, KeyError) as e:
        log.warning("unreadable spooled job %s (%s: %s); skipping",
                    path, type(e).__name__, e)
        return None


def _write_error(root: str, key: str, message: str) -> None:
    path = _error_path(root, key)
    tmp = f"{path}.tmp-{uuid.uuid4().hex[:8]}"
    with open(tmp, "w") as f:
        f.write(message)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------

class FleetWorker:
    """One elastic cell-farm worker: poll the spool, claim a cell by
    lease, train-or-load it through the shared ``TraceCache``, publish,
    release.  Enroll a host by running any number of these against the
    shared root — no registration, no coordinator process."""

    def __init__(self, root: str, worker_id: Optional[str] = None,
                 ttl: Optional[float] = None,
                 poll: Optional[float] = None):
        self.root = root
        self.worker_id = worker_id or default_worker_id()
        self.ttl = lease_ttl() if ttl is None else float(ttl)
        self.poll = poll_interval() if poll is None else float(poll)
        self.cache = TraceCache(root=root)
        self.stats = {"cells_trained": 0, "cells_failed": 0,
                      "cells_skipped": 0, "lease_takeovers": 0}

    # ---- claim -------------------------------------------------------------
    def _claim(self) -> Optional[tuple[CellJob, Lease, str]]:
        qdir = _queue_dir(self.root)
        if not os.path.isdir(qdir):
            return None
        try:
            names = sorted(os.listdir(qdir))
        except FileNotFoundError:
            return None
        for name in names:
            if not name.endswith(_JOB_SUFFIX):
                continue
            key = name[:-len(_JOB_SUFFIX)]
            path = os.path.join(qdir, name)
            if self.cache.contains_key(key):
                _unlink(path)            # already published; drain the spool
                continue
            lease_existed = os.path.exists(_lease_path(self.root, key))
            lease = acquire(self.root, key, self.worker_id, ttl=self.ttl)
            if lease is None:
                continue                 # live claimant; try the next job
            if lease_existed:
                self.stats["lease_takeovers"] += 1
            job = _read_job(path)
            if job is None:              # drained or torn since listing
                lease.release()
                continue
            return job, lease, path
        return None

    # ---- work --------------------------------------------------------------
    def _work(self, job: CellJob, lease: Lease, spool_path: str) -> None:
        hb = _Heartbeat(lease, self.ttl)
        hb.start()
        try:
            art = self.cache.resolve(job.workload, job.assignment,
                                     seed=job.seed,
                                     quant_bits=job.quant_bits)
        except KeyboardInterrupt:
            raise
        except BaseException as e:                       # noqa: BLE001
            self.stats["cells_failed"] += 1
            msg = f"{type(e).__name__}: {e}"
            log.warning("cell %s failed on %s: %s",
                        _job_key(job), self.worker_id, msg)
            _write_error(self.root, _job_key(job), msg)
        else:
            if art.cache_hit:            # raced a concurrent publisher
                self.stats["cells_skipped"] += 1
            else:
                self.stats["cells_trained"] += 1
        finally:
            hb.stop()
            _unlink(spool_path)
            lease.release()

    def run(self, max_cells: Optional[int] = None,
            idle_timeout: Optional[float] = None) -> dict:
        """The worker loop: claim and train until ``max_cells`` cells were
        worked (trained or failed) or the spool stayed empty for
        ``idle_timeout`` seconds (None = run forever).  Returns ``stats``.
        """
        idle_since = time.time()
        while True:
            worked = self.stats["cells_trained"] + self.stats["cells_failed"]
            if max_cells is not None and worked >= max_cells:
                return self.stats
            claimed = self._claim()
            if claimed is None:
                if (idle_timeout is not None
                        and time.time() - idle_since > idle_timeout):
                    return self.stats
                time.sleep(self.poll)
                continue
            self._work(*claimed)
            idle_since = time.time()


def run_worker(root: str, worker_id: Optional[str] = None,
               max_cells: Optional[int] = None,
               idle_timeout: Optional[float] = None,
               ttl: Optional[float] = None,
               stats_path: Optional[str] = None) -> dict:
    """Module-level worker entry point (spawnable by ``multiprocessing``
    and importable from a shell:
    ``python -c "from repro.distributed.fleet import run_worker; ..."``).
    Writes ``stats`` as JSON to ``stats_path`` on exit when given."""
    worker = FleetWorker(root, worker_id=worker_id, ttl=ttl)
    try:
        return worker.run(max_cells=max_cells, idle_timeout=idle_timeout)
    finally:
        if stats_path is not None:
            tmp = f"{stats_path}.tmp"
            with open(tmp, "w") as f:
                json.dump({"worker_id": worker.worker_id, **worker.stats}, f)
            os.replace(tmp, stats_path)


# ---------------------------------------------------------------------------
# Submitter side: cluster resolution
# ---------------------------------------------------------------------------

def resolve_cluster(jobs: Sequence[CellJob], root: str,
                    timeout: Optional[float] = None,
                    ttl: Optional[float] = None,
                    poll: Optional[float] = None,
                    fallback: bool = True) -> list[CellOutcome]:
    """Resolve ``jobs`` through the fleet: spool the pending ones and block
    until every cell is published (by any worker on any host) or errored.
    One outcome per job, in job order — the contract of
    ``cellfarm.resolve_cells``, which delegates here for
    ``workers="cluster"``.

    **Progress** for a cell is a fresh lease heartbeat or its publish; a
    cell with no progress for ``timeout`` seconds (default
    ``cluster_timeout``: twice the lease TTL) is *reclaimed* by the
    submitter — the stale lease is broken, the spool entry withdrawn, and
    with ``fallback=True`` the cell trains in-process (under its own
    heartbeated lease), so the study completes even when every worker died
    or none ever existed.  ``trained`` in the outcome means the cell was
    published during this resolution (by the fleet or the fallback) — the
    unit the caller's budget accounting charges, exactly as for the
    process farm."""
    jobs = list(jobs)
    if not jobs:
        return []
    ttl = lease_ttl() if ttl is None else float(ttl)
    timeout = cluster_timeout(ttl) if timeout is None else float(timeout)
    poll = poll_interval() if poll is None else float(poll)
    cache = TraceCache(root=root)
    my_id = f"submitter-{default_worker_id()}"

    outcomes: list[Optional[CellOutcome]] = [None] * len(jobs)
    keys = [_job_key(job) for job in jobs]
    for i, key in enumerate(keys):
        if cache.contains_key(key):
            outcomes[i] = CellOutcome(key=key, trained=False)
    pending = [i for i, out in enumerate(outcomes) if out is None]
    spool(root, [jobs[i] for i in pending])
    log.info("fleet: %d cell(s) spooled to %s (%d already published)",
             len(pending), _queue_dir(root), len(jobs) - len(pending))

    now = time.time()
    last_progress = {i: now for i in pending}
    last_beat: dict[int, float] = {}
    while pending:
        still = []
        for i in pending:
            key = keys[i]
            if cache.contains_key(key):
                # published during this resolution: a miss happened for
                # this resolution round (fleet-trained counts as farmed)
                outcomes[i] = CellOutcome(key=key, trained=True)
                _unlink(_error_path(root, key))
                _unlink(_spool_path(root, key))
                continue
            err = _read_error(root, key)
            if err is not None:
                outcomes[i] = CellOutcome(key=key, trained=False, error=err)
                _unlink(_error_path(root, key))
                continue
            try:
                beat = os.stat(_lease_path(root, key)).st_mtime
            except FileNotFoundError:
                beat = None
            if beat is not None and beat != last_beat.get(i):
                last_beat[i] = beat
                last_progress[i] = time.time()
            if time.time() - last_progress[i] > timeout:
                out = _reclaim(jobs[i], key, root, my_id, ttl, fallback)
                if out is None:          # a live claimant appeared mid-break
                    last_progress[i] = time.time()
                    still.append(i)
                else:
                    outcomes[i] = out
                continue
            still.append(i)
        pending = still
        if pending:
            time.sleep(poll)
    return outcomes


def _read_error(root: str, key: str) -> Optional[str]:
    try:
        with open(_error_path(root, key)) as f:
            return f.read() or "fleet worker failed (no message)"
    except FileNotFoundError:
        return None


def _reclaim(job: CellJob, key: str, root: str, my_id: str, ttl: float,
             fallback: bool) -> Optional[CellOutcome]:
    """No fleet progress on ``key`` within the window: break its stale
    lease and train in-process (the submitting study is just another
    claimant).  None means a live lease blocked the reclaim — treat as
    progress and keep waiting."""
    lease = acquire(root, key, my_id, ttl=ttl)
    if lease is None:
        return None
    if not fallback:
        lease.release()
        return CellOutcome(key=key, trained=False,
                           error=f"fleet made no progress on {key} "
                                 f"(fallback disabled)")
    log.warning("fleet: no progress on cell %s; reclaiming for in-process "
                "training", key)
    _unlink(_spool_path(root, key))      # withdrawn: workers must not race
    hb = _Heartbeat(lease, ttl)
    hb.start()
    try:
        from repro.distributed.cellfarm import _resolve_job
        return _resolve_job((job, root))
    finally:
        hb.stop()
        lease.release()
