"""Parallel model-cell training: shard pending cells across processes.

The co-exploration trace cache (``repro.core.workloads.cache``) is
content-addressed and publishes atomically, so concurrent trainers of the
same cell race benignly and trainers of *different* cells never interact —
which makes farming the cell list across worker processes safe without any
coordination beyond a shared cache root.  This module is that driver: give
it the pending ``(workload, assignment)`` jobs and a cache root, and it
round-robins them over ``workers`` spawned processes; afterwards every
farmed cell resolves as a cache hit in the parent.

Workers are spawned (not forked): JAX is not fork-safe once initialized,
and each worker re-imports the stack and trains on CPU independently.  For
one or zero pending jobs the farm degrades to in-process resolution — no
spawn cost for the common all-hits re-run.

``Study``/``dse.explore(..., workers=N)`` and ``dse.coexplore(...,
workers=N)`` are the front ends (ROADMAP "parallel cell farming").
"""
from __future__ import annotations

import dataclasses
import multiprocessing
from typing import Optional, Sequence

from repro.core.workloads.cache import TraceCache
from repro.core.workloads.registry import Workload


@dataclasses.dataclass(frozen=True)
class CellJob:
    """One cell to train-or-load: everything a worker needs, picklable."""
    workload: Workload
    assignment: dict               # {"num_steps": T, "population": p}
    seed: int = 0
    quant_bits: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class CellOutcome:
    key: str                       # content address in the shared cache
    trained: bool                  # True = this worker trained it (a miss)


def _resolve_job(args: tuple[CellJob, str]) -> CellOutcome:
    """Worker entry point: resolve one cell against the shared cache root.
    Module-level so the spawn pickler can import it by reference."""
    job, root = args
    cache = TraceCache(root=root)
    art = cache.resolve(job.workload, job.assignment, seed=job.seed,
                        quant_bits=job.quant_bits)
    return CellOutcome(key=art.key, trained=not art.cache_hit)


def resolve_cells(jobs: Sequence[CellJob], root: str,
                  workers: Optional[int] = None) -> list[CellOutcome]:
    """Resolve ``jobs`` into the cache at ``root``, training missing cells
    across up to ``workers`` processes (default: one per job, capped at the
    CPU count).  Returns one outcome per job, in job order.  The parent's
    own ``TraceCache`` counters are untouched — count ``trained`` outcomes
    for miss accounting."""
    args = [(job, root) for job in jobs]
    if not args:
        return []
    workers = min(workers if workers is not None else len(args),
                  len(args), multiprocessing.cpu_count())
    if workers <= 1 or len(args) == 1:
        return [_resolve_job(a) for a in args]
    ctx = multiprocessing.get_context("spawn")   # JAX is not fork-safe
    with ctx.Pool(processes=workers) as pool:
        return pool.map(_resolve_job, args)
