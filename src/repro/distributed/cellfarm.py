"""Parallel model-cell training: shard pending cells across processes.

The co-exploration trace cache (``repro.core.workloads.cache``) is
content-addressed and publishes atomically, so concurrent trainers of the
same cell race benignly and trainers of *different* cells never interact —
which makes farming the cell list across worker processes safe without any
coordination beyond a shared cache root.  This module is that driver: give
it the pending ``(workload, assignment)`` jobs and a cache root, and it
shards them over a spawned-process pool; afterwards every farmed cell
resolves as a cache hit in the parent.

Pool discipline: workers are spawned (not forked — JAX is not fork-safe
once initialized), the pool size is explicitly capped at
``min(jobs, cpu_count, MAX_POOL_WORKERS)`` so a 100-cell grid never spawns
100 interpreters, the pool is REUSED across calls within one process
(``Study`` steps in one ``explore()`` run share the already-imported
workers; ``atexit`` tears it down), and job submission is chunked so each
worker unpickles one slab instead of one job at a time.

``stack=True`` prefers *stacked* training over process farming: jobs are
grouped by ``cellstack.stack_signature`` and every group that can amortize
a compile (≥2 cells — or every group, when too few workers make farming
moot) trains in-process as one ``jit(vmap(train_step))`` batch
(``repro.distributed.cellstack``); only leftover singletons hit the pool.

``Study``/``dse.explore(..., workers=N, stack=...)`` and ``dse.coexplore``
are the front ends (ROADMAP "parallel cell farming" / "device-parallel
training of stacked cells").
"""
from __future__ import annotations

import atexit
import dataclasses
import multiprocessing
import os
from typing import Optional, Sequence

from repro.core.workloads.cache import TraceCache
from repro.core.workloads.registry import Workload

#: hard cap on spawned workers — each is a full interpreter + JAX runtime,
#: so "one per job" stops paying off long before the CPU count on big hosts
MAX_POOL_WORKERS = int(os.environ.get("REPRO_CELLFARM_MAX_WORKERS", "8"))

_pool = None
_pool_size = 0


@dataclasses.dataclass(frozen=True)
class CellJob:
    """One cell to train-or-load: everything a worker needs, picklable."""
    workload: Workload
    assignment: dict               # {"num_steps": T, "population": p}
    seed: int = 0
    quant_bits: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class CellOutcome:
    key: str                       # content address in the shared cache
    trained: bool                  # True = this worker trained it (a miss)


def _resolve_job(args: tuple[CellJob, str]) -> CellOutcome:
    """Worker entry point: resolve one cell against the shared cache root.
    Module-level so the spawn pickler can import it by reference."""
    job, root = args
    cache = TraceCache(root=root)
    art = cache.resolve(job.workload, job.assignment, seed=job.seed,
                        quant_bits=job.quant_bits)
    return CellOutcome(key=art.key, trained=not art.cache_hit)


def _worker_count(n_jobs: int, workers: Optional[int]) -> int:
    """Effective pool size: explicit request, else one per job — both
    capped at the CPU count and the module-level ``MAX_POOL_WORKERS``."""
    return min(workers if workers is not None else n_jobs,
               n_jobs, multiprocessing.cpu_count(), MAX_POOL_WORKERS)


def _get_pool(workers: int):
    """The shared spawn pool, rebuilt only when the requested size changes
    — repeated ``resolve_cells`` calls (Study steps, prefetch rounds)
    reuse the already-imported workers instead of paying a fresh
    interpreter + JAX import per call."""
    global _pool, _pool_size
    if _pool is not None and _pool_size != workers:
        shutdown_pool()
    if _pool is None:
        ctx = multiprocessing.get_context("spawn")   # JAX is not fork-safe
        _pool = ctx.Pool(processes=workers)
        _pool_size = workers
    return _pool


def shutdown_pool() -> None:
    """Tear down the shared worker pool (idempotent; re-created lazily)."""
    global _pool, _pool_size
    if _pool is not None:
        _pool.terminate()
        _pool.join()
        _pool = None
        _pool_size = 0


atexit.register(shutdown_pool)


def resolve_cells(jobs: Sequence[CellJob], root: str,
                  workers: Optional[int] = None,
                  stack: bool = False,
                  max_stack: Optional[int] = None) -> list[CellOutcome]:
    """Resolve ``jobs`` into the cache at ``root``; returns one outcome per
    job, in job order.  ``workers`` bounds the process pool (default: one
    per job, capped at the CPU count and ``MAX_POOL_WORKERS``).

    ``stack=True`` routes same-signature groups through the in-process
    vmapped stack trainer first (``cellstack.resolve_stacked``): with a
    usable pool (≥2 effective workers) only ≥2-cell groups stack and
    singletons still farm in parallel; without one, everything stacks
    in-process (a C=1 stack is just the solo loop, minus the spawn).

    The parent's own ``TraceCache`` counters are untouched — count
    ``trained`` outcomes for miss accounting."""
    jobs = list(jobs)
    if not jobs:
        return []
    outcomes: list[Optional[CellOutcome]] = [None] * len(jobs)

    if stack:
        from repro.distributed import cellstack   # lazy: cellstack imports us
        groups = cellstack.group_jobs(jobs)
        if _worker_count(len(jobs), workers) >= 2:
            stacked_idx = sorted(i for idxs in groups.values()
                                 if len(idxs) >= 2 for i in idxs)
        else:
            stacked_idx = list(range(len(jobs)))
        if stacked_idx:
            kw = {} if max_stack is None else {"max_stack": max_stack}
            got = cellstack.resolve_stacked(
                [jobs[i] for i in stacked_idx], root, **kw)
            for i, out in zip(stacked_idx, got):
                outcomes[i] = out

    farm_idx = [i for i in range(len(jobs)) if outcomes[i] is None]
    if farm_idx:
        args = [(jobs[i], root) for i in farm_idx]
        n = _worker_count(len(args), workers)
        if n <= 1 or len(args) == 1:
            got = [_resolve_job(a) for a in args]
        else:
            # chunked submission: one slab per worker, not one pickle
            # round-trip per job
            chunksize = max(1, (len(args) + n - 1) // n)
            got = _get_pool(n).map(_resolve_job, args, chunksize=chunksize)
        for i, out in zip(farm_idx, got):
            outcomes[i] = out
    return outcomes
