"""Parallel model-cell training: shard pending cells across processes.

The co-exploration trace cache (``repro.core.workloads.cache``) is
content-addressed and publishes atomically, so concurrent trainers of the
same cell race benignly and trainers of *different* cells never interact —
which makes farming the cell list across worker processes safe without any
coordination beyond a shared cache root.  This module is that driver: give
it the pending ``(workload, assignment)`` jobs and a cache root, and it
shards them over a spawned-process pool; afterwards every farmed cell
resolves as a cache hit in the parent.

Pool discipline: workers are spawned (not forked — JAX is not fork-safe
once initialized), the pool size is explicitly capped at
``min(jobs, cpu_count, MAX_POOL_WORKERS)`` so a 100-cell grid never spawns
100 interpreters, the pool is REUSED across calls within one process
(``Study`` steps in one ``explore()`` run share the already-imported
workers; ``atexit`` tears it down), and job submission is chunked so each
worker unpickles one slab instead of one job at a time.

``stack=True`` prefers *stacked* training over process farming: jobs are
grouped by ``cellstack.stack_signature`` and every group that can amortize
a compile (≥2 cells — or every group, when too few workers make farming
moot) trains in-process as one ``jit(vmap(train_step))`` batch
(``repro.distributed.cellstack``); only leftover singletons hit the pool.

``Study``/``dse.explore(..., workers=N, stack=...)`` and ``dse.coexplore``
are the front ends (ROADMAP "parallel cell farming" / "device-parallel
training of stacked cells").

Fault containment: ``resolve_cells`` never raises on a bad cell — worker
exceptions return as failed ``CellOutcome``\\ s, a hard pool crash tears
down and rebuilds the pool, and both are retried up to ``MAX_RETRIES``
rounds (the ``distributed.fault_tolerance`` restart idiom) before the
failure ships in ``CellOutcome.error`` for the caller to fall back on —
required by the multi-tenant service loop (``repro.serve.dse_service``),
where one tenant's bad cell must not kill another tenant's study.
"""
from __future__ import annotations

import atexit
import dataclasses
import logging
import multiprocessing
import os
from typing import Optional, Sequence, Union

from repro.core.workloads.cache import TraceCache, cell_key
from repro.core.workloads.registry import Workload

log = logging.getLogger(__name__)

#: hard cap on spawned workers — each is a full interpreter + JAX runtime,
#: so "one per job" stops paying off long before the CPU count on big hosts
MAX_POOL_WORKERS = int(os.environ.get("REPRO_CELLFARM_MAX_WORKERS", "8"))

#: bounded-retry budget for failed cells (the ``fault_tolerance``
#: supervisor's restart idiom): a crashed worker or a raising job is
#: retried this many extra rounds before its outcome ships with ``error``
#: set — it never raises through the caller (``Study._farm_chunk``)
MAX_RETRIES = int(os.environ.get("REPRO_CELLFARM_MAX_RETRIES", "2"))

_pool = None
_pool_size = 0


@dataclasses.dataclass(frozen=True)
class CellJob:
    """One cell to train-or-load: everything a worker needs, picklable."""
    workload: Workload
    assignment: dict               # {"num_steps": T, "population": p}
    seed: int = 0
    quant_bits: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class CellOutcome:
    key: str                       # content address in the shared cache
    trained: bool                  # True = this worker trained it (a miss)
    #: set when the cell could not be resolved after ``MAX_RETRIES`` retry
    #: rounds — the cache holds nothing for it and nothing was charged;
    #: callers fall back to in-process resolution (or skip)
    error: Optional[str] = None


def _job_key(job: CellJob) -> str:
    norm = {"num_steps": int(job.assignment["num_steps"]),
            "population": float(job.assignment.get("population", 1.0))}
    return cell_key(job.workload, norm, job.seed)


def _resolve_job(args: tuple[CellJob, str]) -> CellOutcome:
    """Worker entry point: resolve one cell against the shared cache root.
    Module-level so the spawn pickler can import it by reference.  Any
    job-level failure is *returned* as a failed outcome, never raised — a
    worker must not poison the whole slab it was mapped."""
    job, root = args
    try:
        cache = TraceCache(root=root)
        art = cache.resolve(job.workload, job.assignment, seed=job.seed,
                            quant_bits=job.quant_bits)
        return CellOutcome(key=art.key, trained=not art.cache_hit)
    except KeyboardInterrupt:
        raise
    except BaseException as e:                           # noqa: BLE001
        return CellOutcome(key=_job_key(job), trained=False,
                           error=f"{type(e).__name__}: {e}")


def _worker_count(n_jobs: int, workers: Optional[int]) -> int:
    """Effective pool size: explicit request, else one per job — both
    capped at the CPU count and the module-level ``MAX_POOL_WORKERS``."""
    return min(workers if workers is not None else n_jobs,
               n_jobs, multiprocessing.cpu_count(), MAX_POOL_WORKERS)


def _get_pool(workers: int):
    """The shared spawn pool, rebuilt only when the requested size changes
    — repeated ``resolve_cells`` calls (Study steps, prefetch rounds)
    reuse the already-imported workers instead of paying a fresh
    interpreter + JAX import per call."""
    global _pool, _pool_size
    if _pool is not None and _pool_size != workers:
        shutdown_pool()
    if _pool is None:
        ctx = multiprocessing.get_context("spawn")   # JAX is not fork-safe
        _pool = ctx.Pool(processes=workers)
        _pool_size = workers
    return _pool


def shutdown_pool() -> None:
    """Tear down the shared worker pool (idempotent; re-created lazily)."""
    global _pool, _pool_size
    if _pool is not None:
        _pool.terminate()
        _pool.join()
        _pool = None
        _pool_size = 0


atexit.register(shutdown_pool)


def _farm_attempt(args: Sequence[tuple[CellJob, str]],
                  workers: Optional[int]) -> list[CellOutcome]:
    """One farming round.  Job-level failures come back as failed outcomes
    from ``_resolve_job``; a *pool*-level crash (a worker process died hard
    enough to break the map) marks every in-flight job failed and tears the
    poisoned pool down, so the next attempt gets a fresh one."""
    args = list(args)
    n = _worker_count(len(args), workers)
    if n <= 1 or len(args) == 1:
        return [_resolve_job(a) for a in args]
    # chunked submission: one slab per worker, not one pickle round-trip
    # per job
    chunksize = max(1, (len(args) + n - 1) // n)
    try:
        return _get_pool(n).map(_resolve_job, args, chunksize=chunksize)
    except Exception as e:                               # noqa: BLE001
        shutdown_pool()
        err = f"worker pool crashed: {type(e).__name__}: {e}"
        log.warning("%s (%d cell(s) in flight)", err, len(args))
        return [CellOutcome(key=_job_key(job), trained=False, error=err)
                for job, _ in args]


def resolve_cells(jobs: Sequence[CellJob], root: str,
                  workers: Union[int, str, None] = None,
                  stack: bool = False,
                  max_stack: Optional[int] = None,
                  retries: Optional[int] = None) -> list[CellOutcome]:
    """Resolve ``jobs`` into the cache at ``root``; returns one outcome per
    job, in job order.  ``workers`` bounds the process pool (default: one
    per job, capped at the CPU count and ``MAX_POOL_WORKERS``).

    ``workers="cluster"`` farms across *hosts* instead of processes: jobs
    spool to ``<root>/queue/`` and any ``fleet.FleetWorker`` enrolled on
    the shared root claims them by lease (``repro.distributed.fleet``).
    The call blocks on lease/publish progress and falls back to in-process
    training for cells the fleet makes no progress on, so it completes
    even with zero live workers.  Failed outcomes ship with
    ``CellOutcome.error`` exactly like the process farm (the fleet path
    has its own reclaim/retry machinery, so the local retry loop does not
    re-enter it); ``stack`` does not apply — slab formation is each
    worker's own affair.

    ``stack=True`` routes same-signature groups through the in-process
    vmapped stack trainer first (``cellstack.resolve_stacked``): with a
    usable pool (≥2 effective workers) only ≥2-cell groups stack and
    singletons still farm in parallel; without one, everything stacks
    in-process (a C=1 stack is just the solo loop, minus the spawn).

    This function **never raises on a bad cell**: a crashed worker, a
    poisoned pool, or a job that errors is retried up to ``retries``
    (default ``MAX_RETRIES``) extra rounds — the ``fault_tolerance``
    restart idiom — and then returned with ``CellOutcome.error`` set, so
    one bad cell cannot kill a study or a service loop.  A failed stack
    group degrades to farming before counting as a retry.

    The parent's own ``TraceCache`` counters are untouched — count
    ``trained`` outcomes for miss accounting."""
    jobs = list(jobs)
    if not jobs:
        return []
    if workers == "cluster":
        from repro.distributed import fleet   # lazy: fleet imports us
        return fleet.resolve_cluster(jobs, root)
    if isinstance(workers, str):
        raise ValueError(f"workers must be an int or 'cluster', "
                         f"got {workers!r}")
    retries = MAX_RETRIES if retries is None else int(retries)
    outcomes: list[Optional[CellOutcome]] = [None] * len(jobs)

    if stack:
        from repro.distributed import cellstack   # lazy: cellstack imports us
        groups = cellstack.group_jobs(jobs)
        if _worker_count(len(jobs), workers) >= 2:
            stacked_idx = sorted(i for idxs in groups.values()
                                 if len(idxs) >= 2 for i in idxs)
        else:
            stacked_idx = list(range(len(jobs)))
        if stacked_idx:
            kw = {} if max_stack is None else {"max_stack": max_stack}
            try:
                got = cellstack.resolve_stacked(
                    [jobs[i] for i in stacked_idx], root, **kw)
            except Exception as e:                       # noqa: BLE001
                # a failed in-process stack is not fatal: the cells fall
                # through to the farm/serial path below untouched
                log.warning("stacked training failed (%s: %s); falling "
                            "back to farming %d cell(s)",
                            type(e).__name__, e, len(stacked_idx))
            else:
                for i, out in zip(stacked_idx, got):
                    outcomes[i] = out

    pending = [i for i in range(len(jobs)) if outcomes[i] is None]
    attempt = 0
    while pending:
        got = _farm_attempt([(jobs[i], root) for i in pending], workers)
        for i, out in zip(pending, got):
            outcomes[i] = out
        pending = [i for i in pending if outcomes[i].error is not None]
        if not pending:
            break
        attempt += 1
        if attempt > retries:
            log.warning("giving up on %d cell(s) after %d retry round(s): "
                        "%s", len(pending), retries,
                        [outcomes[i].error for i in pending[:3]])
            break
        log.warning("retrying %d failed cell(s), round %d/%d",
                    len(pending), attempt, retries)
    return outcomes
