"""Fault tolerance: checkpoint/restart supervision and elastic restore.

On a real multi-pod deployment the failure signal comes from the cluster
manager (missing heartbeat / NCCL-equivalent timeout); here the supervisor
wraps the training loop and reacts to Python exceptions identically:
restore latest checkpoint -> rebuild step -> continue.  The restore path
supports a DIFFERENT mesh than the save path (elastic rescale) because
checkpoints are host-format and resharded on load
(repro.checkpoint.store.restore).

Straggler mitigation at true scale (not exercisable on one host) is
documented in README §Fault tolerance: synchronous data-parallel with
backup-worker dispatch for input pipeline stragglers, and checkpoint-based
eviction for persistent stragglers.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

from repro.checkpoint import store

log = logging.getLogger(__name__)


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep_last: int = 3
    max_restarts: int = 10
    async_save: bool = True


class TrainSupervisor:
    """Run a step function under checkpoint/restart supervision.

    ``state``: any pytree (params, opt_state, step counter...).
    ``step_fn(state, step) -> state``.  Any exception triggers a restore of
    the latest checkpoint and a restart from its step.
    """

    def __init__(self, cfg: SupervisorConfig, state: Any,
                 shardings: Optional[Any] = None):
        self.cfg = cfg
        self.state = state
        self.shardings = shardings
        self.restarts = 0
        self._pending = None

    def _save(self, step: int):
        if self.cfg.async_save:
            if self._pending is not None:
                self._pending.join()       # one outstanding save at a time
            self._pending = store.save_async(
                self.cfg.checkpoint_dir, step, self.state,
                keep_last=self.cfg.keep_last)
        else:
            store.save(self.cfg.checkpoint_dir, step, self.state,
                       keep_last=self.cfg.keep_last)

    def _restore(self) -> int:
        # Join the in-flight async save BEFORE picking the step: reading
        # latest_step first can select a checkpoint older than the one the
        # pending writer publishes moments later — a stale restore that
        # silently replays already-durable steps.
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        step = store.latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return 0
        self.state = store.restore(self.cfg.checkpoint_dir, self.state,
                                   step=step, shardings=self.shardings)
        log.warning("restored checkpoint at step %d", step)
        return step

    def run(self, step_fn: Callable[[Any, int], Any], num_steps: int) -> Any:
        step = 0
        while step < num_steps:
            try:
                while step < num_steps:
                    self.state = step_fn(self.state, step)
                    step += 1
                    if step % self.cfg.checkpoint_every == 0:
                        self._save(step)
            except KeyboardInterrupt:
                raise
            except Exception as e:            # noqa: BLE001 — node failure
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_restarts} restarts") from e
                log.warning("step %d failed (%s); restarting", step, e)
                step = self._restore()
        self._save(step)
        if self._pending is not None:
            self._pending.join()
        return self.state
