"""Per-architecture sharding rules.

Conventions (DESIGN.md §5):
  * "model" (M, 16-way): tensor-parallel dims — flattened head projections,
    d_ff, vocab, MoE experts (when E % 16 == 0), SSD heads, cache head_dim.
  * "data" (D, 16-way) and "pod" (P, 2-way): the global batch; additionally
    the FSDP axis for very large models (optimizer state + params shard over
    D), and the cache *sequence* axis when batch == 1 (long_500k).
  * Projections are sharded on their flattened output dim (e.g. n_heads *
    head_dim), never on a raw head count — this keeps every sharded dim
    divisible by 16 across all ten assigned archs (llama's 24 heads flatten
    to 3072 = 16 * 192).

The rules are path+shape driven so one engine serves every family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

PyTree = Any

# archs whose optimizer state / params additionally shard over "data" (ZeRO)
FSDP_ARCHS = {"arctic-480b", "qwen2-vl-72b", "mixtral-8x7b", "chatglm3-6b"}


def _keystr(path) -> str:
    """``jax.tree_util.keystr(path, simple=True, separator="/")``, built by
    hand because the ``simple``/``separator`` kwargs only exist in newer JAX
    releases.  DictKey carries ``.key``, GetAttrKey ``.name``, SequenceKey
    ``.idx``, FlattenedIndexKey ``.key``."""
    parts = []
    for entry in path:
        for attr in ("key", "name", "idx"):
            if hasattr(entry, attr):
                parts.append(str(getattr(entry, attr)))
                break
        else:
            parts.append(str(entry))
    return "/".join(parts)


def _divisible(n: int, mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def param_spec(path: str, shape: tuple[int, ...], cfg: ArchConfig,
               mesh) -> P:
    """Sharding spec for one parameter leaf.

    ``shape`` includes the stacked-layer leading dim (scan layout): specs
    lead with None for it.
    """
    lead = (None,)  # stacked layers / groups dims (never sharded)
    nd = len(shape)
    is_stacked = ("layers/" in path or "mamba/" in path or
                  "encoder/" in path or "decoder/" in path)
    core = shape[1:] if is_stacked else shape
    if "mamba/" in path:                  # (ng, every, ...) double-stacked
        core = shape[2:]
        lead = (None, None)
    if not is_stacked:
        lead = ()

    def with_lead(*spec):
        return P(*lead, *spec)

    M = "model"
    # ---- embeddings / unembedding ----
    if path.endswith("embed/embedding"):
        # Vocab-sharded.  (A d-sharded table avoids the per-lookup table
        # all-gather, but measured on qwen2-vl prefill it leaks d-sharding
        # into downstream buffers and costs +27 GB/device residents for a
        # -13% wire win — see EXPERIMENTS.md §Perf qwen iterations; the
        # vocab-sharded layout wins on the binding constraint, HBM.)
        return P(M, None)
    if "lm_head" in path:
        return with_lead(None, M) if len(core) == 2 else with_lead(None)
    # ---- MoE ----
    if "/moe/" in path or path.startswith("moe/"):
        if "router" in path:
            return with_lead(*([None] * len(core)))
        if len(core) == 3:  # (E, d, ff) / (E, ff, d)
            if cfg.moe and _divisible(cfg.moe.num_experts, mesh, M):
                return with_lead(M, None, None)        # expert parallel
            # few experts (mixtral): tensor-parallel on each expert's ff
            # dim; the dispatch capacity dim C is data-sharded in
            # moe_apply, so gate/up need no collective and down pays one
            # (E, C/16, d) partial all-reduce per layer (EXPERIMENTS.md
            # §Perf, mixtral iterations — both the FSDP d@data layout and
            # the 2D ff@(model,data) layout lose to this by >10x wire)
            ff_dim = 1 if "w_down" in path else 2
            spec = [None, None, None]
            spec[ff_dim] = M
            return with_lead(*spec)
        # dense-residual MLP inside the moe dict
        if "w_down" in path:
            return with_lead(M, None)
        if "w_gate" in path or "w_up" in path:
            return with_lead(None, M)
        return with_lead(*([None] * len(core)))
    # ---- attention / MLP projections ----
    if any(k in path for k in ("wq", "wk", "wv")):
        return with_lead(None, M)
    if "wo" in path:
        return with_lead(M, None)
    if "w_gate" in path or "w_up" in path:
        return with_lead(None, M)
    if "w_down" in path:
        return with_lead(M, None)
    # ---- SSM block ----
    if "in_proj" in path:
        return with_lead(None, M)
    if "out_proj" in path:
        return with_lead(M, None)
    if "conv_w" in path:
        return with_lead(None, M)
    if "conv_b" in path:
        return with_lead(M)
    # ---- norms, biases, scalars ----
    return with_lead(*([None] * len(core)))


def param_specs(cfg: ArchConfig, params_shapes: PyTree, mesh,
                fsdp: Optional[bool] = None) -> PyTree:
    fsdp = cfg.name in FSDP_ARCHS if fsdp is None else fsdp

    def one(path, leaf):
        p = _keystr(path)
        spec = param_spec(p, leaf.shape, cfg, mesh)
        if fsdp:
            spec = fsdp_extend(spec, leaf.shape, mesh,
                               skip_tp_experts=False)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def fsdp_extend(spec: P, shape: tuple[int, ...], mesh,
                axis: str = "data", min_size: int = 1024,
                skip_tp_experts: bool = True) -> P:
    """ZeRO-style: shard the largest still-replicated dim over `axis`."""
    if axis not in mesh.axis_names:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for e in entries:                    # already data-sharded (e.g. 2D ff)
        if e == axis or (isinstance(e, tuple) and axis in e):
            return spec
    # (skip_tp_experts=True leaves TP-inside-expert weights unsharded on
    # data; measured on mixtral train this LOST to plain FSDP — XLA's
    # activation-all-reduce route for the d@data contraction is cheaper
    # than the layouts that avoid it; see EXPERIMENTS.md §Perf iters 2-4.
    # Kept as an option for the serve path.)
    if skip_tp_experts and len(shape) >= 3 and any(
            e == "model" for e in entries[1:]):
        return spec
    best, best_size = None, min_size - 1
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % mesh.shape[axis] == 0 and n > best_size:
            best, best_size = i, n
    if best is None:
        return spec
    entries[best] = axis
    return P(*entries)


def serve_param_specs(cfg: ArchConfig, params_shapes: PyTree, mesh) -> PyTree:
    """Decode-time weight sharding: 2D TP across (model x data).

    Training uses FSDP (weights gathered under the compute of a big step);
    a one-token decode step cannot hide a 150 GB weight all-gather (see
    EXPERIMENTS.md §Perf, arctic iteration).  Here every large weight is
    *fully* sharded across both axes with "data" on a NON-contracted dim,
    so the forward needs no weight resharding — only tiny activation
    all-reduces.
    """
    base = param_specs(cfg, params_shapes, mesh, fsdp=False)

    def extend(spec: P, leaf) -> P:
        shape = leaf.shape
        if len(shape) < 2 or "data" not in mesh.axis_names:
            return spec
        nd_data = mesh.shape["data"]
        nd_both = nd_data * mesh.shape["model"]
        entries = list(spec) + [None] * (len(shape) - len(spec))
        if "data" in entries or any(isinstance(e, tuple) for e in entries):
            return spec
        last = len(shape) - 1
        # output (non-contracted) dim last: prefer sharding it
        if entries[last] is None and shape[last] % nd_data == 0:
            entries[last] = "data"
        elif entries[last] == "model" and shape[last] % nd_both == 0:
            entries[last] = ("model", "data")
        else:
            for i in range(len(shape) - 1, -1, -1):
                if entries[i] is None and shape[i] % nd_data == 0:
                    entries[i] = "data"
                    break
        return P(*entries)

    return jax.tree.map(extend, base, params_shapes,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Optimizer state specs (mirror the param tree; factored leaves truncated)
# ---------------------------------------------------------------------------

def opt_state_specs(opt_shapes: PyTree, params_shapes: PyTree,
                    p_specs: PyTree) -> PyTree:
    pstruct = jax.tree.structure(params_shapes)
    p_leaves = jax.tree.leaves(params_shapes)
    s_leaves = jax.tree.leaves(p_specs, is_leaf=lambda x: isinstance(x, P))

    def match_leaf(leaf, param, spec):
        if leaf.shape == param.shape:
            return spec
        entries = list(spec) + [None] * (len(param.shape) - len(spec))
        if leaf.shape == param.shape[:-1]:              # adafactor row
            return P(*entries[:-1])
        if leaf.shape == param.shape[:-2] + param.shape[-1:]:  # adafactor col
            return P(*(entries[:-2] + entries[-1:]))
        return P()

    def rec(sub):
        if sub is None:
            return None
        if isinstance(sub, jax.ShapeDtypeStruct):
            return P()                                   # scalar state (count)
        try:
            if jax.tree.structure(sub) == pstruct:
                leaves, treedef = jax.tree.flatten(sub)
                return treedef.unflatten(
                    [match_leaf(l, p, s)
                     for l, p, s in zip(leaves, p_leaves, s_leaves)])
        except Exception:
            pass
        if hasattr(sub, "_fields"):
            return type(sub)(*[rec(getattr(sub, f)) for f in sub._fields])
        if isinstance(sub, (tuple, list)):
            return type(sub)(rec(x) for x in sub)
        if isinstance(sub, dict):
            return {k: rec(v) for k, v in sub.items()}
        return P()

    return rec(opt_shapes)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def _baxes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_specs(cfg: ArchConfig, batch: PyTree, mesh) -> PyTree:
    ba = _baxes(mesh)

    def one(path, leaf):
        p = _keystr(path)
        nb = int(np.prod([mesh.shape[a] for a in
                          (ba if isinstance(ba, tuple) else (ba,))]))
        if "positions" in p:               # (3, B, S)
            return P(None, ba, None) if leaf.shape[1] % nb == 0 else P()
        if leaf.shape[0] % nb != 0:        # tiny batch (long_500k): replicate
            return P(*([None] * leaf.ndim))
        return P(ba, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cfg: ArchConfig, cache_shapes: PyTree, mesh,
                batch_size: int) -> PyTree:
    """KV/state cache sharding.

    batch >= batch-shards: shard batch over (pod?, data), head_dim over model.
    batch == 1 (long_500k): shard the cache sequence axis over data instead.
    """
    ba = _baxes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in
                      (ba if isinstance(ba, tuple) else (ba,))]))
    shard_batch = batch_size % nb == 0
    M = "model"

    def one(path, leaf):
        p = _keystr(path)
        if p.endswith("length"):
            return P()
        if p.endswith("slot_pos"):          # (B, C)
            if shard_batch:
                return P(ba, None)
            return P(None, "data") if leaf.shape[1] % mesh.shape["data"] == 0 else P()
        # cache tensors: (L, B, C, n_kv, hd) | (L/ng, B, ...) | (ng, every, B, ...)
        shape = leaf.shape
        spec = [None] * leaf.ndim
        # find the batch dim (== batch_size)
        try:
            bpos = shape.index(batch_size)
        except ValueError:
            return P(*spec)
        if shard_batch:
            spec[bpos] = ba
        if p.endswith("k") or p.endswith("v") or "cross" in p:
            # (..., B, C, n_kv, hd): shard the SEQUENCE dim C on "model"
            # (split-KV / flash-decoding style).  C always divides 16; the
            # decode softmax becomes partial max/sum + a tiny all-reduce,
            # with no cache resharding (hd-sharding made GSPMD gather the
            # whole cache — EXPERIMENTS.md §Perf, arctic iterations).
            if not shard_batch and shape[-3] % (
                    mesh.shape["data"] * mesh.shape[M]) == 0:
                spec[-3] = ("data", M)
            elif shape[-3] % mesh.shape[M] == 0:
                spec[-3] = M
        elif p.endswith("h"):               # SSD state (..., B, H, N, P)
            if shape[bpos + 1] % mesh.shape[M] == 0:
                spec[bpos + 1] = M          # heads on model
        elif "conv" in p:                   # (..., B, W-1, conv_ch)
            if shape[-1] % mesh.shape[M] == 0:
                spec[-1] = M
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_named(tree_specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
