"""Gradient compression for the data-parallel all-reduce (int8 + error
feedback).

Synchronous data parallelism all-reduces fp32 gradients; at 1000+ nodes the
DP all-reduce is bandwidth-bound, and 4x compression is ~4x fewer bytes on
the wire.  The scheme here is the standard error-feedback quantizer:

    e      <- residual carried from last step           (local, never sent)
    g'     <- g + e
    q      <- round(g' / scale) clipped to int8, scale = max|g'| / 127
    e      <- g' - q * scale                            (new residual)
    G      <- all_reduce_mean(q * scale)                (wire: 1 byte/elem)

Implemented with ``shard_map`` over the batch axes so the quantization is
explicit *around* the collective (inside pjit the all-reduce is implicit and
cannot be intercepted).  Convergence is exercised in
tests/test_distributed.py on a toy model across 8 host devices.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_allreduce(grads: PyTree, errors: PyTree,
                          axis_names: tuple[str, ...]
                          ) -> tuple[PyTree, PyTree]:
    """Per-shard: error-feedback int8 quantize, mean-all-reduce, return
    (global grads, new error residuals).  Must run inside shard_map."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize(q, scale)
        new_e = g32 - deq
        # wire format: int8 payload + fp32 scale; the psum below models the
        # reduction (XLA reduces the dequantized value; byte savings are a
        # property of the interconnect codec on real hardware)
        total = deq
        for ax in axis_names:
            total = jax.lax.pmean(total, ax)
        return total, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    gs, es = zip(*out) if out else ((), ())
    return treedef.unflatten(list(gs)), treedef.unflatten(list(es))


def make_compressed_grad_fn(loss_fn, mesh, batch_axes: tuple[str, ...] = ("data",)):
    """Wrap a per-shard loss into a shard_mapped gradient function with
    int8 error-feedback all-reduce.

    loss_fn(params, batch) -> scalar (computed on the LOCAL batch shard).
    Returns grad_step(params, batch, errors) -> (loss, grads, new_errors);
    params replicated, batch sharded over ``batch_axes``, and the error
    residuals carried with a leading shard dim (they are LOCAL state — each
    shard keeps its own residual; see init_errors).
    """
    from jax.experimental.shard_map import shard_map

    def per_shard(params, batch, errors):
        errors = jax.tree.map(lambda e: e[0], errors)      # drop shard dim
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        for ax in batch_axes:
            loss = jax.lax.pmean(loss, ax)
        grads, errors = ef_compress_allreduce(grads, errors, batch_axes)
        errors = jax.tree.map(lambda e: e[None], errors)
        return loss, grads, errors

    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def grad_step(params, batch, errors):
        fn = shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(), P(ba), P(ba)),
            out_specs=(P(), P(), P(ba)),
            check_rep=False)
        return fn(params, batch, errors)

    return grad_step


def init_errors(params: PyTree, n_shards: int) -> PyTree:
    """Residuals stacked over shards: leading dim = number of batch shards."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + p.shape, jnp.float32), params)
