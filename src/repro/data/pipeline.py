"""Deterministic, shardable host-side data pipeline for LM training.

At production scale every host builds only ITS shard of the global batch
(``host_slice``) and the arrays are assembled into the sharded global batch
via ``jax.make_array_from_process_local_data``; on this single-host container
the same code path degenerates to a device_put with the batch sharding.
Determinism: batch ``i`` of a given (seed, config) is identical regardless of
host count — the elastic-restart requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.seq_len]))


def synthetic_lm_batch(cfg: DataConfig, step: int,
                       order: int = 2) -> dict[str, np.ndarray]:
    """Markov-chain token batch (learnable structure) for step ``step``."""
    rng = _batch_rng(cfg, step)
    likely_rng = np.random.default_rng(cfg.seed)       # chain fixed per run
    likely = likely_rng.integers(0, cfg.vocab, size=(cfg.vocab, 4))
    ctx_w = likely_rng.integers(1, cfg.vocab, size=order)
    B, S = cfg.global_batch, cfg.seq_len
    seqs = np.zeros((B, S + 1), np.int32)
    state = rng.integers(0, cfg.vocab, size=(B, order))
    for t in range(S + 1):
        ctx = (state * ctx_w).sum(-1) % cfg.vocab
        choice = likely[ctx, rng.integers(0, 4, size=B)]
        noise = rng.integers(0, cfg.vocab, size=B)
        tok = np.where(rng.random(B) < 0.1, noise, choice).astype(np.int32)
        seqs[:, t] = tok
        state = np.concatenate([state[:, 1:], tok[:, None]], axis=1)
    return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def host_slice(global_arr: np.ndarray, process_index: int,
               process_count: int) -> np.ndarray:
    """The rows of the global batch this host is responsible for."""
    B = global_arr.shape[0]
    per = B // process_count
    return global_arr[process_index * per:(process_index + 1) * per]


def device_batches(cfg: DataConfig, shardings: Optional[dict] = None,
                   start_step: int = 0) -> Iterator[dict]:
    """Iterate sharded device batches from ``start_step`` (restart support)."""
    step = start_step
    while True:
        host = synthetic_lm_batch(cfg, step)
        if shardings is None:
            yield {k: jnp.asarray(v) for k, v in host.items()}
        else:
            yield {k: jax.device_put(jnp.asarray(v), shardings[k])
                   for k, v in host.items()}
        step += 1
