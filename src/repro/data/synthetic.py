"""Deterministic synthetic datasets (offline stand-ins for MNIST / FMNIST /
DVSGesture) plus token streams for the LM substrate.

The image datasets are *structurally matched* to the originals (28x28 in
[0,1], 10 classes; event streams with two polarity channels for the DVS
analogue) and are generated from fixed seeds so every run, test, and
benchmark sees identical data.  See DESIGN.md §7 for why (no network access).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


def _smooth_prototypes(rng: np.ndarray, num_classes: int, h: int, w: int,
                       blobs: int = 4) -> np.ndarray:
    """Class prototypes as mixtures of Gaussian blobs -> smooth, distinct."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    protos = np.zeros((num_classes, h, w), np.float32)
    for c in range(num_classes):
        for _ in range(blobs):
            cy, cx = rng.uniform(4, h - 4), rng.uniform(4, w - 4)
            sig = rng.uniform(1.5, 4.0)
            amp = rng.uniform(0.5, 1.0)
            protos[c] += amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig ** 2))
        protos[c] /= protos[c].max() + 1e-9
    return protos


def make_images(name: str = "synth-mnist", seed: int = 0, num_classes: int = 10,
                n_train: int = 2048, n_test: int = 512, h: int = 28, w: int = 28,
                noise: float = 0.15) -> Dataset:
    """MNIST/FMNIST-like: per-class smooth prototypes + pixel noise, in [0,1]."""
    rng = np.random.default_rng(seed)
    protos = _smooth_prototypes(rng, num_classes, h, w)

    def _make(n):
        y = rng.integers(0, num_classes, size=n)
        x = protos[y] + noise * rng.standard_normal((n, h, w)).astype(np.float32)
        # per-sample random gain, mimicking intensity variation
        x *= rng.uniform(0.7, 1.0, size=(n, 1, 1)).astype(np.float32)
        return np.clip(x, 0.0, 1.0), y.astype(np.int32)

    x_tr, y_tr = _make(n_train)
    x_te, y_te = _make(n_test)
    return Dataset(name, x_tr, y_tr, x_te, y_te, num_classes)


def make_events(name: str = "synth-dvs", seed: int = 0, num_classes: int = 8,
                n_train: int = 512, n_test: int = 128, t: int = 16,
                h: int = 32, w: int = 32) -> Dataset:
    """DVSGesture-like event streams: a bright blob moving along a
    class-specific trajectory; two polarity channels (on/off events).

    Returns x arrays of shape (N, T, H, W, 2) in {0,1}.
    """
    rng = np.random.default_rng(seed)
    angles = np.linspace(0, 2 * np.pi, num_classes, endpoint=False)
    speeds = 1.0 + 0.5 * (np.arange(num_classes) % 2)

    def _make(n):
        y = rng.integers(0, num_classes, size=n)
        x = np.zeros((n, t, h, w, 2), np.float32)
        for i in range(n):
            ang, spd = angles[y[i]], speeds[y[i]]
            cy, cx = rng.uniform(h * 0.3, h * 0.7), rng.uniform(w * 0.3, w * 0.7)
            dy, dx = spd * np.sin(ang), spd * np.cos(ang)
            prev = None
            for ts in range(t):
                py, px = int(cy + dy * ts) % h, int(cx + dx * ts) % w
                mask = np.zeros((h, w), bool)
                y0, y1 = max(py - 2, 0), min(py + 3, h)
                x0, x1 = max(px - 2, 0), min(px + 3, w)
                mask[y0:y1, x0:x1] = True
                if prev is not None:
                    on = mask & ~prev
                    off = prev & ~mask
                    x[i, ts, :, :, 0][on] = 1.0
                    x[i, ts, :, :, 1][off] = 1.0
                prev = mask
            # sensor noise events
            noise = rng.random((t, h, w, 2)) < 0.01
            x[i] = np.maximum(x[i], noise.astype(np.float32))
        return x, y.astype(np.int32)

    x_tr, y_tr = _make(n_train)
    x_te, y_te = _make(n_test)
    return Dataset(name, x_tr, y_tr, x_te, y_te, num_classes)


def make_tokens(seed: int = 0, vocab: int = 1024, n_seqs: int = 512,
                seq_len: int = 256, order: int = 2) -> np.ndarray:
    """Synthetic language data: a random order-``order`` Markov chain over the
    vocab — learnable structure for LM smoke training (loss must drop)."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each context maps to a few likely tokens
    ctx_hash_w = rng.integers(1, vocab, size=order)
    likely = rng.integers(0, vocab, size=(vocab, 4))
    seqs = np.zeros((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=(n_seqs, order))
    for t in range(seq_len):
        ctx = (state * ctx_hash_w).sum(-1) % vocab
        choice = likely[ctx, rng.integers(0, 4, size=n_seqs)]
        noise = rng.integers(0, vocab, size=n_seqs)
        take_noise = rng.random(n_seqs) < 0.1
        tok = np.where(take_noise, noise, choice)
        seqs[:, t] = tok
        state = np.concatenate([state[:, 1:], tok[:, None]], axis=1)
    return seqs


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0,
            epochs: int = 1) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = perm[i:i + batch_size]
            yield x[idx], y[idx]
