"""Spike-to-spike validation (paper Sec. IV, Simulation & Validation Phase).

The generated hardware is *functionally* validated by checking that its
output spike train equals the trained model's reference spikes at every time
step.  Two implementations of the same fixed-point datapath are compared:

* ``HardwareModel`` — faithful to the accelerator's dataflow: the ECU
  compresses each incoming train into an ascending address list (PENC
  order), each NU serially walks its assigned neurons per address and
  accumulates the int weight, then the activation phase applies the
  fixed-point LIF update (leak multiply is an integer multiply + arithmetic
  right shift, as in the RTL).
* ``reference_apply`` — the same arithmetic vectorised (integer matmul).

Because the datapath is integer, accumulation order cannot change results —
which is exactly why the hardware may process spikes in any order.  The
validation therefore demands **exact** equality, not allclose.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FRAC_BITS = 8


@dataclasses.dataclass
class FixedPointNet:
    """Quantized net: weights[l] int32 ((fan_in, n) dense / (kh, kw, cin, n)
    HWIO conv), biases[l]: (n,) int32.

    ``specs`` describes the layer sequence when the net is not a plain MLP:
    a list of ``("dense",)``, ``("conv", stride, padding)`` and
    ``("pool", window)`` tuples aligned with the model's layer list (dense
    and conv entries consume ``weights`` in order; pool entries carry no
    parameters).  ``None`` means all-dense — the original MLP contract.
    """
    weights: list[np.ndarray]
    biases: list[np.ndarray]
    beta_fp: int                 # round(beta * 2^frac)
    theta_fp: int                # round(threshold * 2^frac) in accumulator scale
    frac_bits: int = FRAC_BITS
    specs: list | None = None


def layer_specs(layers) -> list[tuple]:
    """Duck-typed ``FixedPointNet.specs`` from ``snn`` layer objects.

    Attribute-based so this module stays numpy-pure (no jax import):
    ``window`` ⇒ MaxPool, ``kernel`` ⇒ Conv, otherwise Dense.
    """
    specs: list[tuple] = []
    for layer in layers:
        if hasattr(layer, "window"):
            specs.append(("pool", int(layer.window)))
        elif hasattr(layer, "kernel"):
            specs.append(("conv", int(layer.stride), str(layer.padding)))
        else:
            specs.append(("dense",))
    return specs


def quantize(weights: list[np.ndarray], biases: list[np.ndarray],
             beta: float, threshold: float,
             frac_bits: int = FRAC_BITS,
             specs: list | None = None) -> FixedPointNet:
    # rounding contract (DESIGN.md §13): every weight/bias is round-to-
    # nearest on the 2^-frac_bits grid into int32; accumulation is exact
    # int64, so conv and dense layers share one arithmetic and results are
    # independent of spike/patch order.
    scale = 1 << frac_bits
    return FixedPointNet(
        weights=[np.round(np.asarray(w) * scale).astype(np.int32) for w in weights],
        biases=[np.round(np.asarray(b) * scale).astype(np.int32) for b in biases],
        beta_fp=int(round(beta * scale)),
        theta_fp=int(round(threshold * scale)),
        frac_bits=frac_bits,
        specs=specs,
    )


def _leak(u: np.ndarray, beta_fp: int, frac_bits: int) -> np.ndarray:
    # int multiply + arithmetic right shift == the RTL's leak datapath
    return (u.astype(np.int64) * beta_fp) >> frac_bits


def _is_mlp(net: FixedPointNet) -> bool:
    return net.specs is None or all(s[0] == "dense" for s in net.specs)


def _conv_out_size(size: int, kernel: int, stride: int,
                   padding: str) -> tuple[int, int, int]:
    """(out, pad_lo, pad_hi) for one spatial dim — XLA's SAME/VALID
    convention (numpy-pure twin of ``kernels.spike_conv.conv_out_size``)."""
    if padding == "SAME":
        out = -(-size // stride)
        pad = max((out - 1) * stride + kernel - size, 0)
        return out, pad // 2, pad - pad // 2
    if padding == "VALID":
        return (size - kernel) // stride + 1, 0, 0
    raise ValueError(f"unknown padding {padding!r}")


def _conv_int(x: np.ndarray, w: np.ndarray, stride: int,
              padding: str) -> np.ndarray:
    """Exact integer NHWC x HWIO convolution: (B,H,W,C) {0,1} spikes against
    int32 weights, accumulated in int64 (order-independent, like the dense
    datapath's integer matmul)."""
    B, H, W, _ = x.shape
    kh, kw, _, cout = w.shape
    oh, ph_lo, ph_hi = _conv_out_size(H, kh, stride, padding)
    ow, pw_lo, pw_hi = _conv_out_size(W, kw, stride, padding)
    xp = np.pad(x, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    acc = np.zeros((B, oh, ow, cout), np.int64)
    w64 = w.astype(np.int64)
    for dy in range(kh):
        for dx in range(kw):
            sl = xp[:, dy:dy + (oh - 1) * stride + 1:stride,
                    dx:dx + (ow - 1) * stride + 1:stride, :]
            acc += sl @ w64[dy, dx]
    return acc


def _or_pool_int(x: np.ndarray, window: int) -> np.ndarray:
    """Spike OR-pooling on a {0,1} (B,H,W,C) tensor, non-overlapping windows,
    VALID truncation of ragged edges (matches ``snn._or_pool``)."""
    B, H, W, C = x.shape
    oh, ow = H // window, W // window
    x = x[:, :oh * window, :ow * window, :]
    return x.reshape(B, oh, window, ow, window, C).max(axis=(2, 4))


def penc_compress(spike_bits: np.ndarray, chunk: int = 100) -> list[int]:
    """Chunked priority-encoder compression: ascending addresses within each
    chunk, chunks scanned in order — the ECU's shift-register content."""
    addrs = []
    n = len(spike_bits)
    for start in range(0, n, chunk):
        for off in np.nonzero(spike_bits[start:start + chunk])[0]:
            addrs.append(start + int(off))
    return addrs


class HardwareModel:
    """Serial functional model of the accelerator datapath (single sample)."""

    def __init__(self, net: FixedPointNet, lhr: list[int] | None = None):
        if not _is_mlp(net):
            raise ValueError("HardwareModel models the fc datapath only; "
                             "use reference_apply_batch for conv nets")
        self.net = net
        self.lhr = lhr or [1] * len(net.weights)

    def run(self, spike_input: np.ndarray) -> np.ndarray:
        """spike_input: (T, fan_in) {0,1}.  Returns (T, n_out) spikes."""
        net = self.net
        T = spike_input.shape[0]
        u = [np.zeros(w.shape[1], np.int64) for w in net.weights]
        s = [np.zeros(w.shape[1], np.int64) for w in net.weights]
        out = np.zeros((T, net.weights[-1].shape[1]), np.int64)
        for t in range(T):
            x = spike_input[t].astype(np.int64)
            for l, (w, b) in enumerate(zip(net.weights, net.biases)):
                addrs = penc_compress(x)
                n_neurons = w.shape[1]
                acc = np.zeros(n_neurons, np.int64)
                # NUs partitioned by base address; each walks its neurons
                # serially per spike address (paper Sec. V-C)
                lhr = self.lhr[l]
                for base in range(0, n_neurons, lhr):
                    hi = min(base + lhr, n_neurons)
                    for a in addrs:
                        for n_i in range(base, hi):
                            acc[n_i] += w[a, n_i]
                # activation phase: leak + accumulate + bias, threshold, reset
                u[l] = (_leak(u[l], net.beta_fp, net.frac_bits)
                        + acc + b - net.theta_fp * s[l])
                s[l] = (u[l] >= net.theta_fp).astype(np.int64)
                x = s[l]
            out[t] = s[-1]
        return out


def reference_apply(net: FixedPointNet, spike_input: np.ndarray) -> np.ndarray:
    """Vectorised fixed-point reference (integer matmul), same arithmetic.

    Single-sample, fc-only (the HardwareModel's comparison twin); conv nets
    go through ``reference_apply_batch``.
    """
    if not _is_mlp(net):
        raise ValueError("reference_apply is fc-only; use "
                         "reference_apply_batch for conv nets")
    T = spike_input.shape[0]
    u = [np.zeros(w.shape[1], np.int64) for w in net.weights]
    s = [np.zeros(w.shape[1], np.int64) for w in net.weights]
    out = np.zeros((T, net.weights[-1].shape[1]), np.int64)
    for t in range(T):
        x = spike_input[t].astype(np.int64)
        for l, (w, b) in enumerate(zip(net.weights, net.biases)):
            acc = x @ w.astype(np.int64)
            u[l] = (_leak(u[l], net.beta_fp, net.frac_bits)
                    + acc + b - net.theta_fp * s[l])
            s[l] = (u[l] >= net.theta_fp).astype(np.int64)
            x = s[l]
        out[t] = s[-1]
    return out


def validate(net: FixedPointNet, spike_input: np.ndarray,
             lhr: list[int] | None = None) -> bool:
    """Exact spike-to-spike equality between hardware model and reference."""
    hw = HardwareModel(net, lhr).run(spike_input)
    ref = reference_apply(net, spike_input)
    return bool(np.array_equal(hw, ref))


def population_predict(spike_out: np.ndarray, num_classes: int) -> np.ndarray:
    """(T, B, num_classes*pcr) output spikes -> (B,) predicted classes.

    Class-major population pooling, the layout the hardware generator
    assumes (neuron ``i`` belongs to class ``i // pcr``) — the NumPy twin of
    ``encoding.population_decode``.
    """
    totals = spike_out.sum(axis=0)                       # (B, n_out)
    b, n = totals.shape
    assert n % num_classes == 0, (n, num_classes)
    return totals.reshape(b, num_classes, n // num_classes).sum(-1).argmax(-1)


def quantized_accuracy(weights: list[np.ndarray], biases: list[np.ndarray],
                       spike_input: np.ndarray, labels: np.ndarray,
                       num_classes: int, *, frac_bits: int,
                       beta: float = 0.95, threshold: float = 1.0,
                       specs: list | None = None) -> float:
    """Classification accuracy of the fixed-point datapath at a given weight
    precision — the accuracy leg of the ``weight_bits`` DSE axis (the BRAM
    leg is ``dse.sweep_weight_bits`` / the ``bram`` objective).

    ``spike_input``: (T, B, fan_in) {0,1} for MLPs, (T, B, H, W, C) for conv
    nets (pass ``specs``, e.g. from ``layer_specs``); ``labels``: (B,).
    """
    net = quantize(weights, biases, beta, threshold, frac_bits=frac_bits,
                   specs=specs)
    pred = population_predict(reference_apply_batch(net, spike_input),
                              num_classes)
    return float((pred == np.asarray(labels)).mean())


def reference_apply_batch(net: FixedPointNet,
                          spike_input: np.ndarray) -> np.ndarray:
    """Vectorised fixed-point forward over a batch.

    spike_input: (T, B, fan_in) for MLPs, (T, B, H, W, C) for conv nets
    (per ``net.specs``) -> output spikes (T, B, n_out).  Used for
    quantization-accuracy studies (weight_bits DSE).  All layer kinds share
    the same integer LIF arithmetic; membrane/spike state is allocated
    lazily from each layer's first accumulate so spatial shapes flow
    through conv and pool stages.  Conv nets must end in a dense classifier
    (the topologies ``workloads.build`` emits always do).
    """
    specs = net.specs or [("dense",)] * len(net.weights)
    T, B = spike_input.shape[:2]
    n_lif = sum(1 for sp in specs if sp[0] != "pool")
    u: list = [None] * n_lif
    s: list = [None] * n_lif
    out = np.zeros((T, B, net.weights[-1].shape[1]), np.int64)
    for t in range(T):
        x = spike_input[t].astype(np.int64)
        li = 0
        for sp in specs:
            if sp[0] == "pool":
                x = _or_pool_int(x, sp[1])
                continue
            w, b = net.weights[li], net.biases[li]
            if sp[0] == "conv":
                acc = _conv_int(x, w, sp[1], sp[2])
                bias = b.astype(np.int64).reshape(1, 1, 1, -1)
            else:
                acc = x.reshape(B, -1) @ w.astype(np.int64)
                bias = b.astype(np.int64)[None]
            if u[li] is None:
                u[li] = np.zeros(acc.shape, np.int64)
                s[li] = np.zeros(acc.shape, np.int64)
            u[li] = (_leak(u[li], net.beta_fp, net.frac_bits)
                     + acc + bias - net.theta_fp * s[li])
            s[li] = (u[li] >= net.theta_fp).astype(np.int64)
            x = s[li]
            li += 1
        out[t] = x.reshape(B, -1)
    return out
