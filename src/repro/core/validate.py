"""Spike-to-spike validation (paper Sec. IV, Simulation & Validation Phase).

The generated hardware is *functionally* validated by checking that its
output spike train equals the trained model's reference spikes at every time
step.  Two implementations of the same fixed-point datapath are compared:

* ``HardwareModel`` — faithful to the accelerator's dataflow: the ECU
  compresses each incoming train into an ascending address list (PENC
  order), each NU serially walks its assigned neurons per address and
  accumulates the int weight, then the activation phase applies the
  fixed-point LIF update (leak multiply is an integer multiply + arithmetic
  right shift, as in the RTL).
* ``reference_apply`` — the same arithmetic vectorised (integer matmul).

Because the datapath is integer, accumulation order cannot change results —
which is exactly why the hardware may process spikes in any order.  The
validation therefore demands **exact** equality, not allclose.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FRAC_BITS = 8


@dataclasses.dataclass
class FixedPointNet:
    """Quantized MLP: weights[l]: (fan_in, n) int32, biases[l]: (n,) int32."""
    weights: list[np.ndarray]
    biases: list[np.ndarray]
    beta_fp: int                 # round(beta * 2^frac)
    theta_fp: int                # round(threshold * 2^frac) in accumulator scale
    frac_bits: int = FRAC_BITS


def quantize(weights: list[np.ndarray], biases: list[np.ndarray],
             beta: float, threshold: float,
             frac_bits: int = FRAC_BITS) -> FixedPointNet:
    scale = 1 << frac_bits
    return FixedPointNet(
        weights=[np.round(np.asarray(w) * scale).astype(np.int32) for w in weights],
        biases=[np.round(np.asarray(b) * scale).astype(np.int32) for b in biases],
        beta_fp=int(round(beta * scale)),
        theta_fp=int(round(threshold * scale)),
        frac_bits=frac_bits,
    )


def _leak(u: np.ndarray, beta_fp: int, frac_bits: int) -> np.ndarray:
    # int multiply + arithmetic right shift == the RTL's leak datapath
    return (u.astype(np.int64) * beta_fp) >> frac_bits


def penc_compress(spike_bits: np.ndarray, chunk: int = 100) -> list[int]:
    """Chunked priority-encoder compression: ascending addresses within each
    chunk, chunks scanned in order — the ECU's shift-register content."""
    addrs = []
    n = len(spike_bits)
    for start in range(0, n, chunk):
        for off in np.nonzero(spike_bits[start:start + chunk])[0]:
            addrs.append(start + int(off))
    return addrs


class HardwareModel:
    """Serial functional model of the accelerator datapath (single sample)."""

    def __init__(self, net: FixedPointNet, lhr: list[int] | None = None):
        self.net = net
        self.lhr = lhr or [1] * len(net.weights)

    def run(self, spike_input: np.ndarray) -> np.ndarray:
        """spike_input: (T, fan_in) {0,1}.  Returns (T, n_out) spikes."""
        net = self.net
        T = spike_input.shape[0]
        u = [np.zeros(w.shape[1], np.int64) for w in net.weights]
        s = [np.zeros(w.shape[1], np.int64) for w in net.weights]
        out = np.zeros((T, net.weights[-1].shape[1]), np.int64)
        for t in range(T):
            x = spike_input[t].astype(np.int64)
            for l, (w, b) in enumerate(zip(net.weights, net.biases)):
                addrs = penc_compress(x)
                n_neurons = w.shape[1]
                acc = np.zeros(n_neurons, np.int64)
                # NUs partitioned by base address; each walks its neurons
                # serially per spike address (paper Sec. V-C)
                lhr = self.lhr[l]
                for base in range(0, n_neurons, lhr):
                    hi = min(base + lhr, n_neurons)
                    for a in addrs:
                        for n_i in range(base, hi):
                            acc[n_i] += w[a, n_i]
                # activation phase: leak + accumulate + bias, threshold, reset
                u[l] = (_leak(u[l], net.beta_fp, net.frac_bits)
                        + acc + b - net.theta_fp * s[l])
                s[l] = (u[l] >= net.theta_fp).astype(np.int64)
                x = s[l]
            out[t] = s[-1]
        return out


def reference_apply(net: FixedPointNet, spike_input: np.ndarray) -> np.ndarray:
    """Vectorised fixed-point reference (integer matmul), same arithmetic."""
    T = spike_input.shape[0]
    u = [np.zeros(w.shape[1], np.int64) for w in net.weights]
    s = [np.zeros(w.shape[1], np.int64) for w in net.weights]
    out = np.zeros((T, net.weights[-1].shape[1]), np.int64)
    for t in range(T):
        x = spike_input[t].astype(np.int64)
        for l, (w, b) in enumerate(zip(net.weights, net.biases)):
            acc = x @ w.astype(np.int64)
            u[l] = (_leak(u[l], net.beta_fp, net.frac_bits)
                    + acc + b - net.theta_fp * s[l])
            s[l] = (u[l] >= net.theta_fp).astype(np.int64)
            x = s[l]
        out[t] = s[-1]
    return out


def validate(net: FixedPointNet, spike_input: np.ndarray,
             lhr: list[int] | None = None) -> bool:
    """Exact spike-to-spike equality between hardware model and reference."""
    hw = HardwareModel(net, lhr).run(spike_input)
    ref = reference_apply(net, spike_input)
    return bool(np.array_equal(hw, ref))


def population_predict(spike_out: np.ndarray, num_classes: int) -> np.ndarray:
    """(T, B, num_classes*pcr) output spikes -> (B,) predicted classes.

    Class-major population pooling, the layout the hardware generator
    assumes (neuron ``i`` belongs to class ``i // pcr``) — the NumPy twin of
    ``encoding.population_decode``.
    """
    totals = spike_out.sum(axis=0)                       # (B, n_out)
    b, n = totals.shape
    assert n % num_classes == 0, (n, num_classes)
    return totals.reshape(b, num_classes, n // num_classes).sum(-1).argmax(-1)


def quantized_accuracy(weights: list[np.ndarray], biases: list[np.ndarray],
                       spike_input: np.ndarray, labels: np.ndarray,
                       num_classes: int, *, frac_bits: int,
                       beta: float = 0.95, threshold: float = 1.0) -> float:
    """Classification accuracy of the fixed-point datapath at a given weight
    precision — the accuracy leg of the ``weight_bits`` DSE axis (the BRAM
    leg is ``dse.sweep_weight_bits`` / the ``bram`` objective).

    ``spike_input``: (T, B, fan_in) {0,1}; ``labels``: (B,).
    """
    net = quantize(weights, biases, beta, threshold, frac_bits=frac_bits)
    pred = population_predict(reference_apply_batch(net, spike_input),
                              num_classes)
    return float((pred == np.asarray(labels)).mean())


def reference_apply_batch(net: FixedPointNet,
                          spike_input: np.ndarray) -> np.ndarray:
    """Vectorised fixed-point forward over a batch.

    spike_input: (T, B, fan_in) -> output spikes (T, B, n_out).  Used for
    quantization-accuracy studies (weight_bits DSE)."""
    T, B = spike_input.shape[:2]
    u = [np.zeros((B, w.shape[1]), np.int64) for w in net.weights]
    s = [np.zeros((B, w.shape[1]), np.int64) for w in net.weights]
    out = np.zeros((T, B, net.weights[-1].shape[1]), np.int64)
    for t in range(T):
        x = spike_input[t].astype(np.int64)
        for l, (w, b) in enumerate(zip(net.weights, net.biases)):
            acc = x @ w.astype(np.int64)
            u[l] = (_leak(u[l], net.beta_fp, net.frac_bits)
                    + acc + b[None] - net.theta_fp * s[l])
            s[l] = (u[l] >= net.theta_fp).astype(np.int64)
            x = s[l]
        out[t] = s[-1]
    return out
