"""Spike encodings and population decoding (paper Secs. II-A, VI-C).

* **Rate coding** — pixel intensity -> Bernoulli spike probability per time
  step (the paper's "standard rate coding").
* **Population coding** — the classification layer holds ``PCR`` neurons per
  class (paper: "population coding ratio"); the predicted class is the
  argmax of summed spike counts pooled per class.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rate_encode(key: jax.Array, x: jax.Array, num_steps: int) -> jax.Array:
    """Bernoulli rate code.  ``x`` in [0,1], shape (B, ...) ->
    spikes (T, B, ...) in {0,1}."""
    probs = jnp.broadcast_to(x, (num_steps,) + x.shape)
    return jax.random.bernoulli(key, probs).astype(jnp.float32)


def constant_current_encode(x: jax.Array, num_steps: int) -> jax.Array:
    """Direct (constant-current) encoding: the analog input is applied as the
    synaptic current at every step.  Used for ablations."""
    return jnp.broadcast_to(x, (num_steps,) + x.shape)


def ttfs_encode(x: jax.Array, num_steps: int) -> jax.Array:
    """Time-to-first-spike coding (paper Sec. II-A): brighter pixels spike
    earlier; each neuron spikes at most once.  x in [0,1] -> (T, B, ...)
    with a single spike at step floor((1-x)*(T-1)); x == 0 never spikes."""
    t_spike = jnp.floor((1.0 - x) * (num_steps - 1)).astype(jnp.int32)
    steps = jnp.arange(num_steps, dtype=jnp.int32).reshape(
        (num_steps,) + (1,) * x.ndim)
    spikes = (steps == t_spike[None]).astype(jnp.float32)
    return spikes * (x[None] > 0)


def burst_encode(key: jax.Array, x: jax.Array, num_steps: int,
                 max_burst: int = 4) -> jax.Array:
    """Burst coding (paper Sec. II-A): intensity maps to the number of
    consecutive leading spikes (a burst of up to ``max_burst``)."""
    n_spikes = jnp.round(x * max_burst).astype(jnp.int32)
    steps = jnp.arange(num_steps, dtype=jnp.int32).reshape(
        (num_steps,) + (1,) * x.ndim)
    return (steps < n_spikes[None]).astype(jnp.float32)


def population_pool(spike_counts: jax.Array, num_classes: int) -> jax.Array:
    """Pool output-layer spike counts (..., num_classes*pcr) -> (..., num_classes).

    Neurons are laid out class-major: neuron ``i`` belongs to class
    ``i // pcr`` — the layout the hardware generator assumes when sizing the
    output layer's NUs.
    """
    *lead, n = spike_counts.shape
    assert n % num_classes == 0, (n, num_classes)
    pcr = n // num_classes
    pooled = spike_counts.reshape(*lead, num_classes, pcr).sum(-1)
    return pooled


def population_decode(spike_train: jax.Array, num_classes: int) -> jax.Array:
    """(T, B, num_classes*pcr) spike train -> (B,) predicted class."""
    counts = spike_train.sum(0)
    return jnp.argmax(population_pool(counts, num_classes), axis=-1)


def rate_loss(spike_train: jax.Array, labels: jax.Array, num_classes: int) -> jax.Array:
    """Cross-entropy on population-pooled spike-rate logits.

    Matches snntorch's rate-coded CE: the summed spike count per class pool
    acts as the logit.
    """
    counts = spike_train.sum(0)                       # (B, n_out)
    logits = population_pool(counts, num_classes)     # (B, C)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
