"""Design Space Exploration engine (paper Sec. IV).

Enumerates per-layer LHR vectors (powers of two, the paper's sweep style),
evaluates latency via the cycle-accurate model and area via the component
library *vectorised over all candidates at once*, and extracts the Pareto
frontier over (LUT, cycles).  ``auto_select`` reproduces the paper's
"best mapping" picks: the smallest design within a latency budget, or the
fastest within an area budget.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.accelerator.arch import AcceleratorConfig
from repro.core.accelerator import cycle_model, resources


@dataclasses.dataclass(frozen=True)
class Candidate:
    lhr: tuple[int, ...]
    cycles: float
    lut: float
    energy_mj: float
    pareto: bool = False


@dataclasses.dataclass
class DSEResult:
    config: AcceleratorConfig
    candidates: list[Candidate]

    @property
    def frontier(self) -> list[Candidate]:
        return [c for c in self.candidates if c.pareto]

    def best_within_latency(self, max_cycles: float) -> Optional[Candidate]:
        ok = [c for c in self.candidates if c.cycles <= max_cycles]
        return min(ok, key=lambda c: c.lut) if ok else None

    def best_within_area(self, max_lut: float) -> Optional[Candidate]:
        ok = [c for c in self.candidates if c.lut <= max_lut]
        return min(ok, key=lambda c: c.cycles) if ok else None

    def min_energy(self) -> Candidate:
        return min(self.candidates, key=lambda c: c.energy_mj)


def lhr_grid(cfg: AcceleratorConfig, max_lhr: int = 256,
             max_candidates: int = 200_000) -> np.ndarray:
    """All per-layer power-of-two LHR vectors (capped at layer size)."""
    axes = []
    for layer in cfg.layers:
        cap = min(max_lhr, layer.logical)
        vals = [1]
        while vals[-1] * 2 <= cap:
            vals.append(vals[-1] * 2)
        axes.append(vals)
    n = int(np.prod([len(a) for a in axes]))
    if n > max_candidates:
        raise ValueError(f"{n} candidates exceed cap {max_candidates}; "
                         f"restrict max_lhr or sweep layerwise")
    return np.array(list(itertools.product(*axes)), dtype=np.int64)


def pareto_mask(cycles: np.ndarray, lut: np.ndarray) -> np.ndarray:
    """Non-dominated mask for minimizing both objectives."""
    order = np.lexsort((lut, cycles))           # by cycles, then lut
    mask = np.zeros(len(cycles), dtype=bool)
    best_lut = np.inf
    for i in order:
        if lut[i] < best_lut - 1e-9:
            mask[i] = True
            best_lut = lut[i]
    return mask


def sweep(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
          max_lhr: int = 256,
          lhr_matrix: Optional[np.ndarray] = None) -> DSEResult:
    """Evaluate every candidate LHR vector against a spike trace.

    ``counts``: per-layer (T,) traffic (trace or published averages).
    """
    lhr = lhr_matrix if lhr_matrix is not None else lhr_grid(cfg, max_lhr)
    cycles = cycle_model.latency_cycles(cfg, counts, lhr_matrix=lhr)
    lut = resources.estimate_lut_vector(cfg, lhr)
    mask = pareto_mask(cycles, lut)
    cands = []
    for i in range(len(lhr)):
        c = cfg.with_lhr(tuple(int(x) for x in lhr[i]))
        cands.append(Candidate(
            lhr=tuple(int(x) for x in lhr[i]),
            cycles=float(cycles[i]), lut=float(lut[i]),
            energy_mj=resources.energy_mj(c, counts, float(cycles[i])),
            pareto=bool(mask[i])))
    return DSEResult(config=cfg, candidates=cands)


def sweep_spike_train_length(cfg: AcceleratorConfig,
                             counts_per_t: dict[int, Sequence[np.ndarray]],
                             lhr: Sequence[int]) -> dict[int, float]:
    """Latency as a function of spike-train length T (paper Fig. 7b)."""
    out = {}
    c = cfg.with_lhr(lhr)
    for T, counts in counts_per_t.items():
        out[T] = float(cycle_model.latency_cycles(
            dataclasses.replace(c, num_steps=T), counts))
    return out


@dataclasses.dataclass(frozen=True)
class MemBlockCandidate:
    blocks: tuple[int, ...]      # memory blocks per layer
    cycles: float
    lut: float
    bram: int


def sweep_memory_blocks(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                        divisors: Sequence[int] = (1, 2, 4, 8)
                        ) -> list[MemBlockCandidate]:
    """Explore memory blocks per layer (paper Sec. IV: "modifications can be
    made to the hardware configuration (e.g. ... reduce the memory blocks)").

    Fewer blocks than NUs serialize weight reads (``LayerHW.contention``)
    but shrink the BRAM + mapping-logic budget; the sweep exposes the
    latency/area trade at fixed LHR.
    """
    out = []
    for div in divisors:
        layers = tuple(
            dataclasses.replace(l, mem_blocks=max(1, l.num_nus // div))
            for l in cfg.layers)
        c = dataclasses.replace(cfg, layers=layers)
        cycles = float(cycle_model.latency_cycles(c, counts))
        res = resources.estimate(c)
        out.append(MemBlockCandidate(
            blocks=tuple(l.num_mem_blocks for l in layers),
            cycles=cycles, lut=res.lut, bram=res.bram36))
    return out


def sweep_weight_bits(cfg: AcceleratorConfig,
                      bits_options: Sequence[int] = (4, 6, 8, 12, 16)
                      ) -> dict[int, int]:
    """BRAM footprint vs synapse weight precision (paper Sec. III notes
    weight quantization "significantly affects the system's memory
    requirements").  Accuracy impact is measured separately with the
    fixed-point validator (benchmarks/bench_extensions.py)."""
    out = {}
    for bits in bits_options:
        layers = tuple(dataclasses.replace(l, weight_bits=bits)
                       for l in cfg.layers)
        out[bits] = resources.estimate(
            dataclasses.replace(cfg, layers=layers)).bram36
    return out
