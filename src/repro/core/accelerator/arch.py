"""Hardware architecture configuration (paper Sec. V).

One ``LayerHW`` per spiking layer mirrors the paper's generated RTL: an Event
Control Unit (chunked priority encoder + shift-register address array), a
pool of Neural Units (``ceil(logical / lhr)`` of them), and a Memory Unit
(block RAM holding synapse rows).  ``AcceleratorConfig`` aggregates the
layers plus the global timing constants of the component library.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import snn


def per_layer_col(matrix, l: int):
    """Column ``l`` of a (C, L) per-layer candidate matrix, or a (C,)
    global vector applied to every layer — the batched-DSE axis convention
    shared by ``cycle_model`` and ``resources``."""
    if matrix is None:
        return None
    m = np.asarray(matrix)
    return m[:, l] if m.ndim == 2 else m


@dataclasses.dataclass(frozen=True)
class LayerHW:
    kind: str                   # "fc" | "conv"
    logical: int                # logical neurons (fc) / output channels (conv)
    fan_in_size: int            # pre-synaptic spike-train size in bits (post-pool)
    lhr: int                    # logical-to-hardware ratio (paper Sec. VI-B)
    kernel: int = 0             # conv only
    out_positions: int = 0      # conv only: out_h * out_w
    penc_width: int = 100       # PENC chunk width (paper: ~100-bit FPGA limit)
    mem_blocks: int = 0         # 0 => one block per NU (no port contention)
    weight_bits: int = 8

    def __post_init__(self):
        if self.lhr < 1 or self.lhr > self.logical:
            raise ValueError(
                f"lhr={self.lhr} out of range for layer with {self.logical} "
                f"logical units")

    @property
    def num_nus(self) -> int:
        return -(-self.logical // self.lhr)

    @property
    def num_mem_blocks(self) -> int:
        return self.mem_blocks if self.mem_blocks else self.num_nus

    @property
    def contention(self) -> int:
        """Serialization factor when several NUs share one memory block."""
        return -(-self.num_nus // self.num_mem_blocks)

    @property
    def penc_chunks(self) -> int:
        return -(-self.fan_in_size // self.penc_width)

    @property
    def neurons_per_nu(self) -> int:
        if self.kind == "fc":
            return self.lhr
        return self.out_positions * self.lhr

    @property
    def synapses(self) -> int:
        """Total weights this layer stores."""
        if self.kind == "fc":
            return self.fan_in_size * self.logical
        return self.kernel * self.kernel * self.fan_in_channels * self.logical

    @property
    def fan_in_channels(self) -> int:
        if self.kind != "conv":
            return 0
        # fan_in_size = in_h * in_w * in_c and out_positions = out_h * out_w;
        # with stride-1 SAME conv, in_h*in_w == out_positions.
        return max(1, self.fan_in_size // max(self.out_positions, 1))


@dataclasses.dataclass(frozen=True)
class TimingModel:
    """Calibrated component-library timing constants (see calibrate.py).

    * ``acc_cycles_per_op`` — cycles per single weight accumulate (BRAM
      read-modify-write, pipelined; the Table-I fit lands on 1).
    * ``act_cycles``        — cycles per neuron membrane update in the
      activation phase.
    * ``sync_cycles``       — ECU handshake per layer per time step.
    * ``conv_event_driven_act`` — if True the conv activation phase visits
      only *affected* neuron addresses (lazy leak), the only reading of
      Table I under which net-5's LHR sweep is self-consistent; see
      EXPERIMENTS.md §Reproduction.
    """
    acc_cycles_per_op: int = 1
    act_cycles: int = 1
    sync_cycles: int = 4
    conv_event_driven_act: bool = True
    pool_retention: float = 1.0      # OR-pool spike survival fraction
    clock_mhz: float = 100.0


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    layers: tuple[LayerHW, ...]
    timing: TimingModel = TimingModel()
    num_steps: int = 25

    @property
    def lhr(self) -> tuple[int, ...]:
        return tuple(l.lhr for l in self.layers)

    def with_lhr(self, lhr: Sequence[int]) -> "AcceleratorConfig":
        assert len(lhr) == len(self.layers)
        layers = tuple(dataclasses.replace(l, lhr=r)
                       for l, r in zip(self.layers, lhr))
        return dataclasses.replace(self, layers=layers)

    def with_updates(self,
                     lhr: Sequence[int] | None = None,
                     mem_blocks: Sequence[int] | None = None,
                     weight_bits: Sequence[int] | int | None = None,
                     penc_width: Sequence[int] | int | None = None,
                     clock_mhz: float | None = None) -> "AcceleratorConfig":
        """Materialize one DSE candidate row as a concrete config.

        Per-layer arguments take a length-L sequence; ``weight_bits`` and
        ``penc_width`` also accept a single value applied to every layer.
        """
        def expand(v):
            if v is None:
                return None
            if hasattr(v, "__len__"):
                assert len(v) == len(self.layers), (v, len(self.layers))
                return [int(x) for x in v]
            return [int(v)] * len(self.layers)

        per_layer = {"lhr": expand(lhr), "mem_blocks": expand(mem_blocks),
                     "weight_bits": expand(weight_bits),
                     "penc_width": expand(penc_width)}
        layers = []
        for i, l in enumerate(self.layers):
            kw = {k: v[i] for k, v in per_layer.items() if v is not None}
            layers.append(dataclasses.replace(l, **kw) if kw else l)
        timing = (dataclasses.replace(self.timing, clock_mhz=float(clock_mhz))
                  if clock_mhz is not None else self.timing)
        return dataclasses.replace(self, layers=tuple(layers), timing=timing)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def from_layer_sizes(name: str, sizes: Sequence[int],
                     lhr: Optional[Sequence[int]] = None,
                     timing: TimingModel = TimingModel(),
                     num_steps: int = 25, **kw) -> AcceleratorConfig:
    """Fully-connected accelerator from layer sizes (input first).

    ``sizes = (784, 500, 500, 300)`` builds 3 FC layer engines.
    """
    lhr = tuple(lhr) if lhr is not None else (1,) * (len(sizes) - 1)
    assert len(lhr) == len(sizes) - 1
    layers = tuple(
        LayerHW(kind="fc", logical=sizes[i + 1], fan_in_size=sizes[i],
                lhr=lhr[i], **kw)
        for i in range(len(sizes) - 1))
    return AcceleratorConfig(name=name, layers=layers, timing=timing,
                             num_steps=num_steps)


def from_snn_config(cfg: snn.SNNConfig,
                    lhr: Optional[Sequence[int]] = None,
                    timing: TimingModel = TimingModel(),
                    penc_width: int = 100,
                    weight_bits: int = 8) -> AcceleratorConfig:
    """Build the hardware description straight from a trained model's
    topology — the paper's Architecture Generation Phase."""
    import math as _m
    shapes = snn.output_shapes(cfg)
    layer_list = list(cfg.layers)
    hw = []
    in_shape = cfg.input_shape
    for i, spec in enumerate(layer_list):
        if isinstance(spec, snn.Dense):
            hw.append(LayerHW(
                kind="fc", logical=spec.features,
                fan_in_size=int(_m.prod(in_shape)), lhr=1,
                penc_width=penc_width, weight_bits=weight_bits))
            in_shape = shapes[i]
        elif isinstance(spec, snn.Conv):
            out_shape = shapes[i]
            hw.append(LayerHW(
                kind="conv", logical=spec.features,
                fan_in_size=int(_m.prod(in_shape)), lhr=1,
                kernel=spec.kernel,
                out_positions=out_shape[0] * out_shape[1],
                penc_width=penc_width, weight_bits=weight_bits))
            in_shape = out_shape
        elif isinstance(spec, snn.MaxPool):
            in_shape = shapes[i]
        else:
            raise TypeError(spec)
    acc = AcceleratorConfig(name=cfg.name, layers=tuple(hw), timing=timing,
                            num_steps=cfg.num_steps)
    return acc.with_lhr(lhr) if lhr is not None else acc
