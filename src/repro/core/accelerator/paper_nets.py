"""AcceleratorConfig builders for the paper's five benchmark networks
(Table I), plus their published traffic statistics as cycle-model inputs."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.accelerator import paper_data
from repro.core.accelerator.arch import (AcceleratorConfig, LayerHW,
                                         TimingModel, from_layer_sizes)
from repro.core.accelerator.cycle_model import counts_from_averages

# Spike-train lengths: net-5's T=124 is stated in the paper (Sec. VI-B);
# net-1..4 are not disclosed per row and are calibrated (calibrate.py).
DEFAULT_T = {"net-1": 60, "net-2": 73, "net-3": 51, "net-4": 70, "net-5": 124}


def build(net: str, lhr: Sequence[int] | None = None,
          timing: TimingModel = TimingModel(),
          num_steps: int | None = None) -> AcceleratorConfig:
    spec = paper_data.NETS[net]
    T = num_steps or DEFAULT_T[net]
    if not spec.conv:
        cfg = from_layer_sizes(net, spec.layer_sizes, timing=timing, num_steps=T)
    else:
        # net-5: 128x128 - 32C3 - P2 - 32C3 - P2 - 512 - 256 (- 11)
        layers = (
            LayerHW(kind="conv", logical=32, fan_in_size=128 * 128, lhr=1,
                    kernel=3, out_positions=128 * 128),
            LayerHW(kind="conv", logical=32, fan_in_size=64 * 64 * 32, lhr=1,
                    kernel=3, out_positions=64 * 64),
            LayerHW(kind="fc", logical=512, fan_in_size=32 * 32 * 32, lhr=1),
            LayerHW(kind="fc", logical=256, fan_in_size=512, lhr=1),
        )
        cfg = AcceleratorConfig(name=net, layers=layers, timing=timing,
                                num_steps=T)
    if lhr is not None:
        cfg = cfg.with_lhr(lhr)
    return cfg


def pool_before_flags(net: str) -> list[bool]:
    if net == "net-5":
        return [False, True, True, False]
    return [False] * (len(paper_data.NETS[net].layer_sizes) - 1)


def paper_counts(net: str, cfg: AcceleratorConfig) -> list[np.ndarray]:
    """Constant per-step traffic from the Table-I caption averages."""
    spec = paper_data.NETS[net]
    return counts_from_averages(cfg, spec.avg_spikes,
                                num_steps=cfg.num_steps,
                                pool_before=pool_before_flags(net))
