"""Calibrate the component cost library against the paper's Table I.

Run as a module to re-derive the constants baked into ``TimingModel`` /
``CostLibrary`` defaults:

    PYTHONPATH=src python -m repro.core.accelerator.calibrate

Outputs the fitted constants and the per-row relative errors (reported in
EXPERIMENTS.md §Reproduction).  The paper's own TLM-vs-RTL fidelity budget is
~15% (Sec. II-D); rows exceeding it are flagged.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.accelerator import paper_data, paper_nets
from repro.core.accelerator.arch import TimingModel
from repro.core.accelerator.cycle_model import latency_cycles
from repro.core.accelerator.resources import CostLibrary, estimate, accumulate_ops


def fit_timing(verbose: bool = True) -> tuple[TimingModel, dict[str, int], float]:
    """Grid-search global timing constants + per-net spike-train length T."""
    nets = list(paper_data.NETS)
    best = (None, None, np.inf)
    t_grid = {n: ([124] if n == "net-5" else range(15, 80)) for n in nets}
    for cpo, act, ret in itertools.product((1, 2, 3), (1, 2, 4, 6, 8),
                                           (0.7, 0.85, 1.0)):
        timing = TimingModel(acc_cycles_per_op=cpo, act_cycles=act,
                             pool_retention=ret)
        total_loss, t_pick = 0.0, {}
        for net in nets:
            rows = paper_data.tw_rows(net)
            losses = []
            for T in t_grid[net]:
                cfg0 = paper_nets.build(net, timing=timing, num_steps=T)
                counts = paper_nets.paper_counts(net, cfg0)
                loss = 0.0
                for r in rows:
                    pred = float(latency_cycles(cfg0.with_lhr(r.lhr), counts))
                    loss += abs(np.log(pred / r.cycles))
                losses.append((loss / len(rows), T))
            l, T = min(losses)
            total_loss += l
            t_pick[net] = T
        if total_loss < best[2]:
            best = (timing, t_pick, total_loss)
            if verbose:
                print(f"cpo={cpo} act={act} ret={ret} -> "
                      f"loss={total_loss/len(nets):.4f} T={t_pick}")
    timing, t_pick, loss = best
    return timing, t_pick, loss / len(nets)


def timing_residuals(timing: TimingModel, t_pick: dict[str, int]):
    rows_out = []
    for net in paper_data.NETS:
        cfg0 = paper_nets.build(net, timing=timing, num_steps=t_pick[net])
        counts = paper_nets.paper_counts(net, cfg0)
        for r in paper_data.tw_rows(net):
            pred = float(latency_cycles(cfg0.with_lhr(r.lhr), counts))
            rows_out.append((net, r.lhr, r.cycles, pred, pred / r.cycles - 1))
    return rows_out


def _irls(A: np.ndarray, y: np.ndarray, iters: int = 25) -> np.ndarray:
    """Robust (approx-L1) least squares — Table I contains outlier rows."""
    w = np.ones(len(y))
    sol = None
    for _ in range(iters):
        sol, *_ = np.linalg.lstsq(A * w[:, None], y * w, rcond=None)
        resid = np.abs(A @ sol - y) + 1e3
        w = 1.0 / np.sqrt(resid)
    return sol


def fit_resources() -> tuple[CostLibrary, list]:
    """Least-squares LUT/REG component costs over all TW rows.

    Conv NUs carry their own LUT coefficient: a conv Neural Unit holds the
    2D address-extraction datapath (paper Fig. 5) + per-position membrane
    access machinery, far costlier than the FC LIF ALU.
    """
    feats_lut, y_lut, feats_reg, y_reg, tags = [], [], [], [], []
    for net in paper_data.NETS:
        for r in paper_data.tw_rows(net):
            if r.lut is None:
                continue
            cfg = paper_nets.build(net, lhr=r.lhr)
            fc_nus = sum(l.num_nus for l in cfg.layers if l.kind == "fc")
            cv_nus = sum(l.num_nus for l in cfg.layers if l.kind == "conv")
            fan = sum(l.fan_in_size for l in cfg.layers)
            L = len(cfg.layers)
            feats_lut.append([fc_nus, cv_nus, L])
            y_lut.append(r.lut * 1e3)
            feats_reg.append([fc_nus, cv_nus, fan, L])
            y_reg.append(r.reg * 1e3)
            tags.append((net, r.lhr))
    lut_nu, lut_conv_nu, lut_layer = _irls(np.array(feats_lut, float),
                                           np.array(y_lut))
    reg_nu, reg_conv_nu, reg_addr, reg_layer = _irls(np.array(feats_reg, float),
                                                     np.array(y_reg))

    # split the per-NU LUT between NU datapath and memory mapping logic
    # (85/15 — the split is not observable from aggregate numbers) and the
    # per-layer LUT between the 100-bit PENC and the FSM/wrapper.
    lib = CostLibrary(
        lut_per_nu=round(0.85 * lut_nu, 1),
        lut_per_conv_nu=round(max(lut_conv_nu, 0.0), 1),
        lut_per_mem_block=round(0.15 * lut_nu, 1),
        lut_per_penc_bit=max(round((lut_layer * 0.45) / 100, 2), 0.0),
        lut_fixed_per_layer=round(lut_layer * 0.55, 1),
        reg_per_nu=round(reg_nu, 1),
        reg_per_conv_nu=round(max(reg_conv_nu, 0.0), 1),
        reg_per_addr_bit=round(reg_addr, 3),
        reg_fixed_per_layer=round(max(reg_layer, 0.0), 1),
    )
    resid_rows = []
    for (net, lhr), l_true, r_true in zip(tags, y_lut, y_reg):
        cfg = paper_nets.build(net, lhr=lhr)
        est = estimate(cfg, lib)
        resid_rows.append((net, lhr, l_true, est.lut, est.lut / l_true - 1,
                           r_true, est.reg, est.reg / r_true - 1))
    return lib, resid_rows


def fit_energy(lib: CostLibrary, timing: TimingModel,
               t_pick: dict[str, int]) -> CostLibrary:
    """Fit E = (a + b*LUT) * cycles/f + e_op * acc_ops  (non-negative LS)."""
    A, y = [], []
    for net in paper_data.NETS:
        cfg0 = paper_nets.build(net, timing=timing, num_steps=t_pick[net])
        counts = paper_nets.paper_counts(net, cfg0)
        for r in paper_data.tw_rows(net):
            if r.energy_mj is None:
                continue
            cfg = cfg0.with_lhr(r.lhr)
            runtime = r.cycles / (timing.clock_mhz * 1e6)   # use measured cycles
            lut = estimate(cfg, lib).lut
            ops = accumulate_ops(cfg, counts)
            A.append([runtime, lut * runtime, ops * 1e-12])
            y.append(r.energy_mj * 1e-3)
    A, y = np.array(A), np.array(y)
    # RELATIVE least squares (divide rows by y): Table I energies span
    # 0.09..20.5 mJ — absolute LS would fit only the DVS rows
    A = A / y[:, None]
    y = np.ones_like(y)
    # exact NNLS by active-set enumeration (3 vars -> 8 subsets)
    best_x, best_err = np.zeros(3), np.inf
    for mask in range(1, 8):
        idx = [i for i in range(3) if mask >> i & 1]
        sol, *_ = np.linalg.lstsq(A[:, idx], y, rcond=None)
        if (sol < 0).any():
            continue
        x = np.zeros(3)
        x[idx] = sol
        err = float(np.sum((A @ x - y) ** 2))
        if err < best_err:
            best_x, best_err = x, err
    a, b, e = best_x
    return dataclasses.replace(lib, static_w=round(float(a), 3),
                               w_per_lut=float(b), pj_per_acc_op=round(float(e), 1))


def main():
    print("== timing fit ==")
    timing, t_pick, loss = fit_timing()
    print(f"\nbest: {timing}  T={t_pick}  mean|log-err|={loss:.4f}\n")
    for net, lhr, actual, pred, err in timing_residuals(timing, t_pick):
        flag = "  <-- >15%" if abs(err) > 0.15 else ""
        print(f"{net} {str(lhr):>22}  actual={actual:>9.0f} pred={pred:>9.0f} "
              f"err={err:+.1%}{flag}")
    print("\n== resource fit ==")
    lib, rows = fit_resources()
    print(lib)
    for net, lhr, lt, lp, le, rt, rp, re in rows:
        print(f"{net} {str(lhr):>22}  LUT {lt/1e3:>6.1f}K->{lp/1e3:>6.1f}K "
              f"({le:+.0%})   REG {rt/1e3:>6.1f}K->{rp/1e3:>6.1f}K ({re:+.0%})")
    print("\n== energy fit ==")
    lib2 = fit_energy(lib, timing, t_pick)
    print(f"static_w={lib2.static_w} w_per_lut={lib2.w_per_lut:.3e} "
          f"pj_per_acc_op={lib2.pj_per_acc_op}")


if __name__ == "__main__":
    main()
