"""Cycle-accurate latency model (the paper's TLM simulator, Sec. V).

Driven by per-layer per-time-step spike counts — either the trace of a
trained model (``repro.core.snn.spike_counts_per_layer``) or the paper's
published averages — and an ``AcceleratorConfig``.

Per layer and time step the engine passes through the ECU state machine's
three phases (paper Fig. 4):

  PENC compress:  cycles = spikes + ceil(fan_in / penc_width)
                  (one address emitted per cycle + one cycle to scan each
                  chunk, empty chunks skipped in a single cycle)
  Accumulate:     fc:   spikes * lhr * acc_cpo * contention
                  conv: spikes * k^2 * lhr * acc_cpo * contention
                  (each NU serially walks its logical neurons per spike
                  address; a BRAM read-modify-write costs ``acc_cpo`` cycles;
                  NUs sharing a memory block serialize)
  Activate:       fc:   lhr * act_cycles                  (all owned neurons)
                  conv: min(spikes * k^2, out_positions) * lhr * act_cycles
                  (event-driven activation over affected addresses with lazy
                  leak — see TimingModel.conv_event_driven_act)

Layer-wise pipelining (paper Sec. V-B: "the ECU loads the spike train into a
buffer and moves on") is the exact dataflow recurrence

    finish[l][t] = max(finish[l-1][t], finish[l][t-1]) + lat[l][t]

whose final entry is the per-inference latency.  Everything is vectorised
over arbitrary trailing axes, so a full DSE sweep (thousands of LHR vectors)
or a batch of sample traces evaluates in one shot.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.accelerator.arch import (AcceleratorConfig, LayerHW,
                                         per_layer_col)


def layer_latency(layer: LayerHW, spikes: np.ndarray, t: "TimingModel",
                  lhr: np.ndarray | int | None = None,
                  contention: np.ndarray | int | None = None,
                  penc_chunks: np.ndarray | int | None = None) -> np.ndarray:
    """Latency (cycles) of one layer engine for one time step.

    ``spikes``: incoming spike count(s) — any shape, broadcastable.
    ``lhr``/``contention``/``penc_chunks``: overrides for vectorised DSE
    sweeps (scalars or (C,) candidate vectors; default to the layer's own
    derived values).  ``latency_cycles`` computes consistent overrides from
    per-candidate lhr/mem_blocks/penc_width matrices.
    """
    lhr = layer.lhr if lhr is None else lhr
    contention = layer.contention if contention is None else contention
    penc_chunks = layer.penc_chunks if penc_chunks is None else penc_chunks
    spikes = np.asarray(spikes, dtype=np.float64)
    penc = spikes + penc_chunks
    if layer.kind == "fc":
        acc = spikes * lhr * t.acc_cycles_per_op * contention
        act = lhr * np.float64(t.act_cycles)
    else:
        fan_out = layer.kernel * layer.kernel
        acc = spikes * fan_out * lhr * t.acc_cycles_per_op * contention
        if t.conv_event_driven_act:
            affected = np.minimum(spikes * fan_out, layer.out_positions)
        else:
            affected = np.float64(layer.out_positions)
        act = affected * lhr * t.act_cycles
    return penc + acc + act + t.sync_cycles


def pipeline_latency(lat: np.ndarray) -> np.ndarray:
    """Exact layer-pipeline recurrence.

    ``lat``: (L, T, ...) per-layer per-step latencies.
    Returns finish time of the last layer's last step, shape ``lat.shape[2:]``.
    """
    L, T = lat.shape[:2]
    finish_prev_layer = np.zeros(lat.shape[1:], dtype=np.float64)  # (T, ...)
    for l in range(L):
        finish = np.empty_like(finish_prev_layer)
        busy = np.zeros(lat.shape[2:], dtype=np.float64)
        for t in range(T):
            start = np.maximum(finish_prev_layer[t], busy)
            busy = start + lat[l, t]
            finish[t] = busy
        finish_prev_layer = finish
    return finish_prev_layer[T - 1]


def _ceil_div(a, b):
    return -(-a // b)


def latency_cycles(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                   lhr_matrix: np.ndarray | None = None,
                   mem_blocks_matrix: np.ndarray | None = None,
                   penc_width: np.ndarray | None = None) -> np.ndarray:
    """Per-inference latency.

    ``counts``: per-layer incoming spike counts, each (T,) or (T, ...) —
    entry ``l`` is the traffic entering layer ``l``.
    ``lhr_matrix``: optional (C, L) int array — evaluates C candidate LHR
    vectors at once (vectorised DSE); result shape (..., C) or (C,).
    ``mem_blocks_matrix``: optional (C, L) int array of memory blocks per
    layer (0 = one block per NU).  Port contention is recomputed per
    candidate from the *candidate's* NU count, so joint LHR x mem_blocks
    sweeps stay consistent with the scalar ``with_lhr`` path.
    ``penc_width``: optional (C,) or (C, L) PENC chunk widths.
    """
    L = len(cfg.layers)
    assert len(counts) == L, (len(counts), L)
    batched = any(x is not None
                  for x in (lhr_matrix, mem_blocks_matrix, penc_width))
    lat = []
    for l, layer in enumerate(cfg.layers):
        c = np.asarray(counts[l], dtype=np.float64)
        if not batched:
            lat.append(layer_latency(layer, c, cfg.timing))
            continue
        c = c.reshape(c.shape + (1,))                    # (T, ..., 1)
        lhr_l = per_layer_col(lhr_matrix, l)            # (C,) or None
        mem_l = per_layer_col(mem_blocks_matrix, l)
        pw_l = per_layer_col(penc_width, l)
        contention = None
        if lhr_l is not None or mem_l is not None:
            lhr_v = np.asarray(layer.lhr if lhr_l is None else lhr_l,
                               dtype=np.int64)
            mem_v = np.asarray(layer.mem_blocks if mem_l is None else mem_l,
                               dtype=np.int64)
            nus = _ceil_div(layer.logical, lhr_v)
            contention = _ceil_div(nus, np.where(mem_v > 0, mem_v, nus))
        pchunks = (None if pw_l is None
                   else _ceil_div(layer.fan_in_size,
                                  np.asarray(pw_l, dtype=np.int64)))
        lat.append(layer_latency(layer, c, cfg.timing, lhr=lhr_l,
                                 contention=contention, penc_chunks=pchunks))
    if batched:
        shape = np.broadcast_shapes(*[x.shape for x in lat])
        lat = [np.broadcast_to(x, shape) for x in lat]
    lat = np.stack(lat, axis=0)                          # (L, T, ...)
    return pipeline_latency(lat)


def latency_seconds(cfg: AcceleratorConfig, counts,
                    lhr_matrix: np.ndarray | None = None,
                    mem_blocks_matrix: np.ndarray | None = None,
                    penc_width: np.ndarray | None = None,
                    clock_mhz: np.ndarray | float | None = None) -> np.ndarray:
    """Wall-clock latency; forwards the batched DSE kwargs so a vectorised
    sweep gets per-candidate seconds directly (shape follows
    ``latency_cycles``).  ``clock_mhz`` may be a per-candidate (n,) vector
    for sweeps with a clock axis; default is the base config's clock."""
    clk = np.asarray(cfg.timing.clock_mhz if clock_mhz is None else clock_mhz,
                     np.float64)
    return latency_cycles(cfg, counts, lhr_matrix=lhr_matrix,
                          mem_blocks_matrix=mem_blocks_matrix,
                          penc_width=penc_width) / (clk * 1e6)


def counts_from_traces(counts: Sequence[np.ndarray],
                       pool_before: Sequence[bool] | None = None,
                       pool_retention: float = 1.0) -> list[np.ndarray]:
    """Sampled per-layer spike traces -> per-layer (T,) mean traffic.

    ``counts``: one array per spiking layer, shaped (T,) or (T, N) / any
    trailing sample axes (the ``snn.spike_counts_per_layer`` /
    ``train_snn.dump_traces`` output); trailing axes are averaged away.
    ``pool_before[l]``: True if an OR-pool sits in front of layer ``l`` —
    its traffic is scaled by ``pool_retention`` (spike survival fraction).
    Traces dumped from a real model already carry pooling in the counts, so
    retention scaling is only for average-based (Table-I style) traffic.
    """
    out = []
    for l, c in enumerate(counts):
        c = np.asarray(c, dtype=np.float64)
        if c.ndim > 1:
            c = c.mean(axis=tuple(range(1, c.ndim)))
        scale = pool_retention if pool_before and pool_before[l] else 1.0
        out.append(c * scale)
    return out


def counts_from_averages(cfg: AcceleratorConfig, avg_spikes: Sequence[float],
                         num_steps: int | None = None,
                         pool_before: Sequence[bool] | None = None) -> list[np.ndarray]:
    """Constant per-step traffic from published averages (paper Table-I
    caption) — used for calibration and the Table-I reproduction benchmark.

    ``pool_before[l]``: True if an OR-pool sits in front of layer ``l``
    (its traffic is scaled by ``timing.pool_retention``).
    """
    T = num_steps or cfg.num_steps
    return counts_from_traces(
        [np.full((T,), float(s)) for s in avg_spikes],
        pool_before=pool_before,
        pool_retention=cfg.timing.pool_retention)


@dataclasses.dataclass
class LatencyBreakdown:
    per_layer_per_step: np.ndarray     # (L, T)
    bottleneck_layer: int
    total_cycles: float


def breakdown(cfg: AcceleratorConfig, counts: Sequence[np.ndarray]) -> LatencyBreakdown:
    lat = np.stack([layer_latency(layer, np.asarray(c, np.float64), cfg.timing)
                    for layer, c in zip(cfg.layers, counts)])
    total = pipeline_latency(lat)
    return LatencyBreakdown(
        per_layer_per_step=lat,
        bottleneck_layer=int(np.argmax(lat.sum(axis=1))),
        total_cycles=float(total),
    )
