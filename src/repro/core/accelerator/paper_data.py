"""The paper's published measurements (Table I + caption), used to
(a) calibrate the component cost library and (b) benchmark reproduction
fidelity.  Every number below is transcribed from Aliyev et al. 2023,
Table I and its caption.

Caption spike statistics = average spike events entering each layer
(pre-synaptic traffic), e.g. net-1 "784(95) - 500(81) - 500(86) - 300" means:
input layer 784 neurons with 95 avg spikes/step, hidden-0 500 neurons firing
81/step, hidden-1 500 firing 86/step, population output layer 300 neurons.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """Topology + measured traffic of one paper network."""
    name: str
    dataset: str
    # spiking-layer sizes, input first (input is not a spiking layer but its
    # traffic drives layer 0's ECU); output layer = population size.
    layer_sizes: tuple[int, ...]
    # avg spikes/step entering each *spiking* layer (len == len(layer_sizes)-1)
    avg_spikes: tuple[float, ...]
    population: int
    accuracy: float
    conv: bool = False
    # conv nets: (channels, kernel) per conv layer, None for fc entries
    conv_layers: tuple = ()


@dataclasses.dataclass(frozen=True)
class TableRow:
    net: str
    work: str               # "TW" or citation key of prior work
    lhr: Optional[tuple[int, ...]]
    lut: Optional[float]    # K LUTs
    reg: Optional[float]    # K registers
    cycles: float           # clock cycles / image
    energy_mj: Optional[float]


NETS = {
    "net-1": NetSpec("net-1", "mnist", (784, 500, 500, 300), (95, 81, 86),
                     population=300, accuracy=97.52),
    "net-2": NetSpec("net-2", "mnist", (784, 300, 300, 300, 200), (118, 98, 56, 56),
                     population=200, accuracy=98.02),
    "net-3": NetSpec("net-3", "fmnist", (784, 1024, 1024, 300), (186, 321, 304),
                     population=300, accuracy=84.41),
    "net-4": NetSpec("net-4", "fmnist", (784, 512, 256, 128, 64, 150),
                     (316, 169, 87, 37, 20), population=150, accuracy=76.4),
    # net-5: 128x128(135) - 32C3(240) - P2 - 32C3(1250) - P2 - 512(21) - 256 - 11
    "net-5": NetSpec("net-5", "dvsgesture",
                     (128 * 128, 32, 32, 512, 256),
                     (135, 240, 1250, 21),
                     population=0, accuracy=71.23, conv=True,
                     conv_layers=((32, 3), (32, 3), None, None)),
}

# net-2 caption lists 4 traffic figures for a 784-300-300-300-200 stack; the
# last hidden's 56 is reused for the output layer's input (paper gives
# "784(118) - 300(98) - 300(56) - 200" for a net labelled 784-300-300-300-10;
# we take the caption layout as authoritative for traffic).

TABLE1: list[TableRow] = [
    # --- net-1 (MNIST, vs Fang et al. [12]) ---
    TableRow("net-1", "[12]", None, 124.6, 185.2, 65000, 2.34),
    TableRow("net-1", "TW", (1, 1, 1), 157.6, 103.1, 10583, 0.09),
    TableRow("net-1", "TW", (2, 1, 1), 127.2, 83.2, 16807, 0.12),
    TableRow("net-1", "TW", (1, 2, 1), 127.2, 83.2, 15561, 0.11),
    TableRow("net-1", "TW", (4, 4, 4), 60.8, 39.7, 31583, 0.17),
    TableRow("net-1", "TW", (4, 8, 8), 30.7, 63.4, 53308, 0.27),
    # --- net-2 (MNIST, vs Abderrahmane et al. [11]) ---
    TableRow("net-2", "[11]", None, 22.8, 9.3, 1660, None),
    TableRow("net-2", "TW", (1, 1, 1, 1), 136.5, 86.1, 18710, 0.14),
    TableRow("net-2", "TW", (4, 4, 4, 1), 54.9, 33.2, 67586, 0.39),
    TableRow("net-2", "TW", (4, 4, 8, 1), 50.5, 30.2, 68542, 0.39),
    TableRow("net-2", "TW", (2, 2, 16, 8), 45.7, 27.2, 69998, 0.37),
    TableRow("net-2", "TW", (4, 4, 16, 8), 27.5, 15.4, 72330, 0.36),
    # --- net-3 (FMNIST, vs Liu et al. [33]) ---
    TableRow("net-3", "[33]", None, 124.6, 185.2, 65000, 2.23),
    TableRow("net-3", "TW", (1, 1, 1), 287.6, 185.5, 34563, 1.12),
    TableRow("net-3", "TW", (2, 1, 1), 225.7, 145.2, 35011, 0.97),
    TableRow("net-3", "TW", (8, 2, 4), 90.8, 56.2, 96827, 1.37),
    TableRow("net-3", "TW", (16, 8, 4), 35.8, 21.4, 187099, 1.45),
    TableRow("net-3", "TW", (32, 32, 8), 13.9, 8.7, 388897, 2.21),
    # --- net-4 (FMNIST, vs Ye et al. [34]) ---
    TableRow("net-4", "[34]", None, 13.7, 12.4, 1562000, None),
    TableRow("net-4", "TW", (1, 1, 1, 1, 1), 137.8, 90.3, 40142, 0.56),
    TableRow("net-4", "TW", (1, 4, 4, 1, 1), 103.1, 69.8, 61724, 0.73),
    TableRow("net-4", "TW", (2, 8, 4, 16, 8), 45.1, 67.2, 114266, 0.9),
    TableRow("net-4", "TW", (4, 2, 8, 8, 64), 37.7, 24.6, 69534, 0.48),
    TableRow("net-4", "TW", (32, 16, 8, 16, 64), 6.6, 63.4, 843518, 4.3),
    # --- net-5 (DVSGesture, vs Di Mauro et al. [35] ASIC) ---
    TableRow("net-5", "[35]", None, None, None, 6044000, 0.17),
    TableRow("net-5", "TW", (1, 1, 8, 32), 137.5, 361.5, 2481000, 14.93),
    TableRow("net-5", "TW", (1, 1, 16, 16), 128.1, 352.1, 2493000, 13.41),
    TableRow("net-5", "TW", (1, 1, 32, 32), 119.2, 343.7, 4475000, 20.5),
    TableRow("net-5", "TW", (1, 1, 16, 256), 123.4, 347.5, 2521000, 7.21),
    TableRow("net-5", "TW", (16, 1, 16, 256), 93.5, 267.5, 2486000, 6.24),
]


def tw_rows(net: str) -> list[TableRow]:
    return [r for r in TABLE1 if r.net == net and r.work == "TW"]


def baseline_row(net: str) -> TableRow:
    return next(r for r in TABLE1 if r.net == net and r.work != "TW")
