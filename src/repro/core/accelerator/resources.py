"""FPGA resource + energy estimation (the paper's component cost library).

The paper synthesizes each hardware component once (Xilinx Virtex
UltraScale+, 100 MHz) and sums per-component costs at configuration time.  We
cannot run Vivado here, so the per-component constants are **calibrated
against the paper's own Table I** by least squares (see ``calibrate.py``,
which re-derives them from ``paper_data``); EXPERIMENTS.md reports per-row
residuals.  The structural model is the paper's:

  per NU:          LIF datapath (leak multiplier, adder, comparator) + regs
  per layer ECU:   chunked PENC (~penc_width bits), bit-reset logic, FSM,
                   shift-register address array (fan_in addresses deep)
  per mem block:   BRAM36 primitives holding synapse rows + mapping logic
  top level:       per-layer interconnect / wrapper overhead
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.accelerator.arch import AcceleratorConfig, LayerHW


@dataclasses.dataclass(frozen=True)
class CostLibrary:
    # --- LUT (calibrated: see calibrate.py; residuals in EXPERIMENTS.md) ---
    lut_per_nu: float = 103.0          # FC LIF ALU + address decode
    lut_per_conv_nu: float = 1858.3    # conv NU: 2D addr extraction (Fig. 5)
    lut_per_penc_bit: float = 3.5      # priority encoder + bit-reset
    lut_per_mem_block: float = 18.2    # mapping/arbitration logic
    lut_fixed_per_layer: float = 427.4 # ECU FSM + wrapper
    # --- REG ---
    reg_per_nu: float = 77.2           # membrane/state registers
    reg_per_conv_nu: float = 2735.6    # conv NU pipeline registers
    reg_per_addr_bit: float = 0.944    # shift-register address array
    reg_fixed_per_layer: float = 301.8
    # --- BRAM / DSP ---
    bram36_bits: int = 36 * 1024
    dsp_per_nu: float = 1.0            # beta multiplier
    # --- energy (fit to Table I energy column, relative least squares) ---
    static_w: float = 0.346            # device static + clock tree
    w_per_lut: float = 0.0             # dynamic power per active LUT (the
    #                                    relative fit attributes LUT-correlated
    #                                    energy to the per-op term below)
    pj_per_acc_op: float = 13.2        # per weight accumulate (BRAM read+add)


@dataclasses.dataclass(frozen=True)
class Resources:
    lut: float
    reg: float
    bram36: int
    dsp: int

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.lut + o.lut, self.reg + o.reg,
                         self.bram36 + o.bram36, self.dsp + o.dsp)


def layer_resources(layer: LayerHW, lib: CostLibrary = CostLibrary()) -> Resources:
    nus = layer.num_nus
    # the shift-register array stores compressed spike addresses; the paper
    # sizes it for the layer's worst-case traffic (= fan_in addresses).
    # reg_per_addr_bit is the calibrated per-slot register cost (addr width
    # amortized into the constant).
    shift_regs = layer.fan_in_size * lib.reg_per_addr_bit
    lut_nu = lib.lut_per_conv_nu if layer.kind == "conv" else lib.lut_per_nu
    reg_nu = lib.reg_per_conv_nu if layer.kind == "conv" else lib.reg_per_nu
    lut = (lut_nu * nus
           + lib.lut_per_penc_bit * layer.penc_width
           + lib.lut_per_mem_block * layer.num_mem_blocks
           + lib.lut_fixed_per_layer)
    reg = (reg_nu * nus
           + shift_regs
           + lib.reg_fixed_per_layer)
    bram = math.ceil(layer.synapses * layer.weight_bits / lib.bram36_bits)
    return Resources(lut=lut, reg=reg, bram36=max(bram, 1), dsp=nus)


def estimate(cfg: AcceleratorConfig, lib: CostLibrary = CostLibrary()) -> Resources:
    total = Resources(0.0, 0.0, 0, 0)
    for layer in cfg.layers:
        total = total + layer_resources(layer, lib)
    return total


def estimate_lut_vector(cfg: AcceleratorConfig, lhr_matrix: np.ndarray,
                        lib: CostLibrary = CostLibrary()) -> np.ndarray:
    """Vectorised LUT estimate over (C, L) candidate LHR matrices (DSE)."""
    lhr = np.asarray(lhr_matrix, dtype=np.float64)
    lut = np.zeros(lhr.shape[0])
    for l, layer in enumerate(cfg.layers):
        nus = np.ceil(layer.logical / lhr[:, l])
        mem = layer.mem_blocks if layer.mem_blocks else nus
        lut_nu = lib.lut_per_conv_nu if layer.kind == "conv" else lib.lut_per_nu
        lut += (lut_nu * nus + lib.lut_per_penc_bit * layer.penc_width
                + lib.lut_per_mem_block * mem + lib.lut_fixed_per_layer)
    return lut


def accumulate_ops(cfg: AcceleratorConfig, counts) -> float:
    """Total weight-accumulate operations per inference (for energy)."""
    ops = 0.0
    for layer, c in zip(cfg.layers, counts):
        c = np.asarray(c, dtype=np.float64)
        per_spike = (layer.lhr * layer.num_nus if layer.kind == "fc"
                     else layer.kernel ** 2 * layer.logical)
        ops += float(c.sum()) * per_spike
    return ops


def energy_mj(cfg: AcceleratorConfig, counts, cycles: float,
              lib: CostLibrary = CostLibrary()) -> float:
    """E = (static + LUT-proportional dynamic) * runtime + per-op energy."""
    res = estimate(cfg, lib)
    runtime_s = cycles / (cfg.timing.clock_mhz * 1e6)
    power_w = lib.static_w + lib.w_per_lut * res.lut
    return (power_w * runtime_s + lib.pj_per_acc_op * 1e-12 * accumulate_ops(cfg, counts)) * 1e3
