"""FPGA resource + energy estimation (the paper's component cost library).

The paper synthesizes each hardware component once (Xilinx Virtex
UltraScale+, 100 MHz) and sums per-component costs at configuration time.  We
cannot run Vivado here, so the per-component constants are **calibrated
against the paper's own Table I** by least squares (see ``calibrate.py``,
which re-derives them from ``paper_data``); EXPERIMENTS.md reports per-row
residuals.  The structural model is the paper's:

  per NU:          LIF datapath (leak multiplier, adder, comparator) + regs
  per layer ECU:   chunked PENC (~penc_width bits), bit-reset logic, FSM,
                   shift-register address array (fan_in addresses deep)
  per mem block:   BRAM36 primitives holding synapse rows + mapping logic
  top level:       per-layer interconnect / wrapper overhead
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.accelerator.arch import (AcceleratorConfig, LayerHW,
                                         per_layer_col)


@dataclasses.dataclass(frozen=True)
class CostLibrary:
    # --- LUT (calibrated: see calibrate.py; residuals in EXPERIMENTS.md) ---
    lut_per_nu: float = 103.0          # FC LIF ALU + address decode
    lut_per_conv_nu: float = 1858.3    # conv NU: 2D addr extraction (Fig. 5)
    lut_per_penc_bit: float = 3.5      # priority encoder + bit-reset
    lut_per_mem_block: float = 18.2    # mapping/arbitration logic
    lut_fixed_per_layer: float = 427.4 # ECU FSM + wrapper
    # --- REG ---
    reg_per_nu: float = 77.2           # membrane/state registers
    reg_per_conv_nu: float = 2735.6    # conv NU pipeline registers
    reg_per_addr_bit: float = 0.944    # shift-register address array
    reg_fixed_per_layer: float = 301.8
    # --- BRAM / DSP ---
    bram36_bits: int = 36 * 1024
    dsp_per_nu: float = 1.0            # beta multiplier
    # --- energy (fit to Table I energy column, relative least squares) ---
    static_w: float = 0.346            # device static + clock tree
    w_per_lut: float = 0.0             # dynamic power per active LUT (the
    #                                    relative fit attributes LUT-correlated
    #                                    energy to the per-op term below)
    pj_per_acc_op: float = 13.2        # per weight accumulate (BRAM read+add)


@dataclasses.dataclass(frozen=True)
class Resources:
    lut: float
    reg: float
    bram36: int
    dsp: int

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.lut + o.lut, self.reg + o.reg,
                         self.bram36 + o.bram36, self.dsp + o.dsp)


def layer_resources(layer: LayerHW, lib: CostLibrary = CostLibrary()) -> Resources:
    nus = layer.num_nus
    # the shift-register array stores compressed spike addresses; the paper
    # sizes it for the layer's worst-case traffic (= fan_in addresses).
    # reg_per_addr_bit is the calibrated per-slot register cost (addr width
    # amortized into the constant).
    shift_regs = layer.fan_in_size * lib.reg_per_addr_bit
    lut_nu = lib.lut_per_conv_nu if layer.kind == "conv" else lib.lut_per_nu
    reg_nu = lib.reg_per_conv_nu if layer.kind == "conv" else lib.reg_per_nu
    lut = (lut_nu * nus
           + lib.lut_per_penc_bit * layer.penc_width
           + lib.lut_per_mem_block * layer.num_mem_blocks
           + lib.lut_fixed_per_layer)
    reg = (reg_nu * nus
           + shift_regs
           + lib.reg_fixed_per_layer)
    bram = math.ceil(layer.synapses * layer.weight_bits / lib.bram36_bits)
    return Resources(lut=lut, reg=reg, bram36=max(bram, 1), dsp=nus)


def estimate(cfg: AcceleratorConfig, lib: CostLibrary = CostLibrary()) -> Resources:
    total = Resources(0.0, 0.0, 0, 0)
    for layer in cfg.layers:
        total = total + layer_resources(layer, lib)
    return total


@dataclasses.dataclass(frozen=True)
class ResourcesVector:
    """Per-candidate resource columns for a batch of C designs."""
    lut: np.ndarray                  # (C,) float
    reg: np.ndarray                  # (C,) float
    bram36: np.ndarray               # (C,) int
    dsp: np.ndarray                  # (C,) int


def estimate_vector(cfg: AcceleratorConfig,
                    lhr_matrix: np.ndarray | None = None,
                    mem_blocks_matrix: np.ndarray | None = None,
                    weight_bits: np.ndarray | None = None,
                    penc_width: np.ndarray | None = None,
                    lib: CostLibrary = CostLibrary()) -> ResourcesVector:
    """Vectorised resource estimate over C candidate designs (DSE).

    Per-layer matrices are (C, L); ``weight_bits``/``penc_width`` may also be
    (C,) globals.  Any ``None`` axis falls back to the config's own values,
    so the result matches ``estimate`` row-for-row on materialized configs.
    """
    given = [a for a in (lhr_matrix, mem_blocks_matrix, weight_bits,
                         penc_width) if a is not None]
    if not given:
        raise ValueError("estimate_vector needs at least one candidate axis; "
                         "use estimate() for a single config")
    n = len(np.asarray(given[0]))
    lut = np.zeros(n)
    reg = np.zeros(n)
    bram = np.zeros(n, dtype=np.int64)
    dsp = np.zeros(n)
    for l, layer in enumerate(cfg.layers):
        lhr_l = per_layer_col(lhr_matrix, l)
        nus = (np.ceil(layer.logical / np.asarray(lhr_l, np.float64))
               if lhr_l is not None else np.float64(layer.num_nus))
        mem_l = per_layer_col(mem_blocks_matrix, l)
        if mem_l is None:
            mem = layer.mem_blocks if layer.mem_blocks else nus
        else:
            mem_l = np.asarray(mem_l, np.float64)
            mem = np.where(mem_l > 0, mem_l, nus)
        pw_l = per_layer_col(penc_width, l)
        pw = layer.penc_width if pw_l is None else pw_l
        wb_l = per_layer_col(weight_bits, l)
        wb = layer.weight_bits if wb_l is None else np.asarray(wb_l, np.int64)
        lut_nu = lib.lut_per_conv_nu if layer.kind == "conv" else lib.lut_per_nu
        reg_nu = lib.reg_per_conv_nu if layer.kind == "conv" else lib.reg_per_nu
        lut += (lut_nu * nus + lib.lut_per_penc_bit * pw
                + lib.lut_per_mem_block * mem + lib.lut_fixed_per_layer)
        reg += (reg_nu * nus + layer.fan_in_size * lib.reg_per_addr_bit
                + lib.reg_fixed_per_layer)
        bram += np.maximum(-(-(layer.synapses * wb) // lib.bram36_bits), 1)
        dsp += nus
    return ResourcesVector(lut=lut, reg=reg, bram36=bram,
                           dsp=dsp.astype(np.int64))


def estimate_lut_vector(cfg: AcceleratorConfig, lhr_matrix: np.ndarray,
                        lib: CostLibrary = CostLibrary()) -> np.ndarray:
    """Vectorised LUT estimate over (C, L) candidate LHR matrices (DSE)."""
    return estimate_vector(cfg, lhr_matrix=lhr_matrix, lib=lib).lut


def accumulate_ops(cfg: AcceleratorConfig, counts) -> float:
    """Total weight-accumulate operations per inference (for energy)."""
    ops = 0.0
    for layer, c in zip(cfg.layers, counts):
        c = np.asarray(c, dtype=np.float64)
        per_spike = (layer.lhr * layer.num_nus if layer.kind == "fc"
                     else layer.kernel ** 2 * layer.logical)
        ops += float(c.sum()) * per_spike
    return ops


def energy_mj(cfg: AcceleratorConfig, counts, cycles: float,
              lib: CostLibrary = CostLibrary()) -> float:
    """E = (static + LUT-proportional dynamic) * runtime + per-op energy."""
    res = estimate(cfg, lib)
    runtime_s = cycles / (cfg.timing.clock_mhz * 1e6)
    power_w = lib.static_w + lib.w_per_lut * res.lut
    return (power_w * runtime_s + lib.pj_per_acc_op * 1e-12 * accumulate_ops(cfg, counts)) * 1e3


def accumulate_ops_vector(cfg: AcceleratorConfig, counts,
                          lhr_matrix: np.ndarray | None = None) -> np.ndarray:
    """Vectorised ``accumulate_ops`` over (C, L) candidate LHR matrices.

    FC work per spike is ``lhr * ceil(logical / lhr)`` (each NU walks its
    owned neurons), so it varies with the candidate; conv work is
    LHR-independent.
    """
    if lhr_matrix is None:
        return np.asarray(accumulate_ops(cfg, counts))
    lhr = np.asarray(lhr_matrix, dtype=np.int64)
    ops = np.zeros(lhr.shape[0])
    for l, (layer, c) in enumerate(zip(cfg.layers, counts)):
        csum = float(np.asarray(c, dtype=np.float64).sum())
        if layer.kind == "fc":
            per_spike = lhr[:, l] * -(-layer.logical // lhr[:, l])
        else:
            per_spike = layer.kernel ** 2 * layer.logical
        ops += csum * per_spike
    return ops


def energy_mj_vector(cfg: AcceleratorConfig, counts, cycles: np.ndarray,
                     lhr_matrix: np.ndarray | None = None,
                     lut: np.ndarray | None = None,
                     clock_mhz: np.ndarray | None = None,
                     lib: CostLibrary = CostLibrary()) -> np.ndarray:
    """Vectorised ``energy_mj`` over C candidates.

    ``cycles``: (C,) latencies (from the batched cycle model).  ``lut`` can
    be passed to reuse an ``estimate_vector`` result; ``clock_mhz`` is a
    (C,) per-candidate clock axis (defaults to the config's clock).
    """
    cycles = np.asarray(cycles, dtype=np.float64)
    clk = np.asarray(cfg.timing.clock_mhz if clock_mhz is None else clock_mhz,
                     dtype=np.float64)
    runtime_s = cycles / (clk * 1e6)
    if lut is None:
        lut = (estimate_vector(cfg, lhr_matrix=lhr_matrix, lib=lib).lut
               if lhr_matrix is not None else estimate(cfg, lib).lut)
    power_w = lib.static_w + lib.w_per_lut * lut
    ops = accumulate_ops_vector(cfg, counts, lhr_matrix)
    return (power_w * runtime_s + lib.pj_per_acc_op * 1e-12 * ops) * 1e3
