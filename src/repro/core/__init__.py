# The paper's primary contribution: sparsity-aware SNN accelerator design +
# cycle-accurate DSE.  Submodules:
#   lif, encoding, snn       — spiking model substrate (training side)
#   sparsity                 — layer-wise firing analysis (paper Fig. 1)
#   accelerator              — the cycle-accurate hardware model (paper Sec. V)
#   dse                      — design space exploration engine (paper Sec. IV)
#   validate                 — spike-to-spike hardware validation
from repro.core.lif import LIFParams, lif_step, spike_fn
from repro.core.snn import SNNConfig, Dense, Conv, MaxPool

__all__ = ["LIFParams", "lif_step", "spike_fn", "SNNConfig", "Dense", "Conv",
           "MaxPool"]
