"""Leaky Integrate-and-Fire dynamics with surrogate-gradient spikes.

Forward semantics match the paper (Sec. V-C): at every time step a neuron's
membrane potential is

    U[t] = beta * U[t-1] + I[t] + bias - reset

with a spike ``S[t] = H(U[t] - theta)`` and reset-by-subtraction
(``reset = theta * S[t-1]``, snntorch's default for the ``Leaky`` neuron the
authors train with).  The Heaviside is non-differentiable; training uses the
fast-sigmoid surrogate (Zenke & Ganguli) exactly as snntorch's
``surrogate.fast_sigmoid``:

    dS/dU ~= 1 / (1 + slope * |U - theta|)^2
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_SLOPE = 25.0


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def spike_fn(v: jax.Array, slope: float = DEFAULT_SLOPE) -> jax.Array:
    """Heaviside step with fast-sigmoid surrogate gradient.

    ``v`` is the membrane potential *relative to threshold* (u - theta).
    """
    return (v > 0).astype(v.dtype)


def _spike_fwd(v, slope):
    return spike_fn(v, slope), v


def _spike_bwd(slope, v, g):
    surr = 1.0 / jnp.square(1.0 + slope * jnp.abs(v))
    return (g * surr,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Static neuron constants (per layer)."""
    beta: float = 0.95          # leak factor
    threshold: float = 1.0      # firing threshold
    slope: float = DEFAULT_SLOPE
    reset_mechanism: str = "subtract"   # "subtract" | "zero"


def lif_step(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array,
             p: LIFParams) -> tuple[jax.Array, jax.Array]:
    """One LIF update.  Returns (u, s).

    The hardware NU performs exactly this per neuron (paper Sec. V-C):
    leak-multiply, add accumulated synaptic current (+bias folded into
    ``current``), threshold-compare, reset.
    """
    if p.reset_mechanism == "subtract":
        reset = p.threshold * s_prev
        u = p.beta * u_prev + current - reset
    elif p.reset_mechanism == "zero":
        u = p.beta * u_prev * (1.0 - s_prev) + current
    else:
        raise ValueError(f"unknown reset mechanism {p.reset_mechanism!r}")
    s = spike_fn(u - p.threshold, p.slope)
    return u, s


def lif_init_state(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
