"""Layer-wise sparsity instrumentation (reproduces the paper's Fig. 1 and the
Table-I caption's "average spike events per layer").
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from repro.core import snn
from repro.core.accelerator import cycle_model


@dataclasses.dataclass(frozen=True)
class LayerSparsity:
    layer: int
    logical_neurons: int
    avg_spikes_per_step: float      # mean over time steps & samples
    firing_ratio: float             # avg_spikes / logical_neurons
    static_to_firing: float         # paper Fig. 1 companion metric


def analyze(cfg: snn.SNNConfig, params, spike_input: jax.Array) -> list[LayerSparsity]:
    """Firing statistics for every spiking layer's *input* traffic.

    ``spike_input``: (T, B, ...) encoded input train.
    Entry 0 describes the input layer (encoded pixels); entry ``l`` describes
    the traffic entering spiking layer ``l`` — exactly what sizes the ECU /
    NU workload in the accelerator.
    """
    counts = snn.spike_counts_per_layer(cfg, params, spike_input)  # list[(T,B)]
    traffic = cycle_model.counts_from_traces(counts)               # list[(T,)]
    sizes = _input_sizes(cfg)
    out = []
    for l, (c, n) in enumerate(zip(traffic, sizes)):
        avg = float(np.mean(c))
        ratio = avg / n
        out.append(LayerSparsity(
            layer=l, logical_neurons=n, avg_spikes_per_step=avg,
            firing_ratio=ratio,
            static_to_firing=(n - avg) / max(avg, 1e-9),
        ))
    return out


def _input_sizes(cfg: snn.SNNConfig) -> list[int]:
    """Size of the spike train entering each spiking layer (post-pooling)."""
    import math
    sizes = [int(math.prod(cfg.input_shape))]
    shapes = snn.output_shapes(cfg)
    layer_list = list(cfg.layers)
    for i, spec in enumerate(layer_list):
        if isinstance(spec, (snn.Dense, snn.Conv)):
            shape = shapes[i]
            j = i + 1
            while j < len(layer_list) and isinstance(layer_list[j], snn.MaxPool):
                shape = shapes[j]
                j += 1
            sizes.append(int(math.prod(shape)))
    return sizes[:-1]


def firing_table(stats: Sequence[LayerSparsity]) -> str:
    lines = [f"{'layer':>5} {'neurons':>8} {'avg spikes':>11} "
             f"{'firing ratio':>13} {'static:firing':>14}"]
    for s in stats:
        lines.append(f"{s.layer:>5} {s.logical_neurons:>8} "
                     f"{s.avg_spikes_per_step:>11.1f} {s.firing_ratio:>13.4f} "
                     f"{s.static_to_firing:>14.2f}")
    return "\n".join(lines)
