"""Content-addressed trace/accuracy cache for model cells.

A *cell* is one point of the model subspace: ``(workload, num_steps,
population, seed)``.  Resolving a cell means training (or loading) the
model, dumping its per-layer spike traces, and measuring accuracy — the
expensive leg of co-exploration.  The cache guarantees each cell trains at
most once, across repeated sweeps AND across processes:

* **Key** — sha256 over the workload's canonical ``signature()`` (topology
  template, dataset knobs, training recipe, ``version``) plus the model-axis
  assignment and seed.  Any change to anything that affects the trained
  artifact changes the key; bumping ``Workload.version`` invalidates.
* **Storage** — layered on ``repro.checkpoint.store``: the params pytree and
  the per-layer (T, S) trace counts publish atomically as one checkpoint
  under ``<root>/<key>/step_00000000``, so a crash mid-save never corrupts a
  cell and concurrent trainers of the same cell race benignly (deterministic
  training => identical bytes; last ``os.replace`` wins).  A ``meta.msgpack``
  sidecar (also atomically replaced) holds accuracy, the quantized-accuracy
  table, and the human-readable key fields; its presence marks the cell
  complete.
* **Restore** — the ``like`` tree the checkpoint store needs is rebuilt from
  the workload alone (``snn.init_params`` structure + zero count arrays), so
  no pickled structure is ever trusted.

``TraceCache.resolve`` is the single entry point; it also lazily extends the
cell's quantized-accuracy table (``validate.quantized_accuracy`` at the
requested ``weight_bits`` values) for every workload topology — conv/pool
layers run the fixed-point conv reference (``validate.reference_apply_batch``
with layer specs), MLPs the integer-matmul one — the accuracy leg of the
``weight_bits`` hardware axis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import threading
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.checkpoint import store
from repro.core import encoding, snn, train_snn, validate
from repro.core.workloads.registry import Workload

log = logging.getLogger(__name__)

_META = "meta.msgpack"
_QUANT_SAMPLES = 64          # test samples for the fixed-point accuracy leg

#: meta paths already reported corrupt (quarantine logs once per path)
_quarantined: set[str] = set()


def default_root() -> str:
    return os.environ.get(
        "REPRO_WORKLOAD_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "workloads"))


class BudgetExceeded(RuntimeError):
    """A cache miss would overspend the training budget."""


class TrainingBudget:
    """Training budget denominated in cache *misses* — the expensive leg of
    co-exploration.  Cache hits are free; each miss (an actual training run)
    charges one unit.  ``TraceCache.resolve(..., budget=...)`` charges
    *before* training starts, so an exhausted budget fails fast instead of
    after minutes of wasted work.  NAS-style drivers (``dse.explore``) probe
    ``can_spend`` + ``TraceCache.contains`` to *skip* unaffordable cells
    gracefully rather than raise.

    Thread-safe: one lock guards every check-and-charge, so concurrent
    tenant studies (``repro.serve.dse_service`` maps per-tenant quotas onto
    one shared budget) never double-spend the last unit — ``try_charge`` is
    the atomic check+charge for callers that must not race.  Only the
    lock-free counters round-trip through ``state_dict``/pickle; the lock
    is rebuilt on load, so checkpointed budgets restore across processes.
    """

    def __init__(self, limit: int):
        if limit < 0:
            raise ValueError(f"budget limit must be >= 0, got {limit}")
        self.limit = int(limit)
        self.spent = 0
        self._lock = threading.Lock()

    @property
    def remaining(self) -> int:
        # locked like every other accessor: an unlocked limit - spent can
        # tear against a concurrent load_state_dict swapping both fields
        with self._lock:
            return self.limit - self.spent

    def can_spend(self, n: int = 1) -> bool:
        with self._lock:
            return self.spent + n <= self.limit

    def charge(self, n: int = 1) -> None:
        if not self.try_charge(n):
            raise BudgetExceeded(
                f"training budget exhausted: {self.spent}/{self.limit} "
                f"misses spent, cannot charge {n} more")

    def refund(self, n: int = 1) -> None:
        """Return ``n`` charged-but-unspent units (a training run that was
        charged up front and then failed — ``TraceCache.resolve`` refunds
        on the failure path so the unit is not silently lost).  Clamped at
        zero: a refund can never manufacture budget."""
        with self._lock:
            self.spent = max(0, self.spent - int(n))

    def try_charge(self, n: int = 1) -> bool:
        """Atomically charge ``n`` misses iff affordable; False otherwise
        (the race-free form of ``can_spend`` + ``charge``)."""
        with self._lock:
            if self.spent + n > self.limit:
                return False
            self.spent += n
            return True

    def state_dict(self) -> dict:
        with self._lock:
            return {"limit": self.limit, "spent": self.spent}

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            self.limit = int(state["limit"])
            self.spent = int(state["spent"])

    # the lock never crosses a process boundary: pickling (e.g. inside a
    # farmed job's closure) ships the counters and rebuilds a fresh lock
    def __getstate__(self) -> dict:
        return self.state_dict()

    def __setstate__(self, state: dict) -> None:
        self.limit = int(state["limit"])
        self.spent = int(state["spent"])
        self._lock = threading.Lock()


def cell_key(workload: Workload, assignment: dict, seed: int) -> str:
    """Content hash of everything that determines the trained artifact."""
    payload = {
        "workload": workload.signature(),
        "assignment": {k: assignment[k] for k in sorted(assignment)},
        "seed": int(seed),
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


@dataclasses.dataclass
class CellArtifact:
    """One resolved model cell: trained params + traces + accuracy."""
    workload: str
    assignment: dict                 # {"num_steps": T, "population": p, ...}
    key: str
    snn_cfg: snn.SNNConfig
    params: Any                      # numpy pytree (list of {"w","b"} dicts)
    accuracy: float                  # float-datapath test accuracy
    counts: list[np.ndarray]         # per spiking layer, (T, S) sampled traffic
    quant_acc: dict[int, float]      # weight_bits -> fixed-point accuracy
    cache_hit: bool

    def accuracy_at(self, weight_bits: Optional[int] = None) -> float:
        """Accuracy under a hardware precision choice: the fixed-point
        datapath accuracy when measured at these bits, else the float one."""
        if weight_bits is not None and int(weight_bits) in self.quant_acc:
            return self.quant_acc[int(weight_bits)]
        return self.accuracy


class TraceCache:
    def __init__(self, root: Optional[str] = None):
        self.root = root or default_root()
        self.hits = 0
        self.misses = 0

    # ---- public -----------------------------------------------------------
    def contains(self, workload: Workload, assignment: dict,
                 seed: int = 0) -> bool:
        """True when the cell is already published (resolving it is a hit —
        no training, no budget charge).  Does not touch the counters."""
        norm = {"num_steps": int(assignment["num_steps"]),
                "population": float(assignment.get("population", 1.0))}
        key = cell_key(workload, norm, seed)
        return self._read_meta(os.path.join(self.root, key)) is not None

    def contains_key(self, key: str) -> bool:
        """``contains`` for callers that already hold the content address
        (the fleet's lease/spool machinery tracks cells by key alone).
        Same semantics: complete, readable meta == published."""
        return self._read_meta(os.path.join(self.root, key)) is not None

    def resolve(self, workload: Workload, assignment: dict, seed: int = 0,
                quant_bits: Sequence[int] = (),
                budget: Optional[TrainingBudget] = None) -> CellArtifact:
        """Train-or-load one cell.  ``assignment`` must provide ``num_steps``
        and may provide ``population`` (default 1.0).  ``quant_bits``: weight
        precisions whose fixed-point accuracy the caller needs — computed
        once (any topology: ``validate`` models dense, conv and pool
        datapaths) and appended to the cell's metadata.
        ``budget``: a ``TrainingBudget`` charged one miss *before* training
        starts; an exhausted budget raises ``BudgetExceeded`` instead of
        training (hits are always free)."""
        T = int(assignment["num_steps"])
        pop = float(assignment.get("population", 1.0))
        norm = {"num_steps": T, "population": pop}
        key = cell_key(workload, norm, seed)
        cfg = workload.build(T, pop)
        cell_dir = os.path.join(self.root, key)

        meta = self._read_meta(cell_dir)
        if meta is not None:
            params, counts = self._load_arrays(cell_dir, workload, cfg, T)
            self.hits += 1
            hit = True
        else:
            if budget is not None:
                budget.charge()
            try:
                params, counts, accuracy = self._train(workload, cfg, T,
                                                       seed)
                meta = {"workload": workload.name, "assignment": norm,
                        "seed": int(seed), "accuracy": float(accuracy),
                        "quant_acc": {}}
                self._write_cell(cell_dir, workload, params, counts, meta)
            except BaseException:
                # the charge landed before training; a failed run spent
                # nothing, so hand the unit back instead of leaking it
                if budget is not None:
                    budget.refund()
                raise
            self.misses += 1
            hit = False

        quant, meta = self._extend_quant(cell_dir, workload, cfg, T, params,
                                         meta, quant_bits)
        return CellArtifact(
            workload=workload.name, assignment=norm, key=key, snn_cfg=cfg,
            params=params, accuracy=float(meta["accuracy"]), counts=counts,
            quant_acc=quant, cache_hit=hit)

    def publish(self, workload: Workload, assignment: dict, seed: int = 0, *,
                params, counts: Sequence[np.ndarray], accuracy: float,
                quant_bits: Sequence[int] = (),
                budget: Optional[TrainingBudget] = None) -> CellArtifact:
        """Publish an already-trained cell (the batch hook for stacked
        trainers, ``repro.distributed.cellstack``).  Semantics mirror
        ``resolve``: if the cell is already published — e.g. a concurrent
        trainer won the race — the canonical stored copy is loaded and this
        counts as a hit (the caller's arrays are dropped; deterministic
        training makes them identical anyway); otherwise the arrays are
        written atomically (checkpoint first, ``meta.msgpack`` last), the
        miss counter increments, and ``budget`` is charged one miss.  The
        quantized-accuracy table extends exactly as in ``resolve``, so a
        later solo ``resolve`` of the same recipe is a pure cache hit."""
        T = int(assignment["num_steps"])
        pop = float(assignment.get("population", 1.0))
        norm = {"num_steps": T, "population": pop}
        key = cell_key(workload, norm, seed)
        cfg = workload.build(T, pop)
        cell_dir = os.path.join(self.root, key)

        meta = self._read_meta(cell_dir)
        if meta is not None:
            params, counts = self._load_arrays(cell_dir, workload, cfg, T)
            self.hits += 1
            hit = True
        else:
            if budget is not None:
                budget.charge()
            try:
                params = jax.tree.map(np.asarray, params)
                counts = [np.asarray(c, np.float32) for c in counts]
                meta = {"workload": workload.name, "assignment": norm,
                        "seed": int(seed), "accuracy": float(accuracy),
                        "quant_acc": {}}
                self._write_cell(cell_dir, workload, params, counts, meta)
            except BaseException:
                if budget is not None:   # failed publish spent nothing
                    budget.refund()
                raise
            self.misses += 1
            hit = False

        quant, meta = self._extend_quant(cell_dir, workload, cfg, T, params,
                                         meta, quant_bits)
        return CellArtifact(
            workload=workload.name, assignment=norm, key=key, snn_cfg=cfg,
            params=params, accuracy=float(meta["accuracy"]),
            counts=list(counts), quant_acc=quant, cache_hit=hit)

    # ---- internals --------------------------------------------------------
    def _extend_quant(self, cell_dir: str, workload: Workload,
                      cfg: snn.SNNConfig, T: int, params, meta: dict,
                      quant_bits: Sequence[int]) -> tuple[dict, dict]:
        """Lazily extend the cell's quantized-accuracy table to cover
        ``quant_bits``; returns the (table, freshest-meta) pair."""
        quant = {int(k): float(v) for k, v in meta["quant_acc"].items()}
        missing = [int(b) for b in quant_bits if int(b) not in quant]
        if missing:
            data = workload.make_data(T)
            for bits in missing:
                quant[bits] = _quantized_accuracy(cfg, params, data, bits)
            # merge over the freshest meta: a concurrent resolver may have
            # extended the table for other bits while we computed ours (a
            # lost entry would be benignly recomputed, but don't invite it)
            meta = self._read_meta(cell_dir) or meta
            quant = {**{int(k): float(v)
                        for k, v in meta["quant_acc"].items()}, **quant}
            meta["quant_acc"] = {str(b): a for b, a in quant.items()}
            self._write_meta(cell_dir, meta)
        return quant, meta

    def _train(self, workload: Workload, cfg: snn.SNNConfig, T: int,
               seed: int):
        data = workload.make_data(T)
        res = train_snn.train(cfg, data, steps=workload.train_steps,
                              batch_size=workload.batch_size,
                              lr=workload.lr, seed=seed,
                              matmul_backend=workload.matmul_backend)
        traces = train_snn.dump_traces(cfg, res.params, data.x_test,
                                       max_samples=workload.trace_samples,
                                       matmul_backend=workload.matmul_backend)
        params = jax.tree.map(np.asarray, res.params)
        counts = [np.asarray(c, np.float32)
                  for c in traces["layer_input_spike_counts"]]
        return params, counts, res.test_accuracy

    def _like_tree(self, workload: Workload, cfg: snn.SNNConfig, T: int):
        """Checkpoint target structure, rebuilt from the workload alone."""
        params_like = snn.init_params(jax.random.key(0), cfg)
        S = min(workload.trace_samples, workload.n_test)
        counts_like = [np.zeros((T, S), np.float32)
                       for _ in cfg.layer_sizes()]
        return {"counts": counts_like, "params": params_like}

    def _load_arrays(self, cell_dir: str, workload: Workload,
                     cfg: snn.SNNConfig, T: int):
        like = self._like_tree(workload, cfg, T)
        tree = store.restore(cell_dir, like, step=0)
        params = jax.tree.map(np.asarray, tree["params"])
        counts = [np.asarray(c) for c in tree["counts"]]
        return params, counts

    def _write_cell(self, cell_dir: str, workload: Workload, params,
                    counts: list[np.ndarray], meta: dict) -> None:
        store.save(cell_dir, 0, {"counts": counts, "params": params})
        self._write_meta(cell_dir, meta)       # meta last: marks completion

    def _write_meta(self, cell_dir: str, meta: dict) -> None:
        os.makedirs(cell_dir, exist_ok=True)
        tmp = os.path.join(cell_dir, _META + ".tmp")
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(meta))
        os.replace(tmp, os.path.join(cell_dir, _META))

    def _read_meta(self, cell_dir: str) -> Optional[dict]:
        """Read the completion-marking meta sidecar.  Unreadable meta — a
        truncated or torn write, real on network filesystems — is treated
        as *missing* (the cell re-resolves as a miss and republishes) after
        quarantining the bad bytes to ``meta.msgpack.corrupt``; without the
        quarantine every future ``resolve``/``contains`` of the cell would
        crash forever on the same torn file."""
        path = os.path.join(cell_dir, _META)
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except FileNotFoundError:
            return None
        try:
            meta = msgpack.unpackb(raw)
            if not isinstance(meta, dict) or "accuracy" not in meta \
                    or "quant_acc" not in meta:
                raise ValueError(f"meta is not a complete cell record: "
                                 f"{type(meta).__name__}")
        except Exception as e:                           # noqa: BLE001
            self._quarantine_meta(path, e)
            return None
        return meta

    def _quarantine_meta(self, path: str, error: Exception) -> None:
        if path not in _quarantined:                     # log once per path
            _quarantined.add(path)
            log.warning("unreadable cell meta %s (%s: %s); quarantined as "
                        "%s.corrupt — the cell will retrain",
                        path, type(error).__name__, error, _META)
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass                 # a concurrent resolver already moved it

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses}


def _quantized_accuracy(cfg: snn.SNNConfig, params, data, bits: int) -> float:
    """Fixed-point datapath accuracy at ``bits``-bit weights (any topology:
    conv/pool layers run the integer conv reference via layer specs)."""
    weights, biases = [], []
    for p in params:
        if p:                       # MaxPool entries carry no parameters
            weights.append(np.asarray(p["w"]))
            biases.append(np.asarray(p["b"]))
    specs = validate.layer_specs(cfg.layers)
    conv_net = any(sp[0] != "dense" for sp in specs)
    n = min(_QUANT_SAMPLES, len(data.x_test))
    x = np.asarray(data.x_test[:n])
    if x.ndim == 5:
        # pre-encoded event data (B, T, H, W, C): already a spike train,
        # same time-major transpose as train_snn._encode_input
        spikes = x.transpose(1, 0, 2, 3, 4).astype(np.int64)
    else:
        flat = jnp.asarray(x).reshape(n, -1)
        spikes = np.asarray(encoding.rate_encode(
            jax.random.key(1), flat, cfg.num_steps)).astype(np.int64)
        if conv_net:
            spikes = spikes.reshape(cfg.num_steps, n, *cfg.input_shape)
    return validate.quantized_accuracy(
        weights, biases, spikes, data.y_test[:n],
        num_classes=cfg.num_classes, frac_bits=int(bits) - 1,
        specs=specs if conv_net else None)
