"""Workload registry: the model half of the co-exploration loop.

A ``Workload`` declares everything the Training/Configuration phases need to
produce a *model cell* — one concrete trained model inside the joint
model x hardware design space:

* a **dataset** family (synthetic MNIST / FMNIST / DVS stand-ins — see
  ``repro.data.synthetic`` and DESIGN.md §7) plus its generation knobs;
* a **topology template** (the hidden ``snn.Dense`` / ``snn.Conv`` stack,
  *excluding* the classifier) with a **population-scale knob**: ``build``
  multiplies every template layer's ``features`` by a width multiplier, the
  paper's "neuron population size" axis;
* the **encoding** ("rate" for intensity images, "event" for pre-encoded
  DVS streams) and the candidate ``num_steps`` (spike-train length T)
  values — the paper's robustness-showcase axis;
* training hyper-parameters, all baked into the workload so a cell is fully
  determined by ``(workload, num_steps, population, seed)`` — which is
  exactly the trace-cache key (see ``workloads.cache``).

Workloads are frozen dataclasses: derive variants with
``dataclasses.replace`` (benchmarks shrink ``n_train``/``train_steps`` that
way) and register them under new names.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import snn
from repro.data import synthetic

DATASET_FAMILIES = ("mnist", "fmnist", "dvs")


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    dataset: str                            # one of DATASET_FAMILIES
    input_shape: tuple[int, ...]            # (H, W) images / (H, W, 2) events
    layers: tuple[snn.LayerSpec, ...]       # hidden template at population=1
    num_classes: int
    pcr: int = 1                            # population-coding ratio (output)
    encoding: str = "rate"                  # "rate" | "event"
    num_steps_choices: tuple[int, ...] = (4, 8, 15, 25)
    population_choices: tuple[float, ...] = (0.5, 1.0, 2.0)
    # dataset generation (deterministic — DESIGN.md §7)
    n_train: int = 2048
    n_test: int = 512
    data_seed: int = 0
    noise: float = 0.15                     # images only
    # training recipe (part of the cache key)
    train_steps: int = 150
    batch_size: int = 64
    lr: float = 2e-3
    trace_samples: int = 64                 # test samples traced per cell
    version: int = 1                        # bump to invalidate cached cells
    # Execution backend for the training forward pass ("jnp" | "spike_gemm";
    # None defers to the REPRO_MATMUL_BACKEND env var so whole processes —
    # e.g. cellfarm workers — can opt in without touching recipes).
    # Deliberately NOT part of signature(): the spike_gemm path is
    # parity-locked to the jnp reference (tests/test_train_backend.py), so
    # cached cells are backend-invariant and both recipes share one key.
    matmul_backend: Optional[str] = None

    def __post_init__(self):
        if self.dataset not in DATASET_FAMILIES:
            raise ValueError(f"unknown dataset family {self.dataset!r}; "
                             f"pick from {DATASET_FAMILIES}")
        if (self.matmul_backend is not None
                and self.matmul_backend not in snn.MATMUL_BACKENDS):
            raise ValueError(f"unknown matmul backend "
                             f"{self.matmul_backend!r}; "
                             f"pick from {snn.MATMUL_BACKENDS}")
        want = "event" if self.dataset == "dvs" else "rate"
        if self.encoding != want:
            raise ValueError(f"dataset {self.dataset!r} requires "
                             f"{want!r} encoding, got {self.encoding!r}")
        for spec in self.layers:
            if not isinstance(spec, (snn.Dense, snn.Conv, snn.MaxPool)):
                raise TypeError(spec)

    # ---- topology ---------------------------------------------------------
    def build(self, num_steps: int, population: float = 1.0) -> snn.SNNConfig:
        """Materialize one model cell's topology: template widths scaled by
        the ``population`` multiplier, classifier (``num_classes * pcr``
        neurons) appended unscaled."""
        if population <= 0:
            raise ValueError(f"population multiplier must be > 0, "
                             f"got {population}")
        scaled = tuple(_scale(spec, population) for spec in self.layers)
        out = snn.Dense(self.num_classes * self.pcr)
        return snn.SNNConfig(
            name=f"{self.name}-T{num_steps}-p{population:g}",
            input_shape=self.input_shape,
            layers=scaled + (out,),
            num_classes=self.num_classes,
            pcr=self.pcr,
            num_steps=int(num_steps))

    # ---- data -------------------------------------------------------------
    def make_data(self, num_steps: int) -> synthetic.Dataset:
        """Deterministic dataset for one cell.  Event data is generated at
        the cell's T (the stream length IS the spike train); image data is
        T-independent (rate encoding happens in training)."""
        if self.dataset == "dvs":
            h, w, _ = self.input_shape
            return synthetic.make_events(
                name=f"synth-{self.name}", seed=self.data_seed,
                num_classes=self.num_classes, n_train=self.n_train,
                n_test=self.n_test, t=int(num_steps), h=h, w=w)
        return synthetic.make_images(
            name=f"synth-{self.name}", seed=self.data_seed,
            num_classes=self.num_classes, n_train=self.n_train,
            n_test=self.n_test, h=self.input_shape[0],
            w=self.input_shape[1], noise=self.noise)

    def is_mlp(self) -> bool:
        """True when every layer is Dense — the topologies the *serial*
        hardware model (``validate.HardwareModel``) simulates.  The
        quantized-accuracy leg is no longer gated on this: the fixed-point
        reference covers conv/pool layers too (``validate.layer_specs``)."""
        return all(isinstance(s, snn.Dense) for s in self.layers)

    def signature(self) -> dict:
        """Canonical content description for cache keying — every field that
        changes the trained artifact, in primitive types."""
        return {
            "name": self.name, "dataset": self.dataset,
            "input_shape": list(self.input_shape),
            "layers": [_spec_sig(s) for s in self.layers],
            "num_classes": self.num_classes, "pcr": self.pcr,
            "encoding": self.encoding,
            "n_train": self.n_train, "n_test": self.n_test,
            "data_seed": self.data_seed, "noise": self.noise,
            "train_steps": self.train_steps, "batch_size": self.batch_size,
            "lr": self.lr, "trace_samples": self.trace_samples,
            "version": self.version,
        }


def _scale(spec: snn.LayerSpec, population: float) -> snn.LayerSpec:
    if isinstance(spec, (snn.Dense, snn.Conv)):
        return dataclasses.replace(
            spec, features=max(1, int(round(spec.features * population))))
    return spec                                   # MaxPool: no width


def _spec_sig(spec: snn.LayerSpec) -> list:
    if isinstance(spec, snn.Dense):
        return ["dense", spec.features]
    if isinstance(spec, snn.Conv):
        return ["conv", spec.features, spec.kernel, spec.stride, spec.padding]
    if isinstance(spec, snn.MaxPool):
        return ["pool", spec.window]
    raise TypeError(spec)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Workload] = {}


def register(workload: Workload, overwrite: bool = False) -> Workload:
    if workload.name in _REGISTRY and not overwrite:
        raise ValueError(f"workload {workload.name!r} already registered")
    _REGISTRY[workload.name] = workload
    return workload


def get(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; "
                       f"registered: {names()}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


# Built-ins: the three dataset families of the paper's evaluation, at sizes
# a CPU container trains in minutes.
register(Workload(
    name="mnist-mlp", dataset="mnist", input_shape=(28, 28),
    layers=(snn.Dense(128), snn.Dense(128)),
    num_classes=10, pcr=4))

register(Workload(
    name="fmnist-mlp", dataset="fmnist", input_shape=(28, 28),
    layers=(snn.Dense(128), snn.Dense(128)),
    num_classes=10, pcr=4, data_seed=17, noise=0.35))

register(Workload(
    name="dvs-conv", dataset="dvs", input_shape=(32, 32, 2),
    layers=(snn.Conv(8, 3), snn.MaxPool(2), snn.Conv(16, 3), snn.MaxPool(2),
            snn.Dense(64)),
    num_classes=8, pcr=2, encoding="event",
    num_steps_choices=(8, 12, 16), n_train=512, n_test=128))
