"""Workload registry + trace/accuracy cache (the model half of
co-exploration — see DESIGN.md §9 and ``repro.core.dse.coexplore``).

A ``Workload`` declares a dataset, a topology template with a
population-scale knob, an encoding, and candidate spike-train lengths; the
``TraceCache`` trains-or-loads any ``(workload, num_steps, population,
seed)`` cell deterministically and content-addressed, so repeated sweeps
never retrain and cells can be farmed out across processes.
"""
from repro.core.workloads.cache import (BudgetExceeded, CellArtifact,
                                        TraceCache, TrainingBudget, cell_key,
                                        default_root)
from repro.core.workloads.registry import (DATASET_FAMILIES, Workload, get,
                                           names, register)

__all__ = [
    "BudgetExceeded", "CellArtifact", "DATASET_FAMILIES", "TraceCache",
    "TrainingBudget", "Workload", "cell_key", "default_root", "get", "names",
    "register",
]
