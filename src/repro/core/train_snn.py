"""SNN training driver (the paper's "Training Phase").

Surrogate-gradient descent (fast-sigmoid) + BPTT through the ``lax.scan``
time loop, rate-coded inputs, population-coded outputs, rate cross-entropy.
After training, ``dump_traces`` extracts the spike traffic + weights that the
Configuration Phase feeds to the accelerator model — the JAX equivalent of
the paper's snntorch dump.

Every entry point threads ``matmul_backend`` (``"jnp"`` | ``"spike_gemm"``
| ``"spike_gemm_fused"``, DESIGN.md §11–§12) down to ``snn.apply``; the
kernel backends run both the forward accumulate AND the BPTT cotangent
matmuls block-skip, and the fused backend folds the LIF update into the
accumulate epilogue.  All three are training-equivalent — same loss
trajectory, bit-identical traces — so cached DSE cells stay backend-free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import encoding, snn
from repro.core.accelerator import cycle_model
from repro.data import synthetic
from repro.kernels import ops as kernel_ops

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    params: PyTree
    train_loss: list[float]
    test_accuracy: float
    cfg: snn.SNNConfig


def _encode_input(key: jax.Array, x: jax.Array, num_steps: int) -> jax.Array:
    if x.ndim == 5:        # pre-encoded event data (B, T, H, W, C)
        return x.transpose(1, 0, 2, 3, 4)
    return encoding.rate_encode(key, x, num_steps)


def loss_fn(cfg: snn.SNNConfig, params: PyTree, key: jax.Array,
            x: jax.Array, y: jax.Array,
            matmul_backend: Optional[str] = None) -> jax.Array:
    spikes_in = _encode_input(key, x, cfg.num_steps)
    out_train = snn.apply(cfg, params, spikes_in,
                          matmul_backend=matmul_backend)
    return encoding.rate_loss(out_train, y, cfg.num_classes)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _predict(cfg: snn.SNNConfig, matmul_backend: Optional[str],
             params: PyTree, key: jax.Array, x: jax.Array):
    spikes_in = _encode_input(key, x, cfg.num_steps)
    out_train = snn.apply(cfg, params, spikes_in,
                          matmul_backend=matmul_backend)
    return encoding.population_decode(out_train, cfg.num_classes)


def evaluate(cfg: snn.SNNConfig, params: PyTree, x: np.ndarray, y: np.ndarray,
             batch_size: int = 256, seed: int = 1234,
             matmul_backend: Optional[str] = None) -> float:
    backend = snn.resolve_matmul_backend(matmul_backend)
    correct, total = 0, 0
    key = jax.random.key(seed)
    for i in range(0, len(x), batch_size):
        key, sub = jax.random.split(key)
        xb = jnp.asarray(x[i:i + batch_size])
        pred = _predict(cfg, backend, params, sub, xb)
        correct += int((np.asarray(pred) == y[i:i + batch_size]).sum())
        total += len(y[i:i + batch_size])
    return correct / max(total, 1)


def make_train_step(cfg: snn.SNNConfig, tx,
                    matmul_backend: Optional[str] = None):
    """One SGD step of the training loop as a pure ``(params, opt_state,
    key, x, y) -> (params, opt_state, loss)`` function — unjitted, so
    callers can wrap it in ``jax.jit`` directly (the solo loop below) or
    ``jax.vmap`` it over a leading cell axis first
    (``distributed.cellstack`` trains whole same-signature cell stacks
    through this exact function, which is what keeps stacked and solo
    training bit-identical)."""
    backend = snn.resolve_matmul_backend(matmul_backend)

    def train_step(params, opt_state, key, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, key, x, y,
                              matmul_backend=backend))(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def init_cell(cfg: snn.SNNConfig, tx, seed: int):
    """The exact (params, opt_state, key) chain ``train`` starts from.

    Kept host-side and per-cell on purpose: ``jax.random.normal`` under
    ``vmap`` draws *different* bits than the solo call, so stacked trainers
    must initialize each cell through this function and stack the results
    rather than vmap the initializer (DESIGN.md §14)."""
    key = jax.random.key(seed)
    key, pkey = jax.random.split(key)
    params = snn.init_params(pkey, cfg)
    return params, tx.init(params), key


def train(cfg: snn.SNNConfig, data: synthetic.Dataset, *,
          steps: int = 300, batch_size: int = 64, lr: float = 2e-3,
          seed: int = 0, log_every: int = 50, verbose: bool = False,
          matmul_backend: Optional[str] = None) -> TrainResult:
    backend = snn.resolve_matmul_backend(matmul_backend)
    tx = optim.adam(lr)
    params, opt_state, key = init_cell(cfg, tx, seed)
    train_step = jax.jit(make_train_step(cfg, tx, backend))

    losses = []
    it = synthetic.batches(data.x_train, data.y_train, batch_size,
                           seed=seed, epochs=10_000)
    for step_i in range(steps):
        xb, yb = next(it)
        key, sub = jax.random.split(key)
        params, opt_state, loss = train_step(
            params, opt_state, sub, jnp.asarray(xb), jnp.asarray(yb))
        losses.append(float(loss))
        if verbose and step_i % log_every == 0:
            print(f"step {step_i:4d}  loss {float(loss):.4f}")

    acc = evaluate(cfg, params, data.x_test, data.y_test,
                   matmul_backend=backend)
    return TrainResult(params=params, train_loss=losses, test_accuracy=acc, cfg=cfg)


def dump_traces(cfg: snn.SNNConfig, params: PyTree, x: np.ndarray,
                seed: int = 7, max_samples: int = 64,
                matmul_backend: Optional[str] = None) -> dict:
    """Extract spike-traffic statistics for the accelerator model.

    Returns per-layer input spike counts with shape (T, N) (N = samples) —
    the Configuration-Phase artifact the cycle model consumes.  The counts
    are backend-invariant (tests/test_train_backend.py), so cached DSE cells
    never depend on which matmul path trained them.
    """
    key = jax.random.key(seed)
    xb = jnp.asarray(x[:max_samples])
    spikes_in = _encode_input(key, xb, cfg.num_steps)
    counts = snn.spike_counts_per_layer(cfg, params, spikes_in,
                                        matmul_backend=matmul_backend)
    return {
        "layer_input_spike_counts": [np.asarray(c) for c in counts],
        "layer_sizes": cfg.layer_sizes(),
        "num_steps": cfg.num_steps,
    }


def trace_counts(cfg: snn.SNNConfig, params: PyTree, x: np.ndarray,
                 seed: int = 7, max_samples: int = 64,
                 matmul_backend: Optional[str] = None) -> list[np.ndarray]:
    """``dump_traces`` reduced to the per-layer (T,) mean traffic the cycle
    model consumes — the Configuration-Phase artifact most callers want."""
    traces = dump_traces(cfg, params, x, seed=seed, max_samples=max_samples,
                         matmul_backend=matmul_backend)
    return cycle_model.counts_from_traces(traces["layer_input_spike_counts"])


def train_firing_permutation(train: jax.Array) -> jax.Array:
    """THE profiling statistic of the kernel path: per-input-neuron mean
    firing rate of a (T, B, ...) spike train, sorted cold-first
    (``ops.firing_rate_permutation``).  Single definition so the benchmark's
    ``skip_fraction_profiled`` measures exactly the permutation training
    would apply."""
    flat = train.reshape(-1, int(np.prod(train.shape[2:])))
    return kernel_ops.firing_rate_permutation(flat.mean(0))


def profiled_permutations(cfg: snn.SNNConfig, params: PyTree, x: np.ndarray,
                          seed: int = 7, max_samples: int = 64) -> list:
    """Per-layer pre-synaptic permutations from profiled firing rates.

    Runs a profiling pass over ``x`` and sorts each Dense layer's input axis
    by observed firing rate (``train_firing_permutation``) so cold neurons
    cluster into skippable MXU tiles.  Returns a list aligned with
    ``cfg.layers`` (``None`` for Conv/MaxPool), ready for
    ``snn.apply(..., matmul_backend="spike_gemm", layer_perms=...)``.
    """
    key = jax.random.key(seed)
    xb = jnp.asarray(x[:max_samples])
    spikes_in = _encode_input(key, xb, cfg.num_steps)
    trains = iter(snn.layer_input_trains(cfg, params, spikes_in))
    perms: list = []
    for spec in cfg.layers:
        perm = None
        if isinstance(spec, (snn.Dense, snn.Conv)):
            train = next(trains)
            if isinstance(spec, snn.Dense):
                perm = train_firing_permutation(train)
        perms.append(perm)
    return perms
