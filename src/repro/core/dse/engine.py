"""Hardware-only search: an exact thin wrapper over ``dse.explore``.

``search`` keeps its seed-era signature and numerics, but the loop now
lives in ``dse.study``: the strategy is driven through the ask/tell
contract and each asked chunk flows through the vectorised evaluator into
the incremental Pareto accumulator — a ``GridSearch`` study reproduces the
pre-ask/tell frontier bit-exactly (chunk boundaries and evaluation order
are unchanged; tested).  For joint model x hardware searches, budgeted
strategies, resumable studies, and worker farming, call ``dse.explore``
directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.accelerator import resources
from repro.core.accelerator.arch import AcceleratorConfig
from repro.core.dse.space import SearchSpace
from repro.core.dse.study import (DEFAULT_OBJECTIVES, FrontierQueries,
                                  explore)
from repro.core.dse.table import CandidateTable

__all__ = ["DEFAULT_OBJECTIVES", "FrontierQueries", "SearchResult",
           "auto_select", "search"]


@dataclasses.dataclass
class SearchResult(FrontierQueries):
    config: AcceleratorConfig
    space: SearchSpace
    objectives: tuple[str, ...]
    frontier: CandidateTable          # Pareto-optimal rows (streamed merge)
    n_evaluated: int
    table: Optional[CandidateTable] = None    # all rows iff keep_all

    def best_within_latency(self, max_cycles: float) -> Optional[dict]:
        return self.best_under("lut", cycles=max_cycles)

    def best_within_area(self, max_lut: float) -> Optional[dict]:
        return self.best_under("cycles", lut=max_lut)

    def min_energy(self) -> Optional[dict]:
        t = self._rows(("energy",))
        return t.row(t.argmin("energy")) if len(t) else None

    def config_for(self, row: dict) -> AcceleratorConfig:
        """Materialize a result row as a concrete AcceleratorConfig."""
        return self.config.with_updates(
            lhr=row.get("lhr"), mem_blocks=row.get("mem_blocks"),
            weight_bits=row.get("weight_bits"),
            penc_width=row.get("penc_width"),
            clock_mhz=row.get("clock_mhz"))


def search(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
           space: Optional[SearchSpace] = None,
           strategy: Union[str, object] = "grid",
           objectives: Sequence[str] = DEFAULT_OBJECTIVES,
           chunk_size: int = 65536,
           keep_all: bool = False,
           lib: Optional[resources.CostLibrary] = None) -> SearchResult:
    """Explore ``space`` (default: the per-layer LHR power-of-two product).

    ``objectives`` name metric columns (any of ``evaluate.METRICS``) to
    minimize jointly; the frontier is their k-objective Pareto set, merged
    incrementally across evaluation chunks.
    """
    space = space if space is not None else SearchSpace.product_lhr(cfg)
    if not space.axes:
        raise ValueError("search space has no axes")
    if space.model_axes:
        raise ValueError(
            f"space has model axes "
            f"{[ax.name for ax in space.model_axes]}; those require "
            f"training/cache resolution per cell — use dse.coexplore")
    study = explore(space, config=cfg, counts=counts, strategy=strategy,
                    objectives=objectives, chunk_size=chunk_size,
                    keep_all=keep_all, lib=lib)
    return SearchResult(config=cfg, space=space, objectives=study.objectives,
                        frontier=study.frontier,
                        n_evaluated=study.n_evaluated, table=study.table)


def auto_select(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                max_cycles: Optional[float] = None,
                max_lut: Optional[float] = None,
                space: Optional[SearchSpace] = None,
                **kw) -> Optional[tuple[AcceleratorConfig, dict]]:
    """The paper's "best mapping" picks over an arbitrary search space:
    smallest design within a latency budget (``max_cycles``), fastest within
    an area budget (``max_lut``), or minimum energy when no budget is given.
    Returns (materialized config, result row) or None if no design fits."""
    result = search(cfg, counts, space=space,
                    objectives=("cycles", "lut", "energy"), **kw)
    caps = {}
    if max_cycles is not None:
        caps["cycles"] = max_cycles
    if max_lut is not None:
        caps["lut"] = max_lut
    if max_cycles is not None:
        row = result.best_under("lut", **caps)
    elif max_lut is not None:
        row = result.best_under("cycles", **caps)
    else:
        row = result.min_energy()
    if row is None:
        return None
    return result.config_for(row), row
