"""Streaming search engine: strategy -> chunked evaluation -> Pareto merge.

``search`` never materializes the space: each chunk of candidates flows
through the vectorised evaluator into the incremental Pareto accumulator,
so a multi-million-point joint space runs in the memory of one chunk.  Pass
``keep_all=True`` on small spaces to retain the full metric table (the
legacy ``sweep`` behaviour).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.accelerator import resources
from repro.core.accelerator.arch import AcceleratorConfig
from repro.core.dse.evaluate import METRICS, evaluate_columns
from repro.core.dse.pareto import ParetoAccumulator
from repro.core.dse.space import SearchSpace
from repro.core.dse.strategies import GridSearch
from repro.core.dse.table import CandidateTable

DEFAULT_OBJECTIVES = ("cycles", "lut", "bram", "energy")


class FrontierQueries:
    """Query surface shared by every result that retains a Pareto frontier
    (and optionally the full table): expects ``objectives``, ``frontier``
    and ``table`` attributes on the subclass."""

    objectives: tuple[str, ...]
    frontier: CandidateTable
    table: Optional[CandidateTable]

    def _rows(self, needed: Sequence[str]) -> CandidateTable:
        """Full table when kept; else the frontier — which is only a valid
        search set when every queried column was a search objective (a
        non-objective optimum may live off-frontier)."""
        if self.table is not None:
            return self.table
        missing = [c for c in needed if c not in self.objectives]
        if missing:
            raise ValueError(
                f"columns {missing} were not search objectives "
                f"{self.objectives}; the retained frontier is only optimal "
                f"over the objectives — re-search with them included, or "
                f"with keep_all=True")
        return self.frontier

    def best_under(self, minimize: str, **caps: float) -> Optional[dict]:
        """Row minimizing ``minimize`` among rows with col <= cap for every
        kwarg — e.g. ``best_under("lut", cycles=20e3)``."""
        t = self._rows((minimize, *caps))
        if len(t) == 0:
            return None
        ok = np.ones(len(t), dtype=bool)
        for col, cap in caps.items():
            ok &= np.asarray(t.columns[col], np.float64) <= cap
        if not ok.any():
            return None
        sub = t.take(ok)
        return sub.row(sub.argmin(minimize))


@dataclasses.dataclass
class SearchResult(FrontierQueries):
    config: AcceleratorConfig
    space: SearchSpace
    objectives: tuple[str, ...]
    frontier: CandidateTable          # Pareto-optimal rows (streamed merge)
    n_evaluated: int
    table: Optional[CandidateTable] = None    # all rows iff keep_all

    def best_within_latency(self, max_cycles: float) -> Optional[dict]:
        return self.best_under("lut", cycles=max_cycles)

    def best_within_area(self, max_lut: float) -> Optional[dict]:
        return self.best_under("cycles", lut=max_lut)

    def min_energy(self) -> Optional[dict]:
        t = self._rows(("energy",))
        return t.row(t.argmin("energy")) if len(t) else None

    def config_for(self, row: dict) -> AcceleratorConfig:
        """Materialize a result row as a concrete AcceleratorConfig."""
        return self.config.with_updates(
            lhr=row.get("lhr"), mem_blocks=row.get("mem_blocks"),
            weight_bits=row.get("weight_bits"),
            penc_width=row.get("penc_width"),
            clock_mhz=row.get("clock_mhz"))


def search(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
           space: Optional[SearchSpace] = None,
           strategy: Union[str, object] = "grid",
           objectives: Sequence[str] = DEFAULT_OBJECTIVES,
           chunk_size: int = 65536,
           keep_all: bool = False,
           lib: Optional[resources.CostLibrary] = None) -> SearchResult:
    """Explore ``space`` (default: the per-layer LHR power-of-two product).

    ``objectives`` name metric columns (any of ``evaluate.METRICS``) to
    minimize jointly; the frontier is their k-objective Pareto set, merged
    incrementally across evaluation chunks.
    """
    space = space if space is not None else SearchSpace.product_lhr(cfg)
    if not space.axes:
        raise ValueError("search space has no axes")
    if space.model_axes:
        raise ValueError(
            f"space has model axes "
            f"{[ax.name for ax in space.model_axes]}; those require "
            f"training/cache resolution per cell — use dse.coexplore")
    for obj in objectives:
        if obj not in METRICS:
            raise ValueError(f"unknown objective {obj!r}; pick from {METRICS}")
    if isinstance(strategy, str):
        if strategy != "grid":
            raise ValueError(f"unknown strategy name {strategy!r}; pass a "
                             f"strategy instance for non-grid search")
        strategy = GridSearch(chunk_size)

    acc = ParetoAccumulator(objectives)
    kept: Optional[list[CandidateTable]] = [] if keep_all else None

    def evaluate(cols: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        metrics = evaluate_columns(cfg, counts, cols, lib=lib)
        chunk = CandidateTable({**cols, **metrics})
        acc.update(chunk)
        if kept is not None:
            kept.append(chunk)
        return metrics

    n = strategy.run(space, evaluate, tuple(objectives))
    table = CandidateTable.concat(kept) if kept is not None else None
    return SearchResult(config=cfg, space=space, objectives=tuple(objectives),
                        frontier=acc.frontier, n_evaluated=n, table=table)


def auto_select(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                max_cycles: Optional[float] = None,
                max_lut: Optional[float] = None,
                space: Optional[SearchSpace] = None,
                **kw) -> Optional[tuple[AcceleratorConfig, dict]]:
    """The paper's "best mapping" picks over an arbitrary search space:
    smallest design within a latency budget (``max_cycles``), fastest within
    an area budget (``max_lut``), or minimum energy when no budget is given.
    Returns (materialized config, result row) or None if no design fits."""
    result = search(cfg, counts, space=space,
                    objectives=("cycles", "lut", "energy"), **kw)
    caps = {}
    if max_cycles is not None:
        caps["cycles"] = max_cycles
    if max_lut is not None:
        caps["lut"] = max_lut
    if max_cycles is not None:
        row = result.best_under("lut", **caps)
    elif max_lut is not None:
        row = result.best_under("cycles", **caps)
    else:
        row = result.min_energy()
    if row is None:
        return None
    return result.config_for(row), row
