"""Declarative search space over hardware *and* model parameters.

A ``SearchSpace`` is an ordered list of axes over an ``AcceleratorConfig``.
Three axis shapes cover the paper's DSE dimensions plus the joint sweeps the
seed engine could not express:

* **per-layer scalar** — one axis per layer, independent options
  (``add_per_layer("lhr", [[1,2,4], [1,2], ...])``); the Cartesian product
  explores every per-layer combination, exactly like the seed ``lhr_grid``.
* **joint (zipped) vector** — one axis whose options are whole per-layer
  vectors (``add_joint("mem_blocks", [(64,32,16), (32,16,8)])``); all layers
  move together, the seed ``sweep_memory_blocks`` pattern.
* **global scalar** — one value applied everywhere
  (``add_global("weight_bits", (4, 6, 8))`` or ``add_global("clock_mhz", …)``).

The full space is the Cartesian product of all axes (last axis fastest,
matching ``itertools.product``).  Nothing is ever materialized: ``decode``
turns a chunk of flat candidate indices into column arrays by mixed-radix
digit extraction, so a billion-point space streams through fixed memory.

Known axis names and where they act:

  ``lhr``          per layer — NU count (latency, LUT/REG/DSP, energy)
  ``mem_blocks``   per layer — port contention vs BRAM mapping logic
  ``weight_bits``  per layer or global — BRAM footprint (accuracy measured
                   separately via ``validate.quantized_accuracy``)
  ``penc_width``   per layer or global — PENC scan cycles vs encoder LUTs
  ``clock_mhz``    global — runtime/energy scaling

**Model axes** (``num_steps``, ``population``, ``dataset`` — added via
``add_model``) live in the same declarative space but act on the *model*,
not the hardware: every combination of their values is a *model cell* that
must be trained (or cache-loaded) before hardware evaluation, so the plain
``search`` engine refuses them — ``dse.coexplore`` factors the joint space
into (model cell) x (hardware subspace) and streams each cell's hardware
subspace through the usual chunked evaluator.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.accelerator.arch import AcceleratorConfig

#: axes resolved by training/loading a model cell, not by the cycle model
MODEL_AXES = ("dataset", "num_steps", "population")

# per-layer defaults pulled from the base config when an axis doesn't cover
# a layer (or doesn't exist at all)
_PER_LAYER_DEFAULTS = {
    "lhr": lambda layer: layer.lhr,
    "mem_blocks": lambda layer: layer.mem_blocks,
    "weight_bits": lambda layer: layer.weight_bits,
    "penc_width": lambda layer: layer.penc_width,
}


def iter_cells(axes: Sequence[tuple[str, Sequence]]):
    """Assignment dicts over (name, values) pairs, last axis fastest — the
    product iteration shared by ``SearchSpace.model_cells`` and
    ``dse.coexplore``'s kwargs path."""
    names = [n for n, _ in axes]
    for combo in itertools.product(*[v for _, v in axes]):
        yield dict(zip(names, combo))


def pow2_values(cap: int) -> list[int]:
    """[1, 2, 4, ...] up to ``cap`` — the paper's LHR sweep style."""
    vals = [1]
    while vals[-1] * 2 <= cap:
        vals.append(vals[-1] * 2)
    return vals


@dataclasses.dataclass(frozen=True)
class Axis:
    name: str
    values: tuple                 # scalars, or length-L tuples (joint axis)
    layer: int | None = None      # index for per-layer scalar axes

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"axis {self.name!r} has no values")
        if self.is_vector:
            if self.layer is not None:
                raise ValueError(f"joint axis {self.name!r} cannot bind to "
                                 f"a single layer")
            lens = {len(v) for v in self.values}
            if len(lens) != 1:
                raise ValueError(f"joint axis {self.name!r} has ragged "
                                 f"options: {lens}")

    @property
    def is_vector(self) -> bool:
        return isinstance(self.values[0], (tuple, list, np.ndarray))

    @property
    def cardinality(self) -> int:
        return len(self.values)


class SearchSpace:
    def __init__(self, config: AcceleratorConfig, axes: Sequence[Axis] = ()):
        self.config = config
        self.axes: list[Axis] = []
        for ax in axes:
            self._append(ax)

    # ---- construction (fluent) -------------------------------------------
    def _append(self, axis: Axis) -> None:
        for ax in self.axes:
            if ax.name != axis.name:
                continue
            if ax.is_vector or axis.is_vector or ax.layer is None \
                    or axis.layer is None or ax.layer == axis.layer:
                raise ValueError(
                    f"axis {axis.name!r} conflicts with an existing axis of "
                    f"the same name (only distinct per-layer bindings may "
                    f"share a name)")
        if axis.layer is not None and not (
                0 <= axis.layer < len(self.config.layers)):
            raise ValueError(f"axis {axis.name!r}: layer {axis.layer} out of "
                             f"range for {len(self.config.layers)} layers")
        self.axes.append(axis)

    def add_per_layer(self, name: str,
                      values_per_layer: Sequence[Sequence]) -> "SearchSpace":
        """One independent scalar axis per layer (Cartesian across layers)."""
        if len(values_per_layer) != len(self.config.layers):
            raise ValueError(f"{name}: {len(values_per_layer)} value lists "
                             f"for {len(self.config.layers)} layers")
        for i, vals in enumerate(values_per_layer):
            self._append(Axis(name, tuple(vals), layer=i))
        return self

    def add_joint(self, name: str, options: Sequence[Sequence]) -> "SearchSpace":
        """One axis whose options are whole per-layer vectors (zipped)."""
        opts = tuple(tuple(o) for o in options)
        for o in opts:
            if len(o) != len(self.config.layers):
                raise ValueError(f"{name}: option {o} has {len(o)} entries "
                                 f"for {len(self.config.layers)} layers")
        self._append(Axis(name, opts))
        return self

    def add_global(self, name: str, values: Sequence) -> "SearchSpace":
        if name in MODEL_AXES:
            raise ValueError(f"{name!r} is a model axis; use add_model")
        self._append(Axis(name, tuple(values)))
        return self

    def add_model(self, name: str, values: Sequence) -> "SearchSpace":
        """Model-parameter axis (``num_steps`` / ``population`` /
        ``dataset``): each value combination is a model cell resolved by
        training or the trace cache — see ``dse.coexplore``."""
        if name not in MODEL_AXES:
            raise ValueError(f"unknown model axis {name!r}; "
                             f"pick from {MODEL_AXES}")
        self._append(Axis(name, tuple(values)))
        return self

    @classmethod
    def product_lhr(cls, config: AcceleratorConfig,
                    max_lhr: int = 256) -> "SearchSpace":
        """Per-layer power-of-two LHR product — the seed ``lhr_grid`` space."""
        return cls(config).add_per_layer(
            "lhr", [pow2_values(min(max_lhr, l.logical))
                    for l in config.layers])

    # ---- geometry ---------------------------------------------------------
    @property
    def size(self) -> int:
        n = 1
        for ax in self.axes:
            n *= ax.cardinality          # python int: no overflow
        return n if self.axes else 0

    # ---- model / hardware factorization -----------------------------------
    @property
    def model_axes(self) -> list[Axis]:
        return [ax for ax in self.axes if ax.name in MODEL_AXES]

    @property
    def hw_axes(self) -> list[Axis]:
        return [ax for ax in self.axes if ax.name not in MODEL_AXES]

    def model_cells(self):
        """Iterate the model subspace: one assignment dict per cell, in
        declared-axis product order (last axis fastest).  A space with no
        model axes has exactly one (empty) cell."""
        axes = self.model_axes
        if not axes:
            yield {}
            return
        yield from iter_cells([(ax.name, ax.values) for ax in axes])

    def hardware_subspace(self, config: AcceleratorConfig | None = None,
                          dedup: bool = True) -> "SearchSpace":
        """The hardware-only axes, rebound to ``config`` (a model cell's
        derived ``AcceleratorConfig``).  ``lhr`` options (per-layer scalar
        or joint vector) are clamped to the cell's layer sizes (duplicates
        dropped, order kept) — a population-scaled cell may be narrower
        than the template the axes were declared against; joint axes whose
        vector width disagrees with the cell's layer count are rejected.

        ``dedup=False`` keeps clamp-induced duplicate values so every axis
        retains its *template* cardinality — the property the joint ask/tell
        driver needs: a strategy's digit over the template space then stays
        a valid digit in every cell's rebound subspace."""
        config = config if config is not None else self.config
        sub = SearchSpace(config)
        for ax in self.hw_axes:
            if ax.layer is not None and ax.layer >= len(config.layers):
                raise ValueError(
                    f"axis {ax.name!r} binds layer {ax.layer} but the cell "
                    f"config has {len(config.layers)} layers; pass a "
                    f"per-cell hw_space callable to coexplore instead")
            values = ax.values
            if ax.is_vector:
                if len(values[0]) != len(config.layers):
                    raise ValueError(
                        f"joint axis {ax.name!r} options are "
                        f"{len(values[0])}-wide but the cell config has "
                        f"{len(config.layers)} layers; pass a per-cell "
                        f"hw_space callable to coexplore instead")
                if ax.name == "lhr":
                    caps = [l.logical for l in config.layers]
                    clamped = (tuple(min(int(x), c) for x, c in zip(v, caps))
                               for v in values)
                    values = tuple(dict.fromkeys(clamped) if dedup
                                   else clamped)
            elif ax.name == "lhr" and ax.layer is not None:
                cap = config.layers[ax.layer].logical
                clamped = (min(int(v), cap) for v in ax.values)
                values = tuple(dict.fromkeys(clamped) if dedup else clamped)
            sub._append(Axis(ax.name, values, layer=ax.layer))
        return sub

    def split_digits(self, digits: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Split an (n, n_axes) digit matrix into its model-axis and
        hardware-axis columns (each in declared-axis order) — the joint
        ask/tell driver factors asked chunks by model cell this way."""
        digits = np.asarray(digits)
        model = [i for i, ax in enumerate(self.axes) if ax.name in MODEL_AXES]
        hw = [i for i, ax in enumerate(self.axes) if ax.name not in MODEL_AXES]
        return digits[:, model], digits[:, hw]

    def model_assignment(self, model_digits: Sequence[int]) -> dict:
        """One model-axis digit row -> assignment dict (``dataset`` values
        stay whatever was declared — name or Workload instance)."""
        axes = self.model_axes
        if len(model_digits) != len(axes):
            raise ValueError(f"{len(model_digits)} model digits for "
                             f"{len(axes)} model axes")
        return {ax.name: ax.values[int(d)]
                for ax, d in zip(axes, model_digits)}

    def signature(self) -> list:
        """Canonical structural description (axis names, bindings, values)
        used to verify a resumed ``Study`` is given the space it was
        checkpointed with.  Values reduce to primitives; objects (e.g.
        Workload instances on a ``dataset`` axis) reduce to their ``name``
        or ``repr``."""
        def prim(v):
            if isinstance(v, (tuple, list, np.ndarray)):
                return [prim(x) for x in v]
            if isinstance(v, (int, float, str, bool)):
                return v
            if isinstance(v, np.generic):
                return v.item()
            return getattr(v, "name", repr(v))
        return [[ax.name, ax.layer, prim(ax.values)] for ax in self.axes]

    # ---- decoding ---------------------------------------------------------
    def digits(self, flat_idx: np.ndarray) -> np.ndarray:
        """Mixed-radix digits (n, n_axes), last axis fastest."""
        idx = np.asarray(flat_idx, dtype=np.int64)
        out = np.empty((len(idx), len(self.axes)), dtype=np.int64)
        stride = 1
        for a in range(len(self.axes) - 1, -1, -1):
            card = self.axes[a].cardinality
            out[:, a] = (idx // stride) % card
            stride *= card
        return out

    def sample_digits(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Uniform random digit matrix — valid even for spaces past 2^63."""
        return np.stack([rng.integers(ax.cardinality, size=n)
                         for ax in self.axes], axis=1)

    def assemble(self, digits: np.ndarray) -> dict[str, np.ndarray]:
        """Digit matrix -> named column arrays, filling config defaults for
        layers no axis covers."""
        n = len(digits)
        n_layers = len(self.config.layers)
        cols: dict[str, np.ndarray] = {}
        for a, ax in enumerate(self.axes):
            vals = np.asarray(ax.values)
            picked = vals[digits[:, a]]              # (n,) or (n, L)
            if ax.is_vector:
                cols[ax.name] = picked
            elif ax.layer is None:
                cols[ax.name] = picked
            else:
                if ax.name not in cols:
                    default = _PER_LAYER_DEFAULTS.get(ax.name)
                    if default is None:
                        raise ValueError(f"no per-layer default for axis "
                                         f"{ax.name!r}")
                    base = [default(l) for l in self.config.layers]
                    cols[ax.name] = np.tile(
                        np.asarray(base, dtype=vals.dtype), (n, 1))
                cols[ax.name][:, ax.layer] = picked
        return cols

    def decode(self, flat_idx: np.ndarray) -> dict[str, np.ndarray]:
        """Chunk of flat candidate indices -> column arrays."""
        return self.assemble(self.digits(flat_idx))
