"""Declarative search space over hardware *and* model parameters.

A ``SearchSpace`` is an ordered list of axes over an ``AcceleratorConfig``.
Three axis shapes cover the paper's DSE dimensions plus the joint sweeps the
seed engine could not express:

* **per-layer scalar** — one axis per layer, independent options
  (``add_per_layer("lhr", [[1,2,4], [1,2], ...])``); the Cartesian product
  explores every per-layer combination, exactly like the seed ``lhr_grid``.
* **joint (zipped) vector** — one axis whose options are whole per-layer
  vectors (``add_joint("mem_blocks", [(64,32,16), (32,16,8)])``); all layers
  move together, the seed ``sweep_memory_blocks`` pattern.
* **global scalar** — one value applied everywhere
  (``add_global("weight_bits", (4, 6, 8))`` or ``add_global("clock_mhz", …)``).

The full space is the Cartesian product of all axes (last axis fastest,
matching ``itertools.product``).  Nothing is ever materialized: ``decode``
turns a chunk of flat candidate indices into column arrays by mixed-radix
digit extraction, so a billion-point space streams through fixed memory.

Known axis names and where they act:

  ``lhr``          per layer — NU count (latency, LUT/REG/DSP, energy)
  ``mem_blocks``   per layer — port contention vs BRAM mapping logic
  ``weight_bits``  per layer or global — BRAM footprint (accuracy measured
                   separately via ``validate.quantized_accuracy``)
  ``penc_width``   per layer or global — PENC scan cycles vs encoder LUTs
  ``clock_mhz``    global — runtime/energy scaling
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.accelerator.arch import AcceleratorConfig

# per-layer defaults pulled from the base config when an axis doesn't cover
# a layer (or doesn't exist at all)
_PER_LAYER_DEFAULTS = {
    "lhr": lambda layer: layer.lhr,
    "mem_blocks": lambda layer: layer.mem_blocks,
    "weight_bits": lambda layer: layer.weight_bits,
    "penc_width": lambda layer: layer.penc_width,
}


def pow2_values(cap: int) -> list[int]:
    """[1, 2, 4, ...] up to ``cap`` — the paper's LHR sweep style."""
    vals = [1]
    while vals[-1] * 2 <= cap:
        vals.append(vals[-1] * 2)
    return vals


@dataclasses.dataclass(frozen=True)
class Axis:
    name: str
    values: tuple                 # scalars, or length-L tuples (joint axis)
    layer: int | None = None      # index for per-layer scalar axes

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"axis {self.name!r} has no values")
        if self.is_vector:
            if self.layer is not None:
                raise ValueError(f"joint axis {self.name!r} cannot bind to "
                                 f"a single layer")
            lens = {len(v) for v in self.values}
            if len(lens) != 1:
                raise ValueError(f"joint axis {self.name!r} has ragged "
                                 f"options: {lens}")

    @property
    def is_vector(self) -> bool:
        return isinstance(self.values[0], (tuple, list, np.ndarray))

    @property
    def cardinality(self) -> int:
        return len(self.values)


class SearchSpace:
    def __init__(self, config: AcceleratorConfig, axes: Sequence[Axis] = ()):
        self.config = config
        self.axes: list[Axis] = []
        for ax in axes:
            self._append(ax)

    # ---- construction (fluent) -------------------------------------------
    def _append(self, axis: Axis) -> None:
        for ax in self.axes:
            if ax.name != axis.name:
                continue
            if ax.is_vector or axis.is_vector or ax.layer is None \
                    or axis.layer is None or ax.layer == axis.layer:
                raise ValueError(
                    f"axis {axis.name!r} conflicts with an existing axis of "
                    f"the same name (only distinct per-layer bindings may "
                    f"share a name)")
        if axis.layer is not None and not (
                0 <= axis.layer < len(self.config.layers)):
            raise ValueError(f"axis {axis.name!r}: layer {axis.layer} out of "
                             f"range for {len(self.config.layers)} layers")
        self.axes.append(axis)

    def add_per_layer(self, name: str,
                      values_per_layer: Sequence[Sequence]) -> "SearchSpace":
        """One independent scalar axis per layer (Cartesian across layers)."""
        if len(values_per_layer) != len(self.config.layers):
            raise ValueError(f"{name}: {len(values_per_layer)} value lists "
                             f"for {len(self.config.layers)} layers")
        for i, vals in enumerate(values_per_layer):
            self._append(Axis(name, tuple(vals), layer=i))
        return self

    def add_joint(self, name: str, options: Sequence[Sequence]) -> "SearchSpace":
        """One axis whose options are whole per-layer vectors (zipped)."""
        opts = tuple(tuple(o) for o in options)
        for o in opts:
            if len(o) != len(self.config.layers):
                raise ValueError(f"{name}: option {o} has {len(o)} entries "
                                 f"for {len(self.config.layers)} layers")
        self._append(Axis(name, opts))
        return self

    def add_global(self, name: str, values: Sequence) -> "SearchSpace":
        self._append(Axis(name, tuple(values)))
        return self

    @classmethod
    def product_lhr(cls, config: AcceleratorConfig,
                    max_lhr: int = 256) -> "SearchSpace":
        """Per-layer power-of-two LHR product — the seed ``lhr_grid`` space."""
        return cls(config).add_per_layer(
            "lhr", [pow2_values(min(max_lhr, l.logical))
                    for l in config.layers])

    # ---- geometry ---------------------------------------------------------
    @property
    def size(self) -> int:
        n = 1
        for ax in self.axes:
            n *= ax.cardinality          # python int: no overflow
        return n if self.axes else 0

    # ---- decoding ---------------------------------------------------------
    def digits(self, flat_idx: np.ndarray) -> np.ndarray:
        """Mixed-radix digits (n, n_axes), last axis fastest."""
        idx = np.asarray(flat_idx, dtype=np.int64)
        out = np.empty((len(idx), len(self.axes)), dtype=np.int64)
        stride = 1
        for a in range(len(self.axes) - 1, -1, -1):
            card = self.axes[a].cardinality
            out[:, a] = (idx // stride) % card
            stride *= card
        return out

    def sample_digits(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Uniform random digit matrix — valid even for spaces past 2^63."""
        return np.stack([rng.integers(ax.cardinality, size=n)
                         for ax in self.axes], axis=1)

    def assemble(self, digits: np.ndarray) -> dict[str, np.ndarray]:
        """Digit matrix -> named column arrays, filling config defaults for
        layers no axis covers."""
        n = len(digits)
        n_layers = len(self.config.layers)
        cols: dict[str, np.ndarray] = {}
        for a, ax in enumerate(self.axes):
            vals = np.asarray(ax.values)
            picked = vals[digits[:, a]]              # (n,) or (n, L)
            if ax.is_vector:
                cols[ax.name] = picked
            elif ax.layer is None:
                cols[ax.name] = picked
            else:
                if ax.name not in cols:
                    default = _PER_LAYER_DEFAULTS.get(ax.name)
                    if default is None:
                        raise ValueError(f"no per-layer default for axis "
                                         f"{ax.name!r}")
                    base = [default(l) for l in self.config.layers]
                    cols[ax.name] = np.tile(
                        np.asarray(base, dtype=vals.dtype), (n, 1))
                cols[ax.name][:, ax.layer] = picked
        return cols

    def decode(self, flat_idx: np.ndarray) -> dict[str, np.ndarray]:
        """Chunk of flat candidate indices -> column arrays."""
        return self.assemble(self.digits(flat_idx))
