"""Legacy DSE API, rewired as thin wrappers over the streaming engine.

The seed engine's entry points (``sweep``, ``sweep_memory_blocks``,
``sweep_weight_bits``, ``lhr_grid``, ``Candidate``/``DSEResult``) keep their
exact signatures and numerics, but every evaluation now runs through the
chunked vectorised path — no per-candidate ``with_lhr`` materialization or
scalar ``energy_mj`` calls remain.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.accelerator import cycle_model, resources
from repro.core.accelerator.arch import AcceleratorConfig
from repro.core.dse.engine import search
from repro.core.dse.evaluate import evaluate_columns
from repro.core.dse.pareto import pareto_mask
from repro.core.dse.space import SearchSpace, pow2_values


@dataclasses.dataclass(frozen=True)
class Candidate:
    lhr: tuple[int, ...]
    cycles: float
    lut: float
    energy_mj: float
    pareto: bool = False


@dataclasses.dataclass
class DSEResult:
    config: AcceleratorConfig
    candidates: list[Candidate]

    @property
    def frontier(self) -> list[Candidate]:
        return [c for c in self.candidates if c.pareto]

    def best_within_latency(self, max_cycles: float) -> Optional[Candidate]:
        ok = [c for c in self.candidates if c.cycles <= max_cycles]
        return min(ok, key=lambda c: c.lut) if ok else None

    def best_within_area(self, max_lut: float) -> Optional[Candidate]:
        ok = [c for c in self.candidates if c.lut <= max_lut]
        return min(ok, key=lambda c: c.cycles) if ok else None

    def min_energy(self) -> Candidate:
        return min(self.candidates, key=lambda c: c.energy_mj)


def lhr_grid(cfg: AcceleratorConfig, max_lhr: int = 256,
             max_candidates: int = 200_000) -> np.ndarray:
    """All per-layer power-of-two LHR vectors (capped at layer size).

    Materializes the full (C, L) matrix, so it keeps the seed's candidate
    cap; for larger spaces build a ``SearchSpace`` and stream through
    ``search`` instead — there is no cap on that path.
    """
    axes = [pow2_values(min(max_lhr, layer.logical)) for layer in cfg.layers]
    n = int(np.prod([len(a) for a in axes]))
    if n > max_candidates:
        raise ValueError(f"{n} candidates exceed cap {max_candidates}; "
                         f"restrict max_lhr, sweep layerwise, or stream via "
                         f"dse.search(SearchSpace.product_lhr(cfg))")
    return np.array(list(itertools.product(*axes)), dtype=np.int64)


def sweep(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
          max_lhr: int = 256,
          lhr_matrix: Optional[np.ndarray] = None,
          chunk_size: int = 65536) -> DSEResult:
    """Evaluate every candidate LHR vector against a spike trace.

    ``counts``: per-layer (T,) traffic (trace or published averages).
    Evaluation is chunked and fully vectorised (including energy); the
    returned per-candidate object list is only built at the end, for
    compatibility.
    """
    lhr = np.asarray(lhr_matrix if lhr_matrix is not None
                     else lhr_grid(cfg, max_lhr), dtype=np.int64)
    n = len(lhr)
    cycles = np.empty(n)
    lut = np.empty(n)
    energy = np.empty(n)
    for s in range(0, n, chunk_size):
        m = evaluate_columns(cfg, counts, {"lhr": lhr[s:s + chunk_size]})
        cycles[s:s + chunk_size] = m["cycles"]
        lut[s:s + chunk_size] = m["lut"]
        energy[s:s + chunk_size] = m["energy"]
    mask = pareto_mask(cycles, lut)
    cands = [Candidate(lhr=tuple(int(x) for x in lhr[i]),
                       cycles=float(cycles[i]), lut=float(lut[i]),
                       energy_mj=float(energy[i]), pareto=bool(mask[i]))
             for i in range(n)]
    return DSEResult(config=cfg, candidates=cands)


def sweep_spike_train_length(cfg: AcceleratorConfig,
                             counts_per_t: dict[int, Sequence[np.ndarray]],
                             lhr: Sequence[int]) -> dict[int, float]:
    """Latency as a function of spike-train length T (paper Fig. 7b)."""
    out = {}
    c = cfg.with_lhr(lhr)
    for T, counts in counts_per_t.items():
        out[T] = float(cycle_model.latency_cycles(
            dataclasses.replace(c, num_steps=T), counts))
    return out


@dataclasses.dataclass(frozen=True)
class MemBlockCandidate:
    blocks: tuple[int, ...]      # memory blocks per layer
    cycles: float
    lut: float
    bram: int


def sweep_memory_blocks(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                        divisors: Sequence[int] = (1, 2, 4, 8)
                        ) -> list[MemBlockCandidate]:
    """Explore memory blocks per layer (paper Sec. IV: "modifications can be
    made to the hardware configuration (e.g. ... reduce the memory blocks)").

    Fewer blocks than NUs serialize weight reads (``LayerHW.contention``)
    but shrink the BRAM + mapping-logic budget.  A thin wrapper: one joint
    ``mem_blocks`` axis through the streaming engine.
    """
    options = [tuple(max(1, layer.num_nus // d) for layer in cfg.layers)
               for d in divisors]
    space = SearchSpace(cfg).add_joint("mem_blocks", options)
    res = search(cfg, counts, space=space,
                 objectives=("cycles", "lut", "bram"), keep_all=True)
    t = res.table
    return [MemBlockCandidate(
        blocks=tuple(int(x) for x in t.columns["mem_blocks"][i]),
        cycles=float(t.columns["cycles"][i]),
        lut=float(t.columns["lut"][i]),
        bram=int(t.columns["bram"][i])) for i in range(len(t))]


def sweep_weight_bits(cfg: AcceleratorConfig,
                      bits_options: Sequence[int] = (4, 6, 8, 12, 16)
                      ) -> dict[int, int]:
    """BRAM footprint vs synapse weight precision (paper Sec. III notes
    weight quantization "significantly affects the system's memory
    requirements").  Accuracy impact is measured separately with the
    fixed-point validator (``validate.quantized_accuracy``).  A thin
    wrapper over the batched resource path."""
    bits = np.asarray(bits_options, dtype=np.int64)
    bram = resources.estimate_vector(cfg, weight_bits=bits).bram36
    return {int(b): int(r) for b, r in zip(bits, bram)}
