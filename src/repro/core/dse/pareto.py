"""k-objective Pareto machinery with streaming (chunk-incremental) merge.

Dominance is *strict* Pareto dominance for minimization: point ``a``
dominates ``b`` iff ``all(a <= b)`` and ``any(a < b)``.  Exact duplicates
therefore never dominate each other and every copy of a non-dominated point
stays on the frontier — this is what makes the chunk-incremental merge
order-independent: the frontier of a stream equals the frontier of the
concatenation regardless of chunk boundaries (tests/test_dse.py proves the
equivalence on ties and duplicates).

All checks run blockwise so memory stays O(block * frontier) even when a
chunk holds tens of thousands of points.
"""
from __future__ import annotations

import zlib
from typing import Optional, Sequence

import numpy as np

from repro.core.dse.table import CandidateTable


def any_dominates(front: Optional[np.ndarray], points: np.ndarray,
                  block: int = 1024) -> np.ndarray:
    """(len(points),) bool — some row of ``front`` strictly dominates point.

    A point never dominates itself, so ``any_dominates(x, x)`` is the
    "dominated within x" mask (duplicates survive).
    """
    points = np.asarray(points, np.float64)
    out = np.zeros(len(points), dtype=bool)
    if front is None or len(front) == 0 or len(points) == 0:
        return out
    front = np.asarray(front, np.float64)
    k_objs = front.shape[1]
    for s in range(0, len(points), block):
        p = points[s:s + block]                              # (m, K)
        le = np.ones((len(front), len(p)), dtype=bool)
        lt = np.zeros((len(front), len(p)), dtype=bool)
        for k in range(k_objs):
            f_k = front[:, k:k + 1]
            le &= f_k <= p[:, k]
            lt |= f_k < p[:, k]
        out[s:s + block] = (le & lt).any(axis=0)
    return out


def frontier_of(objectives: np.ndarray, block: int = 4096) -> np.ndarray:
    """Frontier rows of an (N, K) objective matrix, streamed blockwise."""
    obj = np.asarray(objectives, np.float64)
    front = np.empty((0, obj.shape[1]))
    for s in range(0, len(obj), block):
        sub = obj[s:s + block]
        sub = sub[~any_dominates(front, sub)]
        sub = sub[~any_dominates(sub, sub)]
        front = np.concatenate([front[~any_dominates(sub, front)], sub])
    return front


def pareto_mask_k(objectives: np.ndarray, block: int = 4096) -> np.ndarray:
    """Non-dominated mask over an (N, K) objective matrix (minimize all).

    Builds the frontier incrementally then takes one membership pass, so the
    cost is O(N * frontier) and memory stays bounded for very large N.
    """
    obj = np.asarray(objectives, np.float64)
    if obj.ndim != 2:
        raise ValueError(f"objectives must be (N, K), got {obj.shape}")
    return ~any_dominates(frontier_of(obj, block), obj)


def pareto_mask(cycles: np.ndarray, lut: np.ndarray) -> np.ndarray:
    """Two-objective non-dominated mask (the seed engine's signature).

    Unlike the seed implementation this keeps *every* copy of a duplicated
    frontier point (strict dominance), which is required for the streaming
    merge to be chunk-order independent.
    """
    return pareto_mask_k(np.stack([np.asarray(cycles, np.float64),
                                   np.asarray(lut, np.float64)], axis=1))


def _col_as_f64(v: np.ndarray) -> np.ndarray:
    """Column as float64 for duplicate keying.  Non-numeric columns (the
    ``dataset`` model axis is a string column) map through crc32 — a
    deterministic, process-independent code that is exact in float64."""
    v = np.asarray(v)
    if v.dtype.kind in "USO":
        crc = np.frompyfunc(lambda s: float(zlib.crc32(str(s).encode())), 1, 1)
        return crc(v).astype(np.float64)
    return v.astype(np.float64)


def _row_keys(table: CandidateTable, idx: np.ndarray | None = None
              ) -> np.ndarray:
    """Rows flattened across ALL columns, for exact-duplicate detection."""
    cols = []
    for k in sorted(table.columns):
        v = _col_as_f64(table.columns[k]).reshape(len(table), -1)
        cols.append(v if idx is None else v[idx])
    return np.ascontiguousarray(np.concatenate(cols, axis=1))


def _rows_in(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(len(a),) bool — row of ``a`` appears (exactly) among rows of ``b``."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros(len(a), dtype=bool)
    dt = [("", a.dtype)] * a.shape[1]
    return np.isin(a.view(dt).ravel(), np.ascontiguousarray(b).view(dt).ravel())


class ParetoAccumulator:
    """Incremental k-objective Pareto merge over CandidateTable chunks.

    Feed arbitrarily many chunks through :meth:`update`; only frontier rows
    are retained, so an unbounded stream evaluates in bounded memory.  The
    final :attr:`frontier` equals (as a row set) the frontier of a
    monolithic evaluation of the concatenated chunks.  Distinct candidates
    with tied objectives all survive, but exact full-row duplicates — the
    same candidate re-evaluated, as Random/EvolutionarySearch routinely do
    — are kept once, so frontier size never inflates with re-visits.
    """

    def __init__(self, objectives: Sequence[str]):
        if not objectives:
            raise ValueError("need at least one objective column name")
        self.objectives = tuple(objectives)
        self._table: Optional[CandidateTable] = None
        self._obj: Optional[np.ndarray] = None               # (F, K)

    def update(self, table: CandidateTable) -> bool:
        """Merge one chunk; returns True when the frontier changed (rows
        added and/or dominated rows dropped) — the signal streaming
        frontier consumers (``repro.serve.dse_service``) key events on."""
        if len(table) == 0:
            return False
        obj = np.stack([np.asarray(table.columns[k], np.float64)
                        for k in self.objectives], axis=1)
        idx = np.flatnonzero(~any_dominates(self._obj, obj))
        local = pareto_mask_k(obj[idx])
        idx = idx[local]
        # drop exact re-evaluations: within the chunk ...
        keys = _row_keys(table, idx)
        _, first = np.unique(keys, axis=0, return_index=True)
        first.sort()
        idx, keys = idx[first], keys[first]
        # ... and against the retained frontier
        if self._table is not None and len(self._table):
            fresh = ~_rows_in(keys, _row_keys(self._table))
            idx = idx[fresh]
        sub = obj[idx]
        if self._table is None:
            self._table, self._obj = table.take(idx), sub
            return len(idx) > 0
        old_keep = ~any_dominates(sub, self._obj)
        changed = len(idx) > 0 or not old_keep.all()
        self._table = CandidateTable.concat(
            [self._table.take(old_keep), table.take(idx)])
        self._obj = np.concatenate([self._obj[old_keep], sub])
        return changed

    @property
    def frontier(self) -> CandidateTable:
        if self._table is None:
            return CandidateTable({})
        return self._table
