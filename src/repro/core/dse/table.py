"""Structure-of-arrays candidate storage.

A ``CandidateTable`` is a dict of equal-length NumPy columns — configuration
axes (``lhr``/``mem_blocks`` are (N, L), global axes like ``weight_bits`` or
``clock_mhz`` may be (N,)) next to metric columns (``cycles``, ``lut``,
``reg``, ``bram``, ``dsp``, ``energy``, all (N,)).  No per-candidate Python
objects exist anywhere in the search path; a 200k-candidate chunk is a
handful of arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class CandidateTable:
    columns: dict[str, np.ndarray]

    def __post_init__(self):
        lens = {k: len(v) for k, v in self.columns.items()}
        if len(set(lens.values())) > 1:
            raise ValueError(f"ragged columns: {lens}")

    def __len__(self) -> int:
        for v in self.columns.values():
            return len(v)
        return 0

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.columns.values())

    def take(self, idx) -> "CandidateTable":
        """Row subset by boolean mask or integer index array."""
        idx = np.asarray(idx)
        return CandidateTable({k: v[idx] for k, v in self.columns.items()})

    @staticmethod
    def concat(tables: Iterable["CandidateTable"]) -> "CandidateTable":
        tables = [t for t in tables if t.columns]
        if not tables:
            return CandidateTable({})
        keys = tables[0].columns.keys()
        for t in tables[1:]:
            if t.columns.keys() != keys:
                raise ValueError(f"column mismatch: {sorted(keys)} vs "
                                 f"{sorted(t.columns.keys())}")
        return CandidateTable({k: np.concatenate([t.columns[k] for t in tables])
                               for k in keys})

    def row(self, i: int) -> dict:
        """One candidate as plain Python values (tuples for per-layer cols)."""
        out = {}
        for k, v in self.columns.items():
            if v.ndim == 2:
                out[k] = tuple(v[i].tolist())
            else:
                out[k] = v[i].item()
        return out

    def argsort(self, key: str) -> np.ndarray:
        return np.argsort(self.columns[key], kind="stable")

    def sorted_by(self, key: str) -> "CandidateTable":
        return self.take(self.argsort(key))

    def argmin(self, key: str) -> int:
        return int(np.argmin(self.columns[key]))
