"""Unified ask/tell exploration front end: ``explore(...) -> Study``.

One driver subsumes the hardware-only ``search`` loop and the model-hardware
``coexplore`` loop.  The strategy (``dse.strategies``) owns only the
*choice* of candidates through the pull-style ``ask(n)``/``tell(digits,
obj)`` contract; the ``Study`` driver owns chunked evaluation, the
incremental Pareto merge, model-cell resolution through the workload trace
cache, training-budget accounting, checkpoint/resume, and worker farming.

Three driver modes, picked from the space and strategy:

* **hardware** — no model axes: digits assemble against one fixed
  ``AcceleratorConfig`` and stream through the chunked evaluator.  This is
  ``dse.search`` (now an exact thin wrapper).
* **cells** — model axes with ``GridSearch``: the joint space factors into
  (model cell) x (hardware subspace) and every cell's subspace is swept
  exhaustively — ``dse.coexplore``'s classic behaviour, one cell per
  ``step()``.
* **joint** — model axes with ``RandomSearch``/``EvolutionarySearch``: the
  strategy samples digits over the *full* joint space (model axes
  included).  The driver groups each asked chunk by model cell, resolves
  new cells through the cache, and charges a **training budget in cache
  misses** (``train_budget=k``): once the budget is spent, candidates in
  untrained cells are returned to the strategy as ``+inf`` rows instead of
  being trained — the NAS-style loop where the search decides which
  expensive network evaluations to spend (cache hits stay free).  Per-cell
  subspace rebinding keeps template digit cardinalities
  (``hardware_subspace(cfg, dedup=False)``), so one digit encoding is valid
  in every cell.

``Study`` is checkpointable (``checkpoint/store.py`` holds the frontier
arrays; a ``study.json`` sidecar holds strategy RNG state, cursors,
evaluated count, budget, and cell records) and resumable via
``explore(..., checkpoint_dir=..., resume=True)`` — cells never retrain on
resume because the trace cache is content-addressed.  ``workers=N`` shards
pending cell training across processes (``repro.distributed.cellfarm``),
safe because the cache publish is atomic.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.checkpoint import store
from repro.core import workloads
from repro.core.accelerator import arch, cycle_model, resources
from repro.core.dse.evaluate import AXIS_NAMES, METRICS, evaluate_columns
from repro.core.dse.pareto import ParetoAccumulator
from repro.core.dse.space import MODEL_AXES, SearchSpace, iter_cells
from repro.core.dse.strategies import GridSearch, Strategy
from repro.core.dse.table import CandidateTable
from repro.core.workloads import TraceCache, TrainingBudget, Workload
from repro.distributed import cellfarm

DEFAULT_OBJECTIVES = ("cycles", "lut", "bram", "energy")
DEFAULT_CO_OBJECTIVES = ("error", "cycles", "lut", "energy")

#: metric columns a co-exploration row carries beyond the hardware METRICS
CO_METRICS = METRICS + ("accuracy", "error")

HwSpaceFn = Callable[[arch.AcceleratorConfig], SearchSpace]

_SIDECAR = "study.json"


class FrontierQueries:
    """Query surface shared by every result that retains a Pareto frontier
    (and optionally the full table): expects ``objectives``, ``frontier``
    and ``table`` attributes on the subclass."""

    objectives: tuple[str, ...]
    frontier: CandidateTable
    table: Optional[CandidateTable]

    def _rows(self, needed: Sequence[str]) -> CandidateTable:
        """Full table when kept; else the frontier — which is only a valid
        search set when every queried column was a search objective (a
        non-objective optimum may live off-frontier)."""
        if self.table is not None:
            return self.table
        missing = [c for c in needed if c not in self.objectives]
        if missing:
            raise ValueError(
                f"columns {missing} were not search objectives "
                f"{self.objectives}; the retained frontier is only optimal "
                f"over the objectives — re-search with them included, or "
                f"with keep_all=True")
        return self.frontier

    def best_under(self, minimize: str, **caps: float) -> Optional[dict]:
        """Row minimizing ``minimize`` among rows with col <= cap for every
        kwarg — e.g. ``best_under("lut", cycles=20e3)``."""
        t = self._rows((minimize, *caps))
        if len(t) == 0:
            return None
        ok = np.ones(len(t), dtype=bool)
        for col, cap in caps.items():
            ok &= np.asarray(t.columns[col], np.float64) <= cap
        if not ok.any():
            return None
        sub = t.take(ok)
        return sub.row(sub.argmin(minimize))


@dataclasses.dataclass
class CellRecord:
    """One resolved model cell and its hardware sub-sweep summary."""
    workload: str
    assignment: dict                     # model-axis values for this cell
    key: str                             # trace-cache content address
    accuracy: float                      # float-datapath accuracy
    quant_acc: dict[int, float]          # weight_bits -> fixed-point accuracy
    cache_hit: bool
    n_evaluated: int                     # hardware candidates streamed
    layer_sizes: list[int]


def _model_axis_list(space: Optional[SearchSpace],
                     workload: Optional[Union[str, Workload]],
                     num_steps, population, datasets,
                     resolve: Callable[[Union[str, Workload]], Workload]
                     ) -> list[tuple]:
    """Canonical (name, values) list in MODEL_AXES order."""
    if space is not None and space.model_axes:
        given = [n for n, v in (("num_steps", num_steps),
                                ("population", population),
                                ("datasets", datasets)) if v is not None]
        if given:
            raise ValueError(
                f"model axes declared both in the space "
                f"({[ax.name for ax in space.model_axes]}) and via kwargs "
                f"{given}; pick one declaration style")
        by_name = {ax.name: tuple(ax.values) for ax in space.model_axes}
        if "dataset" in by_name:          # normalize instances to names
            by_name["dataset"] = tuple(
                resolve(d).name for d in by_name["dataset"])
    else:
        by_name = {}
        if datasets is not None:
            by_name["dataset"] = tuple(resolve(d).name for d in datasets)
        if num_steps is not None:
            by_name["num_steps"] = tuple(int(t) for t in num_steps)
        if population is not None:
            by_name["population"] = tuple(float(p) for p in population)
    if "num_steps" not in by_name:
        wls = ([resolve(d) for d in by_name["dataset"]]
               if "dataset" in by_name else [resolve(workload)])
        choices = {wl.name: tuple(wl.num_steps_choices) for wl in wls}
        if len(set(choices.values())) > 1:
            raise ValueError(
                f"the swept workloads declare different num_steps_choices "
                f"({choices}); pass num_steps=... explicitly")
        by_name["num_steps"] = next(iter(choices.values()))
    return [(n, by_name[n]) for n in MODEL_AXES if n in by_name]


def _bits_values(sub: SearchSpace) -> list[int]:
    vals: set[int] = set()
    for ax in sub.axes:
        if ax.name != "weight_bits":
            continue
        for v in ax.values:
            if ax.is_vector:
                vals.update(int(x) for x in v)
            else:
                vals.add(int(v))
    return sorted(vals)


def _row_bits(cols: dict[str, np.ndarray]) -> Optional[np.ndarray]:
    """Per-candidate effective weight precision: the global column, or the
    per-layer minimum (the precision that bounds datapath accuracy)."""
    wb = cols.get("weight_bits")
    if wb is None:
        return None
    wb = np.asarray(wb)
    return wb.min(axis=1) if wb.ndim == 2 else wb


def _pad_layers(col: np.ndarray, width: int) -> np.ndarray:
    """Pad a (n, L) per-layer column to (n, width) with -1 (absent layer)."""
    if col.ndim != 2 or col.shape[1] == width:
        return col
    pad = np.full((len(col), width - col.shape[1]), -1, dtype=col.dtype)
    return np.concatenate([col, pad], axis=1)


def _check_subspace(sub: SearchSpace, what: str) -> None:
    if sub.model_axes:
        raise ValueError("hardware subspace must not contain model axes")
    if not sub.axes:
        raise ValueError(f"hardware subspace for {what} has no "
                         f"axes — nothing to sweep")
    unknown = {ax.name for ax in sub.axes} - AXIS_NAMES
    if unknown:
        raise ValueError(f"hardware subspace for {what} has axes "
                         f"{sorted(unknown)} the evaluator does not "
                         f"know; known: {sorted(AXIS_NAMES)}")


@dataclasses.dataclass
class _LiveCell:
    """A resolved model cell's in-memory evaluation context."""
    record: CellRecord
    assignment: dict                  # model-axis values, dataset as name
    accel: arch.AcceleratorConfig
    sub: SearchSpace                  # rebound hw subspace (template digits)
    counts: list[np.ndarray]
    accuracy: float
    quant_acc: dict[int, float]


class Study(FrontierQueries):
    """A (possibly in-flight) exploration: frontier so far, evaluated count,
    resolved model cells, budget/cache accounting, and the lifecycle verbs
    ``step``/``run``/``checkpoint``.  Construct through ``explore``."""

    def __init__(self, *, mode: str, space: Optional[SearchSpace],
                 strategy: Strategy, objectives: tuple[str, ...],
                 chunk_size: int, keep_all: bool,
                 lib: Optional[resources.CostLibrary],
                 # hardware mode
                 config: Optional[arch.AcceleratorConfig] = None,
                 counts: Optional[Sequence[np.ndarray]] = None,
                 # cells / joint modes
                 cache: Optional[TraceCache] = None,
                 budget: Optional[TrainingBudget] = None,
                 seed: int = 0,
                 resolve_wl: Optional[Callable] = None,
                 model_axes: Optional[list[tuple]] = None,
                 cell_plan: Optional[list[tuple]] = None,
                 l_max: int = 0,
                 workers: Union[int, str] = 0,
                 stack: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: Optional[int] = None):
        self.mode = mode
        self.space = space
        self.strategy = strategy
        self.objectives = tuple(objectives)
        self.chunk_size = chunk_size
        self.keep_all = keep_all
        self.lib = lib
        self.config = config
        self.counts = counts
        self.cache = cache
        self.budget = budget
        self.seed = seed
        self.workers = workers
        self.stack = stack
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self._resolve_wl = resolve_wl
        self._model_axes = model_axes or []
        self._cell_plan = cell_plan or []       # cells mode prepass output
        self._l_max = l_max

        self.done = False
        self.n_evaluated = 0
        self.rounds = 0
        self.cells: list[CellRecord] = []
        self.skipped: list[dict] = []
        self.farmed_misses = 0
        #: bumped whenever the Pareto frontier actually changes — streaming
        #: consumers (repro.serve.dse_service) diff this across steps
        #: instead of comparing frontier tables
        self.frontier_version = 0
        #: cooperative-stepping hooks: each is called as ``fn(study)`` after
        #: every counted step (and never after the terminal False step)
        self.listeners: list[Callable[["Study"], None]] = []
        self._acc = ParetoAccumulator(self.objectives)
        self._kept: Optional[list[CandidateTable]] = [] if keep_all else None
        self._table: Optional[CandidateTable] = None
        self._cell_cursor = 0                   # cells mode
        self._prefetched = False
        self._live: dict[str, Optional[_LiveCell]] = {}   # joint mode
        if mode in ("hardware", "joint"):
            strategy.bind(space, self.objectives)

    # ---- results -----------------------------------------------------------
    @property
    def frontier(self) -> CandidateTable:
        return self._acc.frontier

    @property
    def table(self) -> Optional[CandidateTable]:
        if self._kept is None:
            return None
        if self._table is None or len(self._table) != sum(
                len(t) for t in self._kept):
            self._table = CandidateTable.concat(self._kept)
        return self._table

    @property
    def cache_stats(self) -> dict:
        stats = dict(self.cache.stats) if self.cache is not None else {}
        if self.cache is not None:
            stats["farmed_misses"] = self.farmed_misses
        return stats

    @property
    def summary(self) -> dict:
        """Auditable run summary: evaluation counts, workload-cache hit/miss
        counters, and the remaining training budget."""
        out = {"mode": self.mode, "done": self.done,
               "n_evaluated": self.n_evaluated,
               "frontier_size": len(self.frontier),
               "rounds": self.rounds}
        if self.cache is not None:
            out["cells_resolved"] = len(self.cells)
            out["cells_skipped"] = len(self.skipped)
            out["cache"] = self.cache_stats
            out["train_budget"] = (
                None if self.budget is None else
                {"limit": self.budget.limit, "spent": self.budget.spent,
                 "remaining": self.budget.remaining})
        return out

    # ---- lifecycle ---------------------------------------------------------
    def run(self) -> "Study":
        """Drive to completion, checkpointing every ``checkpoint_every``
        steps (and once at the end) when a checkpoint_dir is set."""
        while self.step():
            if (self.checkpoint_dir and self.checkpoint_every
                    and self.rounds % self.checkpoint_every == 0):
                self.checkpoint()
        if self.checkpoint_dir:
            self.checkpoint()
        return self

    def step(self) -> bool:
        """One unit of work: an ask/evaluate/tell round (hardware/joint
        modes) or one full model cell (cells mode).  False when done."""
        if self.done:
            return False
        if self.mode == "cells":
            advanced = self._step_cells()
        else:
            advanced = self._step_ask_tell()
        if advanced:
            self.rounds += 1
            for fn in self.listeners:
                fn(self)
        else:
            self.done = True
        return advanced

    # ---- hardware + joint rounds ------------------------------------------
    def _step_ask_tell(self) -> bool:
        digits = self.strategy.ask(self.chunk_size)
        if len(digits) == 0:
            return False
        if self.mode == "hardware":
            obj = self._evaluate_hardware(digits)
        else:
            obj = self._evaluate_joint(digits)
        self.strategy.tell(digits, obj)
        return True

    def _objective_matrix(self, chunk: CandidateTable) -> np.ndarray:
        return np.stack([np.asarray(chunk.columns[k], np.float64)
                         for k in self.objectives], axis=1)

    def _accumulate(self, chunk: CandidateTable) -> None:
        if self._acc.update(chunk):
            self.frontier_version += 1
        if self._kept is not None:
            self._kept.append(chunk)
        self.n_evaluated += len(chunk)

    def _evaluate_hardware(self, digits: np.ndarray) -> np.ndarray:
        cols = self.space.assemble(digits)
        metrics = evaluate_columns(self.config, self.counts, cols,
                                   lib=self.lib)
        chunk = CandidateTable({**cols, **metrics})
        self._accumulate(chunk)
        return self._objective_matrix(chunk)

    # ---- joint (candidate-major) mode -------------------------------------
    def _evaluate_joint(self, digits: np.ndarray) -> np.ndarray:
        model_d, hw_d = self.space.split_digits(digits)
        obj = np.full((len(digits), len(self.objectives)), np.inf)
        # np.unique gives a deterministic (lexicographic) cell order, so the
        # budget spends identically across runs and worker counts
        uniq, inverse = np.unique(model_d, axis=0, return_inverse=True)
        self._farm_chunk(uniq)
        for u, row in enumerate(uniq):
            cell = self._joint_cell(row)
            if cell is None:
                continue                        # over budget: rows stay +inf
            idx = np.flatnonzero(inverse == u)
            cols = cell.sub.assemble(hw_d[idx])
            metrics = evaluate_columns(cell.accel, cell.counts, cols,
                                       lib=self.lib)
            chunk = self._joint_chunk(cell, cols, metrics)
            self._accumulate(chunk)
            cell.record.n_evaluated += len(idx)
            obj[idx] = self._objective_matrix(chunk)
        return obj

    def _joint_chunk(self, cell: _LiveCell, cols: dict,
                     metrics: dict) -> CandidateTable:
        n = len(next(iter(metrics.values())))
        row_bits = _row_bits(cols)
        if row_bits is None or not cell.quant_acc:
            acc_col = np.full(n, cell.accuracy)
        else:
            uniq = np.unique(row_bits)
            by_bits = np.array([cell.quant_acc.get(int(b), cell.accuracy)
                                for b in uniq])
            acc_col = by_bits[np.searchsorted(uniq, row_bits)]
        out_cols = {k: (_pad_layers(v, self._l_max) if v.ndim == 2 else v)
                    for k, v in cols.items()}
        for name, v in cell.assignment.items():
            out_cols[name] = np.full(
                n, v, dtype=(np.int64 if name == "num_steps" else
                             np.float64 if name == "population" else None))
        return CandidateTable({**out_cols, **metrics,
                               "accuracy": acc_col, "error": 1.0 - acc_col})

    def _cell_assignment(self, model_row: np.ndarray) -> dict:
        """Model digit row -> assignment dict, dataset normalized to name."""
        raw = self.space.model_assignment(model_row)
        if "dataset" in raw:
            raw["dataset"] = self._resolve_wl(raw["dataset"]).name
        if "num_steps" in raw:
            raw["num_steps"] = int(raw["num_steps"])
        if "population" in raw:
            raw["population"] = float(raw["population"])
        return raw

    def _digit_key(self, model_row) -> str:
        return ",".join(str(int(d)) for d in model_row)

    def _joint_cell(self, model_row: np.ndarray) -> Optional[_LiveCell]:
        """Resolve (or look up) the cell for one model digit row; None when
        the cell was skipped for budget (and it stays skipped for the whole
        study, so a resumed run matches an uninterrupted one)."""
        key = self._digit_key(model_row)
        if key in self._live:
            return self._live[key]
        assignment = self._cell_assignment(model_row)
        wl = (self._resolve_wl(assignment["dataset"])
              if "dataset" in assignment else self._resolve_wl(None))
        cell_asn = {"num_steps": assignment["num_steps"],
                    "population": assignment.get("population", 1.0)}
        affordable = (self.budget is None or self.budget.can_spend()
                      or self.cache.contains(wl, cell_asn, seed=self.seed))
        if not affordable:
            self.skipped.append({"workload": wl.name, **assignment})
            self._live[key] = None
            return None
        cell = self._materialize(wl, assignment, cell_asn)
        self._live[key] = cell
        self.cells.append(cell.record)
        return cell

    def _materialize(self, wl: Workload, assignment: dict,
                     cell_asn: dict,
                     record: Optional[CellRecord] = None) -> _LiveCell:
        """Build a cell's evaluation context, training through the cache if
        needed.  ``record`` is passed on resume to keep the original
        cache_hit/n_evaluated bookkeeping."""
        snn_cfg = wl.build(int(cell_asn["num_steps"]),
                           float(cell_asn["population"]))
        accel = arch.from_snn_config(snn_cfg)
        sub = self.space.hardware_subspace(accel, dedup=False)
        _check_subspace(sub, f"cell {assignment}")
        bits = _bits_values(sub)
        artifact = self.cache.resolve(wl, cell_asn, seed=self.seed,
                                      quant_bits=bits,
                                      budget=self.budget)
        if record is None:
            record = CellRecord(
                workload=wl.name, assignment=dict(assignment),
                key=artifact.key, accuracy=artifact.accuracy,
                quant_acc=dict(artifact.quant_acc),
                cache_hit=artifact.cache_hit, n_evaluated=0,
                layer_sizes=snn_cfg.layer_sizes())
        return _LiveCell(record=record, assignment=assignment, accel=accel,
                         sub=sub,
                         counts=cycle_model.counts_from_traces(
                             artifact.counts),
                         accuracy=artifact.accuracy,
                         quant_acc=dict(artifact.quant_acc))

    @property
    def _farming(self) -> bool:
        """True when pending cells should resolve out-of-process first: a
        usable process pool (``workers >= 2``), the fleet
        (``workers="cluster"``), or in-process stacking."""
        return (self.workers == "cluster" or self.stack
                or (isinstance(self.workers, int) and self.workers >= 2))

    def _farm_chunk(self, uniq_model_rows: np.ndarray) -> None:
        """Train this chunk's unresolved, affordable cells across worker
        processes — vmapped same-signature stacks with ``stack=True``, or
        the lease-coordinated fleet with ``workers="cluster"`` — before
        the serial resolution loop (joint mode)."""
        if not self._farming:
            return
        jobs, keys = [], []
        afford = (self.budget.remaining if self.budget is not None
                  else len(uniq_model_rows))
        for row in uniq_model_rows:
            key = self._digit_key(row)
            if key in self._live:
                continue
            assignment = self._cell_assignment(row)
            wl = (self._resolve_wl(assignment["dataset"])
                  if "dataset" in assignment else self._resolve_wl(None))
            cell_asn = {"num_steps": assignment["num_steps"],
                        "population": assignment.get("population", 1.0)}
            if self.cache.contains(wl, cell_asn, seed=self.seed):
                continue
            if len(jobs) >= afford:
                break
            sub = self.space.hardware_subspace(
                arch.from_snn_config(wl.build(
                    int(cell_asn["num_steps"]), cell_asn["population"])),
                dedup=False)
            jobs.append(cellfarm.CellJob(
                workload=wl, assignment=cell_asn, seed=self.seed,
                quant_bits=tuple(_bits_values(sub))))
            keys.append(key)
        self._charge_farmed(cellfarm.resolve_cells(
            jobs, self.cache.root, workers=self.workers, stack=self.stack))

    def _charge_farmed(self, outcomes: list) -> None:
        for out in outcomes:
            if out.error is not None:
                # the farm gave up on this cell after bounded retries
                # (cellfarm.CellOutcome.error); nothing was published and
                # nothing is charged — the serial resolution path below
                # trains it in-process (or skips it for budget) instead of
                # the whole study dying on one bad worker
                continue
            if out.trained:
                self.farmed_misses += 1
                if self.budget is not None:
                    self.budget.charge()

    # ---- cells (cell-major grid) mode -------------------------------------
    def _step_cells(self) -> bool:
        self._prefetch_cells()
        while self._cell_cursor < len(self._cell_plan):
            cell, wl, snn_cfg, accel, sub = \
                self._cell_plan[self._cell_cursor]
            self._cell_cursor += 1
            cell_asn = {"num_steps": int(cell["num_steps"]),
                        "population": float(cell.get("population", 1.0))}
            if (self.budget is not None and not self.budget.can_spend()
                    and not self.cache.contains(wl, cell_asn,
                                                seed=self.seed)):
                self.skipped.append({"workload": wl.name, **cell})
                continue
            self._sweep_cell(cell, wl, snn_cfg, accel, sub, cell_asn)
            return True
        return False

    def _sweep_cell(self, cell, wl, snn_cfg, accel, sub, cell_asn) -> None:
        bits = _bits_values(sub)
        artifact = self.cache.resolve(wl, cell_asn, seed=self.seed,
                                      quant_bits=bits, budget=self.budget)
        live = _LiveCell(
            record=CellRecord(
                workload=wl.name, assignment=dict(cell), key=artifact.key,
                accuracy=artifact.accuracy,
                quant_acc=dict(artifact.quant_acc),
                cache_hit=artifact.cache_hit, n_evaluated=0,
                layer_sizes=snn_cfg.layer_sizes()),
            assignment=dict(cell), accel=accel, sub=sub,
            counts=cycle_model.counts_from_traces(artifact.counts),
            accuracy=artifact.accuracy, quant_acc=dict(artifact.quant_acc))
        inner = GridSearch(self.chunk_size)
        inner.bind(sub, self.objectives)
        while True:
            digits = inner.ask(self.chunk_size)
            if len(digits) == 0:
                break
            cols = sub.assemble(digits)
            metrics = evaluate_columns(accel, live.counts, cols,
                                       lib=self.lib)
            chunk = self._joint_chunk(live, cols, metrics)
            self._accumulate(chunk)
            live.record.n_evaluated += len(digits)
            inner.tell(digits, self._objective_matrix(chunk))
        self.cells.append(live.record)

    def _prefetch_cells(self) -> None:
        """Farm the cell plan's pending training across worker processes —
        vmapped same-signature stacks with ``stack=True``, or the fleet
        with ``workers="cluster"`` (cells mode); afterwards every
        prefetched cell resolves as a hit."""
        if self._prefetched or not self._farming:
            return
        self._prefetched = True
        jobs = []
        afford = (self.budget.remaining if self.budget is not None
                  else len(self._cell_plan))
        for cell, wl, _snn_cfg, _accel, sub in \
                self._cell_plan[self._cell_cursor:]:
            cell_asn = {"num_steps": int(cell["num_steps"]),
                        "population": float(cell.get("population", 1.0))}
            if self.cache.contains(wl, cell_asn, seed=self.seed):
                continue
            if len(jobs) >= afford:
                break
            jobs.append(cellfarm.CellJob(
                workload=wl, assignment=cell_asn, seed=self.seed,
                quant_bits=tuple(_bits_values(sub))))
        self._charge_farmed(cellfarm.resolve_cells(
            jobs, self.cache.root, workers=self.workers, stack=self.stack))

    # ---- checkpoint / resume ----------------------------------------------
    def _signature(self) -> str:
        """Stable hash of the search definition, so a resumed study refuses
        a different space/objectives/strategy."""
        if self.space is not None:
            sig = self.space.signature()
        else:                                   # cells mode, kwargs path
            sig = [[n, None, [str(v) for v in vals]]
                   for n, vals in self._model_axes]
            sig += [sub.signature() for _, _, _, _, sub in self._cell_plan]
        blob = json.dumps({"sig": sig, "objectives": list(self.objectives),
                           "strategy": type(self.strategy).__name__,
                           "strategy_config": self.strategy.signature(),
                           "mode": self.mode, "seed": self.seed},
                          sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    def checkpoint(self, directory: Optional[str] = None) -> str:
        """Persist the study state: frontier arrays through the atomic
        checkpoint store, everything else (strategy RNG state, cursors,
        budget, cell records) in a ``study.json`` sidecar written last —
        its presence marks a complete checkpoint.  Each checkpoint writes a
        fresh step directory (numbered by round) and prunes older ones only
        *after* the sidecar publishes, so a crash mid-checkpoint always
        leaves the previous (sidecar, arrays) pair intact and consistent.

        Cells mode sweeps each cell with its own inner grid, so the outer
        strategy holds no state there — only the cell cursor is recorded.
        """
        directory = directory or self.checkpoint_dir
        if directory is None:
            raise ValueError("no checkpoint directory: pass one here or as "
                             "explore(checkpoint_dir=...)")
        front = self.frontier.columns
        numeric = {k: np.asarray(v) for k, v in front.items()
                   if np.asarray(v).dtype.kind not in "USO"}
        strings = {k: np.asarray(v).tolist() for k, v in front.items()
                   if np.asarray(v).dtype.kind in "USO"}
        step = int(self.rounds)
        store.save(directory, step, {"frontier": numeric})
        meta = {
            "version": 1,
            "signature": self._signature(),
            "mode": self.mode,
            "done": self.done,
            "objectives": list(self.objectives),
            "n_evaluated": int(self.n_evaluated),
            "rounds": int(self.rounds),
            "frontier_step": step,
            "farmed_misses": int(self.farmed_misses),
            "strategy": {"class": type(self.strategy).__name__,
                         "state": (self.strategy.state_dict()
                                   if self.mode != "cells" else {})},
            "budget": (None if self.budget is None
                       else self.budget.state_dict()),
            "cell_cursor": int(self._cell_cursor),
            "cells": [self._record_dict(r) for r in self.cells],
            "skipped": list(self.skipped),
            "resolved": {k: (None if v is None else
                             self.cells.index(v.record))
                         for k, v in self._live.items()},
            "frontier": {
                "numeric": {k: {"dtype": str(v.dtype),
                                "shape": list(v.shape)}
                            for k, v in numeric.items()},
                "strings": strings,
            },
        }
        tmp = os.path.join(directory, _SIDECAR + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(directory, _SIDECAR))
        for old in store.all_steps(directory):      # prune after publish
            if old != step:
                shutil.rmtree(os.path.join(directory, f"step_{old:08d}"),
                              ignore_errors=True)
        return directory

    @staticmethod
    def _record_dict(r: CellRecord) -> dict:
        return {"workload": r.workload, "assignment": r.assignment,
                "key": r.key, "accuracy": r.accuracy,
                "quant_acc": {str(b): a for b, a in r.quant_acc.items()},
                "cache_hit": r.cache_hit, "n_evaluated": r.n_evaluated,
                "layer_sizes": list(r.layer_sizes)}

    def load(self, directory: str) -> "Study":
        """Restore a checkpointed study into this (freshly constructed,
        identically configured) instance."""
        path = os.path.join(directory, _SIDECAR)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no study checkpoint under {directory}")
        with open(path) as f:
            meta = json.load(f)
        if meta["signature"] != self._signature():
            raise ValueError(
                "checkpoint was written for a different study (space axes, "
                "objectives, strategy, mode, or seed differ) — resume with "
                "the arguments the study was started with")
        like = {"frontier": {
            k: np.zeros(m["shape"], dtype=np.dtype(m["dtype"]))
            for k, m in meta["frontier"]["numeric"].items()}}
        tree = store.restore(directory, like,
                             step=int(meta["frontier_step"]), device=False)
        cols = {k: np.asarray(v) for k, v in tree["frontier"].items()}
        for k, vals in meta["frontier"]["strings"].items():
            cols[k] = np.asarray(vals)
        if cols and self._acc.update(CandidateTable(cols)):
            self.frontier_version += 1
        self.done = bool(meta["done"])
        self.n_evaluated = int(meta["n_evaluated"])
        self.rounds = int(meta["rounds"])
        self.farmed_misses = int(meta["farmed_misses"])
        if self.mode != "cells":
            self.strategy.load_state_dict(meta["strategy"]["state"])
        if self.budget is not None and meta["budget"] is not None:
            self.budget.load_state_dict(meta["budget"])
        self._cell_cursor = int(meta["cell_cursor"])
        self.cells = [CellRecord(
            workload=d["workload"], assignment=d["assignment"],
            key=d["key"], accuracy=d["accuracy"],
            quant_acc={int(b): a for b, a in d["quant_acc"].items()},
            cache_hit=d["cache_hit"], n_evaluated=d["n_evaluated"],
            layer_sizes=d["layer_sizes"]) for d in meta["cells"]]
        self.skipped = list(meta["skipped"])
        for key, idx in meta["resolved"].items():
            if idx is None:
                self._live[key] = None
            else:
                rec = self.cells[idx]
                wl = self._resolve_wl(rec.workload)
                asn = dict(rec.assignment)
                cell_asn = {"num_steps": int(asn["num_steps"]),
                            "population": float(asn.get("population", 1.0))}
                self._live[key] = self._materialize(wl, asn, cell_asn,
                                                    record=rec)
        return self


def explore(space: Optional[SearchSpace] = None, *,
            # hardware-only evaluation context
            config: Optional[arch.AcceleratorConfig] = None,
            counts: Optional[Sequence[np.ndarray]] = None,
            # model-cell resolution context
            workload: Union[str, Workload, None] = None,
            datasets: Optional[Sequence[Union[str, Workload]]] = None,
            num_steps: Optional[Sequence[int]] = None,
            population: Optional[Sequence[float]] = None,
            hw_space: Optional[HwSpaceFn] = None,
            max_lhr: Optional[int] = None,
            weight_bits: Optional[Sequence[int]] = None,
            cache: Optional[TraceCache] = None,
            seed: int = 0,
            train_budget: Union[int, TrainingBudget, None] = None,
            # search
            strategy: Union[str, Strategy] = "grid",
            objectives: Optional[Sequence[str]] = None,
            chunk_size: int = 65536,
            keep_all: bool = False,
            lib: Optional[resources.CostLibrary] = None,
            # study lifecycle
            workers: Union[int, str] = 0,
            stack: bool = False,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: Optional[int] = None,
            resume: bool = False,
            run: bool = True) -> Study:
    """The unified front end: explore ``space`` and return a ``Study``.

    Hardware-only spaces (no model axes, no workload kwargs) evaluate
    against ``config``/``counts`` exactly like ``dse.search``.  Spaces with
    model axes (or ``workload``/``datasets``/... kwargs) resolve each model
    cell through the ``workloads`` trace cache like ``dse.coexplore`` — with
    ``GridSearch`` every cell's hardware subspace is enumerated; with
    ``RandomSearch``/``EvolutionarySearch`` the strategy searches the *full
    joint space* and ``train_budget=k`` caps training at k cache misses
    (candidates in unaffordable cells return to the strategy as ``+inf``).

    ``checkpoint_dir`` + ``checkpoint_every=n`` checkpoint the study every n
    steps; ``resume=True`` restores from ``checkpoint_dir`` and continues.
    ``workers=N`` trains pending cells across N processes;
    ``workers="cluster"`` spools them to the shared cache root's job queue
    for any enrolled ``fleet.FleetWorker`` — on this or any other host —
    to claim by lease (``repro.distributed.fleet``; blocks on fleet
    progress with an in-process fallback, so it completes with zero live
    workers too).  ``stack=True`` prefers batching same-signature cells
    into one vmapped device-resident stack over farming them
    (``repro.distributed.cellstack`` — published cells are bit-identical
    to solo training either way).  ``run=False`` returns the un-run study
    for manual ``step()``-ing.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    if isinstance(workers, str) and workers != "cluster":
        raise ValueError(f"workers must be an int or 'cluster', "
                         f"got {workers!r}")
    if isinstance(strategy, str):
        if strategy != "grid":
            raise ValueError(f"unknown strategy name {strategy!r}; pass a "
                             f"strategy instance for non-grid search")
        strategy = GridSearch(chunk_size)
    if keep_all and checkpoint_dir is not None:
        raise ValueError("checkpointing retains only the frontier; "
                         "keep_all tables are not checkpointed — drop one")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir=...")

    is_joint = (workload is not None or datasets is not None
                or num_steps is not None or population is not None
                or (space is not None and bool(space.model_axes)))
    if is_joint:
        study = _build_joint(
            space, workload=workload, datasets=datasets, num_steps=num_steps,
            population=population, hw_space=hw_space, max_lhr=max_lhr,
            weight_bits=weight_bits, cache=cache, seed=seed,
            train_budget=train_budget, strategy=strategy,
            objectives=objectives, chunk_size=chunk_size, keep_all=keep_all,
            lib=lib, workers=workers, stack=stack,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every)
    else:
        ignored = [name for name, val, default in (
            ("cache", cache, None), ("train_budget", train_budget, None),
            ("workers", workers, 0), ("stack", stack, False),
            ("hw_space", hw_space, None),
            ("max_lhr", max_lhr, None), ("weight_bits", weight_bits, None),
            ("seed", seed, 0)) if val != default]
        if ignored:
            raise ValueError(
                f"{ignored} only apply to model-cell resolution (spaces "
                f"with model axes or a workload); this exploration is "
                f"hardware-only")
        study = _build_hardware(
            space, config=config, counts=counts, strategy=strategy,
            objectives=objectives, chunk_size=chunk_size, keep_all=keep_all,
            lib=lib, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every)
    if resume:
        study.load(checkpoint_dir)
    if run:
        study.run()
    return study


def _build_hardware(space, *, config, counts, strategy, objectives,
                    chunk_size, keep_all, lib, checkpoint_dir,
                    checkpoint_every) -> Study:
    if space is None:
        raise ValueError("hardware-only exploration needs a SearchSpace "
                         "(or pass a workload for co-exploration)")
    if not space.axes:
        raise ValueError("search space has no axes")
    config = config if config is not None else space.config
    if counts is None:
        raise ValueError("hardware-only exploration needs counts= (per-layer "
                         "spike traffic)")
    objectives = tuple(objectives) if objectives is not None \
        else DEFAULT_OBJECTIVES
    for obj in objectives:
        if obj not in METRICS:
            raise ValueError(f"unknown objective {obj!r}; pick from {METRICS}")
    return Study(mode="hardware", space=space, strategy=strategy,
                 objectives=objectives, chunk_size=chunk_size,
                 keep_all=keep_all, lib=lib, config=config, counts=counts,
                 checkpoint_dir=checkpoint_dir,
                 checkpoint_every=checkpoint_every)


def _build_joint(space, *, workload, datasets, num_steps, population,
                 hw_space, max_lhr, weight_bits, cache, seed, train_budget,
                 strategy, objectives, chunk_size, keep_all, lib, workers,
                 stack, checkpoint_dir, checkpoint_every) -> Study:
    objectives = tuple(objectives) if objectives is not None \
        else DEFAULT_CO_OBJECTIVES
    for obj in objectives:
        if obj == "accuracy":
            raise ValueError("objectives are minimized — use 'error' "
                             "(= 1 - accuracy) instead of 'accuracy'")
        if obj not in CO_METRICS:
            raise ValueError(f"unknown objective {obj!r}; pick from "
                             f"{CO_METRICS}")
    if workload is None and datasets is None and (
            space is None or not any(ax.name == "dataset"
                                     for ax in space.model_axes)):
        raise ValueError("pass a workload, datasets=..., or a space with a "
                         "'dataset' model axis")
    custom_hw = hw_space is not None or (space is not None
                                         and bool(space.hw_axes))
    given_hw = [n for n, v in (("max_lhr", max_lhr),
                               ("weight_bits", weight_bits)) if v is not None]
    if custom_hw and given_hw:
        raise ValueError(
            f"the {given_hw} kwargs only shape the default hardware "
            f"subspace, but one is already declared via "
            f"{'hw_space' if hw_space is not None else 'the space'}; "
            f"pick one declaration style")
    cache = cache if cache is not None else TraceCache()
    if isinstance(train_budget, int):
        train_budget = TrainingBudget(train_budget)

    # Workload instances handed in directly (the ``workload`` param or
    # ``datasets=`` entries) need not be in the global registry — cells
    # carry only the name, so keep a local name -> Workload view.
    local_wls: dict[str, Workload] = {}
    if isinstance(workload, Workload):
        local_wls[workload.name] = workload
    for d in (datasets or ()):
        if isinstance(d, Workload):
            local_wls[d.name] = d
    if space is not None:
        for ax in space.model_axes:
            if ax.name == "dataset":
                for d in ax.values:
                    if isinstance(d, Workload):
                        local_wls[d.name] = d
    base_wl_holder = workload

    def resolve_wl(w: Union[str, Workload, None]) -> Workload:
        if w is None:
            w = base_wl_holder
        if isinstance(w, Workload):
            return w
        return local_wls[w] if w in local_wls else workloads.get(w)

    model_axes = _model_axis_list(space, workload, num_steps, population,
                                  datasets, resolve_wl)
    base_wl = resolve_wl(workload) if workload is not None else None

    def hw_factory(cfg: arch.AcceleratorConfig) -> SearchSpace:
        if hw_space is not None:
            return hw_space(cfg)
        if space is not None and space.hw_axes:
            return space.hardware_subspace(cfg)
        sub = SearchSpace.product_lhr(
            cfg, max_lhr=max_lhr if max_lhr is not None else 32)
        if weight_bits is not None:
            sub.add_global("weight_bits", tuple(int(b) for b in weight_bits))
        return sub

    mode = "cells" if isinstance(strategy, GridSearch) else "joint"
    if mode == "joint":
        if space is None or not space.hw_axes or hw_space is not None:
            raise ValueError(
                "joint Random/EvolutionarySearch strategies search the full "
                "joint digit space — declare both the model axes and the "
                "hardware axes in one SearchSpace (hw_space callables and "
                "default subspaces are only supported with GridSearch)")
        declared = {ax.name for ax in space.model_axes}
        needed = {n for n, _ in model_axes}
        if needed - declared:
            raise ValueError(
                f"joint strategies need every model axis declared in the "
                f"space; missing {sorted(needed - declared)} (e.g. "
                f"add_model('num_steps', ...))")
        l_max = _joint_prepass(space, model_axes, resolve_wl, base_wl)
        return Study(mode="joint", space=space, strategy=strategy,
                     objectives=objectives, chunk_size=chunk_size,
                     keep_all=keep_all, lib=lib, cache=cache,
                     budget=train_budget, seed=seed, resolve_wl=resolve_wl,
                     model_axes=model_axes, l_max=l_max, workers=workers,
                     stack=stack, checkpoint_dir=checkpoint_dir,
                     checkpoint_every=checkpoint_every)

    # cells mode: materialize every cell's topology and hardware subspace
    # BEFORE any training — a bad subspace (model axes, inconsistent column
    # sets across cells) fails here rather than mid-sweep with cells already
    # trained; also finds the widest per-layer column for cross-topology
    # padding.
    cell_plan: list[tuple] = []
    for cell in iter_cells(model_axes):
        wl = resolve_wl(cell["dataset"]) if "dataset" in cell else base_wl
        snn_cfg = wl.build(int(cell["num_steps"]),
                           float(cell.get("population", 1.0)))
        accel = arch.from_snn_config(snn_cfg)
        sub = hw_factory(accel)
        _check_subspace(sub, f"cell {cell}")
        cell_plan.append((cell, wl, snn_cfg, accel, sub))
    if not cell_plan:
        raise ValueError("model subspace is empty (an axis has no values)")
    names0 = sorted({ax.name for ax in cell_plan[0][4].axes})
    for cell, _, _, _, sub in cell_plan[1:]:
        names = sorted({ax.name for ax in sub.axes})
        if names != names0:
            raise ValueError(
                f"hardware subspaces must share axis names across cells "
                f"(one CandidateTable holds the joint frontier): cell "
                f"{cell_plan[0][0]} has {names0} but cell {cell} has {names}")
    l_max = max(len(accel.layers) for _, _, _, accel, _ in cell_plan)
    return Study(mode="cells", space=space, strategy=strategy,
                 objectives=objectives, chunk_size=chunk_size,
                 keep_all=keep_all, lib=lib, cache=cache, budget=train_budget,
                 seed=seed, resolve_wl=resolve_wl, model_axes=model_axes,
                 cell_plan=cell_plan, l_max=l_max, workers=workers,
                 stack=stack, checkpoint_dir=checkpoint_dir,
                 checkpoint_every=checkpoint_every)


def _joint_prepass(space: SearchSpace, model_axes, resolve_wl,
                   base_wl) -> int:
    """Validate the template hw axes and every dataset's topology binding
    before any training; returns the widest per-layer column width."""
    _check_subspace(SearchSpace(space.config, [
        dataclasses.replace(ax) for ax in space.hw_axes]), "the space")
    by_name = dict(model_axes)
    t0 = int(by_name["num_steps"][0])
    wls = ([resolve_wl(d) for d in by_name["dataset"]]
           if "dataset" in by_name else [base_wl])
    l_max = 0
    for wl in wls:
        accel = arch.from_snn_config(wl.build(t0, 1.0))
        space.hardware_subspace(accel, dedup=False)   # raises on bad binding
        l_max = max(l_max, len(accel.layers))
    return l_max
