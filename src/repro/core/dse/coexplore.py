"""Model–hardware co-exploration (the paper's full five-phase loop).

The headline claim of the paper is joint tailoring of "both the hardware and
model parameters".  ``coexplore`` makes model parameters searchable axes by
factoring the joint space into

    (model cell) x (hardware subspace)

A *model cell* is one assignment of the model axes (``num_steps``,
``population``, ``dataset``).  Each cell resolves **once** through the
``workloads.TraceCache`` to trained params, measured accuracy, and per-layer
spike traces (``snn.spike_counts_per_layer``); its topology derives an
``AcceleratorConfig`` (``arch.from_snn_config``), and the cell's hardware
subspace then streams through the existing chunked evaluator
(``evaluate_columns``) exactly as a PR-1 hardware-only search would — the
numerics on a fixed cell are identical by construction (tested).

Accuracy joins cycles/LUT/BRAM/energy as a first-class Pareto objective:
every candidate row carries ``accuracy`` and ``error`` (= 1 - accuracy)
columns, and ``error`` is minimized in the shared k-objective accumulator.
When the hardware subspace has a ``weight_bits`` axis and the workload is a
rate-encoded MLP, the accuracy is the **fixed-point datapath** accuracy at
that precision
(``validate.quantized_accuracy``, cached per (cell, bits)); otherwise the
float accuracy of the trained cell.

Per-layer axis columns (``lhr``, ``mem_blocks``) are padded with -1 to the
widest cell when cells differ in layer count (the ``dataset`` axis mixes
topologies), so one ``CandidateTable`` holds the whole joint frontier.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.core import workloads
from repro.core.accelerator import arch, cycle_model, resources
from repro.core.dse.engine import FrontierQueries
from repro.core.dse.evaluate import AXIS_NAMES, METRICS, evaluate_columns
from repro.core.dse.pareto import ParetoAccumulator
from repro.core.dse.space import MODEL_AXES, SearchSpace, iter_cells
from repro.core.dse.strategies import GridSearch
from repro.core.dse.table import CandidateTable
from repro.core.workloads import TraceCache, Workload

DEFAULT_CO_OBJECTIVES = ("error", "cycles", "lut", "energy")

#: metric columns a co-exploration row carries beyond the hardware METRICS
CO_METRICS = METRICS + ("accuracy", "error")

HwSpaceFn = Callable[[arch.AcceleratorConfig], SearchSpace]


@dataclasses.dataclass
class CellRecord:
    """One resolved model cell and its hardware sub-sweep summary."""
    workload: str
    assignment: dict                     # model-axis values for this cell
    key: str                             # trace-cache content address
    accuracy: float                      # float-datapath accuracy
    quant_acc: dict[int, float]          # weight_bits -> fixed-point accuracy
    cache_hit: bool
    n_evaluated: int                     # hardware candidates streamed
    layer_sizes: list[int]


@dataclasses.dataclass
class CoExploreResult(FrontierQueries):
    """Joint search result.  ``best_under`` (shared with ``SearchResult``)
    answers accuracy-aware picks — e.g. ``best_under("cycles", error=0.1)``
    for the fastest design losing at most 10 points of accuracy."""
    objectives: tuple[str, ...]
    frontier: CandidateTable             # joint accuracy-aware Pareto set
    cells: list[CellRecord]
    n_evaluated: int
    cache: TraceCache
    table: Optional[CandidateTable] = None      # all rows iff keep_all

    @property
    def cache_stats(self) -> dict:
        return self.cache.stats


def _model_axis_list(space: Optional[SearchSpace],
                     workload: Optional[Union[str, Workload]],
                     num_steps, population, datasets,
                     resolve: Callable[[Union[str, Workload]], Workload]
                     ) -> list[tuple]:
    """Canonical (name, values) list in MODEL_AXES order."""
    if space is not None and space.model_axes:
        given = [n for n, v in (("num_steps", num_steps),
                                ("population", population),
                                ("datasets", datasets)) if v is not None]
        if given:
            raise ValueError(
                f"model axes declared both in the space "
                f"({[ax.name for ax in space.model_axes]}) and via kwargs "
                f"{given}; pick one declaration style")
        by_name = {ax.name: tuple(ax.values) for ax in space.model_axes}
        if "dataset" in by_name:          # normalize instances to names
            by_name["dataset"] = tuple(
                resolve(d).name for d in by_name["dataset"])
    else:
        by_name = {}
        if datasets is not None:
            by_name["dataset"] = tuple(resolve(d).name for d in datasets)
        if num_steps is not None:
            by_name["num_steps"] = tuple(int(t) for t in num_steps)
        if population is not None:
            by_name["population"] = tuple(float(p) for p in population)
    if "num_steps" not in by_name:
        wls = ([resolve(d) for d in by_name["dataset"]]
               if "dataset" in by_name else [resolve(workload)])
        choices = {wl.name: tuple(wl.num_steps_choices) for wl in wls}
        if len(set(choices.values())) > 1:
            raise ValueError(
                f"the swept workloads declare different num_steps_choices "
                f"({choices}); pass num_steps=... explicitly")
        by_name["num_steps"] = next(iter(choices.values()))
    return [(n, by_name[n]) for n in MODEL_AXES if n in by_name]


def _bits_values(sub: SearchSpace) -> list[int]:
    vals: set[int] = set()
    for ax in sub.axes:
        if ax.name != "weight_bits":
            continue
        for v in ax.values:
            if ax.is_vector:
                vals.update(int(x) for x in v)
            else:
                vals.add(int(v))
    return sorted(vals)


def _row_bits(cols: dict[str, np.ndarray]) -> Optional[np.ndarray]:
    """Per-candidate effective weight precision: the global column, or the
    per-layer minimum (the precision that bounds datapath accuracy)."""
    wb = cols.get("weight_bits")
    if wb is None:
        return None
    wb = np.asarray(wb)
    return wb.min(axis=1) if wb.ndim == 2 else wb


def _pad_layers(col: np.ndarray, width: int) -> np.ndarray:
    """Pad a (n, L) per-layer column to (n, width) with -1 (absent layer)."""
    if col.ndim != 2 or col.shape[1] == width:
        return col
    pad = np.full((len(col), width - col.shape[1]), -1, dtype=col.dtype)
    return np.concatenate([col, pad], axis=1)


def coexplore(workload: Union[str, Workload, None] = None,
              space: Optional[SearchSpace] = None, *,
              num_steps: Optional[Sequence[int]] = None,
              population: Optional[Sequence[float]] = None,
              datasets: Optional[Sequence[Union[str, Workload]]] = None,
              hw_space: Union[HwSpaceFn, None] = None,
              max_lhr: Optional[int] = None,
              weight_bits: Optional[Sequence[int]] = None,
              objectives: Sequence[str] = DEFAULT_CO_OBJECTIVES,
              cache: Optional[TraceCache] = None,
              seed: int = 0,
              chunk_size: int = 65536,
              keep_all: bool = False,
              lib: Optional[resources.CostLibrary] = None) -> CoExploreResult:
    """Joint model x hardware search returning an accuracy-aware frontier.

    Model axes come from ``space`` (a ``SearchSpace`` with ``add_model``
    axes) or the ``num_steps`` / ``population`` / ``datasets`` kwargs
    (defaults: the workload's ``num_steps_choices`` x population 1.0).  The
    hardware subspace per cell comes from, in priority order: ``hw_space``
    (a callable ``AcceleratorConfig -> SearchSpace``), the hardware axes of
    ``space`` rebound to the cell (``SearchSpace.hardware_subspace``), or a
    default per-layer power-of-two LHR product capped at ``max_lhr``
    (default 32) plus an optional global ``weight_bits`` axis.  The
    ``max_lhr``/``weight_bits`` kwargs only shape that default — passing
    them next to a custom subspace raises rather than silently dropping
    them.

    ``objectives`` may use any hardware metric plus ``error``
    (= 1 - accuracy, the minimization form of the accuracy objective).
    """
    for obj in objectives:
        if obj == "accuracy":
            raise ValueError("objectives are minimized — use 'error' "
                             "(= 1 - accuracy) instead of 'accuracy'")
        if obj not in CO_METRICS:
            raise ValueError(f"unknown objective {obj!r}; pick from "
                             f"{CO_METRICS}")
    if workload is None and datasets is None and (
            space is None or not any(ax.name == "dataset"
                                     for ax in space.model_axes)):
        raise ValueError("pass a workload, datasets=..., or a space with a "
                         "'dataset' model axis")
    custom_hw = hw_space is not None or (space is not None
                                         and bool(space.hw_axes))
    given_hw = [n for n, v in (("max_lhr", max_lhr),
                               ("weight_bits", weight_bits)) if v is not None]
    if custom_hw and given_hw:
        raise ValueError(
            f"the {given_hw} kwargs only shape the default hardware "
            f"subspace, but one is already declared via "
            f"{'hw_space' if hw_space is not None else 'the space'}; "
            f"pick one declaration style")
    cache = cache if cache is not None else TraceCache()

    # Workload instances handed in directly (the ``workload`` param or
    # ``datasets=`` entries) need not be in the global registry — cells
    # carry only the name, so keep a local name -> Workload view.
    local_wls: dict[str, Workload] = {}
    if isinstance(workload, Workload):
        local_wls[workload.name] = workload
    for d in (datasets or ()):
        if isinstance(d, Workload):
            local_wls[d.name] = d
    if space is not None:
        for ax in space.model_axes:
            if ax.name == "dataset":
                for d in ax.values:
                    if isinstance(d, Workload):
                        local_wls[d.name] = d

    def resolve_wl(w: Union[str, Workload]) -> Workload:
        if isinstance(w, Workload):
            return w
        return local_wls[w] if w in local_wls else workloads.get(w)

    model_axes = _model_axis_list(space, workload, num_steps, population,
                                  datasets, resolve_wl)
    base_wl = resolve_wl(workload) if workload is not None else None

    def hw_factory(cfg: arch.AcceleratorConfig) -> SearchSpace:
        if hw_space is not None:
            return hw_space(cfg)
        if space is not None and space.hw_axes:
            return space.hardware_subspace(cfg)
        sub = SearchSpace.product_lhr(
            cfg, max_lhr=max_lhr if max_lhr is not None else 32)
        if weight_bits is not None:
            sub.add_global("weight_bits", tuple(int(b) for b in weight_bits))
        return sub

    # Prepass: materialize every cell's topology and hardware subspace
    # BEFORE any training — a bad subspace (model axes, inconsistent column
    # sets across cells) fails here rather than mid-sweep with cells already
    # trained; also finds the widest per-layer column for cross-topology
    # padding.
    cells: list[tuple] = []
    for cell in iter_cells(model_axes):
        wl = resolve_wl(cell["dataset"]) if "dataset" in cell else base_wl
        snn_cfg = wl.build(int(cell["num_steps"]),
                           float(cell.get("population", 1.0)))
        accel = arch.from_snn_config(snn_cfg)
        sub = hw_factory(accel)
        if sub.model_axes:
            raise ValueError("hardware subspace must not contain model axes")
        if not sub.axes:
            raise ValueError(f"hardware subspace for cell {cell} has no "
                             f"axes — nothing to sweep")
        unknown = {ax.name for ax in sub.axes} - AXIS_NAMES
        if unknown:
            raise ValueError(f"hardware subspace for cell {cell} has axes "
                             f"{sorted(unknown)} the evaluator does not "
                             f"know; known: {sorted(AXIS_NAMES)}")
        cells.append((cell, wl, snn_cfg, accel, sub))
    if not cells:
        raise ValueError("model subspace is empty (an axis has no values)")
    names0 = sorted({ax.name for ax in cells[0][4].axes})
    for cell, _, _, _, sub in cells[1:]:
        names = sorted({ax.name for ax in sub.axes})
        if names != names0:
            raise ValueError(
                f"hardware subspaces must share axis names across cells "
                f"(one CandidateTable holds the joint frontier): cell "
                f"{cells[0][0]} has {names0} but cell {cell} has {names}")
    l_max = max(len(accel.layers) for _, _, _, accel, _ in cells)

    acc = ParetoAccumulator(tuple(objectives))
    kept: Optional[list[CandidateTable]] = [] if keep_all else None
    records: list[CellRecord] = []
    n_total = 0

    for cell, wl, snn_cfg, accel, sub in cells:
        bits = _bits_values(sub)
        artifact = cache.resolve(wl, cell, seed=seed, quant_bits=bits)
        counts = cycle_model.counts_from_traces(artifact.counts)

        def evaluate(cols: dict[str, np.ndarray],
                     _cell=cell, _accel=accel, _art=artifact,
                     _counts=counts) -> dict[str, np.ndarray]:
            metrics = evaluate_columns(_accel, _counts, cols, lib=lib)
            n = len(next(iter(metrics.values())))
            row_bits = _row_bits(cols)
            if row_bits is None or not _art.quant_acc:
                acc_col = np.full(n, _art.accuracy)
            else:
                uniq = np.unique(row_bits)
                by_bits = np.array([_art.accuracy_at(int(b)) for b in uniq])
                acc_col = by_bits[np.searchsorted(uniq, row_bits)]
            out_cols = {k: (_pad_layers(v, l_max) if v.ndim == 2 else v)
                        for k, v in cols.items()}
            for name, _vals in model_axes:
                v = _cell[name]
                out_cols[name] = np.full(
                    n, v, dtype=(np.int64 if name == "num_steps" else
                                 np.float64 if name == "population" else None))
            chunk = CandidateTable({**out_cols, **metrics,
                                    "accuracy": acc_col,
                                    "error": 1.0 - acc_col})
            acc.update(chunk)
            if kept is not None:
                kept.append(chunk)
            return metrics

        n_cell = GridSearch(chunk_size).run(sub, evaluate, tuple(objectives))
        n_total += n_cell
        records.append(CellRecord(
            workload=wl.name, assignment=dict(cell), key=artifact.key,
            accuracy=artifact.accuracy, quant_acc=dict(artifact.quant_acc),
            cache_hit=artifact.cache_hit, n_evaluated=n_cell,
            layer_sizes=snn_cfg.layer_sizes()))

    table = CandidateTable.concat(kept) if kept is not None else None
    return CoExploreResult(objectives=tuple(objectives), frontier=acc.frontier,
                           cells=records, n_evaluated=n_total, cache=cache,
                           table=table)
