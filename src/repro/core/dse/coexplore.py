"""Model–hardware co-exploration: an exact thin wrapper over ``dse.explore``.

The headline claim of the paper is joint tailoring of "both the hardware and
model parameters".  ``coexplore`` makes model parameters searchable axes by
factoring the joint space into

    (model cell) x (hardware subspace)

A *model cell* is one assignment of the model axes (``num_steps``,
``population``, ``dataset``).  Each cell resolves **once** through the
``workloads.TraceCache`` to trained params, measured accuracy, and per-layer
spike traces; its topology derives an ``AcceleratorConfig``
(``arch.from_snn_config``), and the cell's hardware subspace then streams
through the chunked evaluator exactly as a hardware-only search would — the
numerics on a fixed cell are identical by construction (tested).

Accuracy joins cycles/LUT/BRAM/energy as a first-class Pareto objective:
every candidate row carries ``accuracy`` and ``error`` (= 1 - accuracy)
columns, and ``error`` is minimized in the shared k-objective accumulator.
When the hardware subspace has a ``weight_bits`` axis, the accuracy is the
**fixed-point datapath** accuracy at that precision
(``validate.quantized_accuracy``, cached per (cell, bits)) for every
topology — the integer reference models dense, conv and OR-pool layers, so
conv cells like ``dvs-conv`` are no longer padded with float accuracy.

Per-layer axis columns (``lhr``, ``mem_blocks``) are padded with -1 to the
widest cell when cells differ in layer count (the ``dataset`` axis mixes
topologies), so one ``CandidateTable`` holds the whole joint frontier.

The loop itself lives in ``dse.study`` since the ask/tell redesign; this
wrapper adapts the returned ``Study`` to the classic ``CoExploreResult``
and forwards the new knobs: ``strategy=`` (a non-grid strategy searches the
*joint* digit space instead of enumerating cells — requires a declared
space), ``train_budget=k`` (at most k cache misses), and ``workers=N``
(parallel cell farming).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.core.accelerator import resources
from repro.core.dse.strategies import GridSearch, Strategy
from repro.core.dse.study import (CO_METRICS, DEFAULT_CO_OBJECTIVES,
                                  CellRecord, FrontierQueries, HwSpaceFn,
                                  Study, explore)
from repro.core.dse.table import CandidateTable
from repro.core.workloads import TraceCache, TrainingBudget, Workload

__all__ = ["CO_METRICS", "DEFAULT_CO_OBJECTIVES", "CellRecord",
           "CoExploreResult", "HwSpaceFn", "coexplore"]


@dataclasses.dataclass
class CoExploreResult(FrontierQueries):
    """Joint search result.  ``best_under`` (shared with ``SearchResult``)
    answers accuracy-aware picks — e.g. ``best_under("cycles", error=0.1)``
    for the fastest design losing at most 10 points of accuracy."""
    objectives: tuple[str, ...]
    frontier: CandidateTable             # joint accuracy-aware Pareto set
    cells: list[CellRecord]
    n_evaluated: int
    cache: TraceCache
    table: Optional[CandidateTable] = None      # all rows iff keep_all
    study: Optional[Study] = None               # the underlying Study

    @property
    def cache_stats(self) -> dict:
        return self.cache.stats

    @property
    def summary(self) -> dict:
        """Auditable counters: cache hits/misses, remaining train budget,
        cells resolved/skipped (see ``Study.summary``)."""
        if self.study is not None:
            return self.study.summary
        return {"n_evaluated": self.n_evaluated,
                "frontier_size": len(self.frontier),
                "cells_resolved": len(self.cells),
                "cache": dict(self.cache.stats)}


def coexplore(workload: Union[str, Workload, None] = None,
              space=None, *,
              num_steps: Optional[Sequence[int]] = None,
              population: Optional[Sequence[float]] = None,
              datasets: Optional[Sequence[Union[str, Workload]]] = None,
              hw_space: Union[HwSpaceFn, None] = None,
              max_lhr: Optional[int] = None,
              weight_bits: Optional[Sequence[int]] = None,
              objectives: Sequence[str] = DEFAULT_CO_OBJECTIVES,
              cache: Optional[TraceCache] = None,
              seed: int = 0,
              chunk_size: int = 65536,
              keep_all: bool = False,
              lib: Optional[resources.CostLibrary] = None,
              strategy: Optional[Strategy] = None,
              train_budget: Union[int, TrainingBudget, None] = None,
              workers: int = 0,
              stack: bool = False) -> CoExploreResult:
    """Joint model x hardware search returning an accuracy-aware frontier.

    Model axes come from ``space`` (a ``SearchSpace`` with ``add_model``
    axes) or the ``num_steps`` / ``population`` / ``datasets`` kwargs
    (defaults: the workload's ``num_steps_choices`` x population 1.0).  The
    hardware subspace per cell comes from, in priority order: ``hw_space``
    (a callable ``AcceleratorConfig -> SearchSpace``), the hardware axes of
    ``space`` rebound to the cell (``SearchSpace.hardware_subspace``), or a
    default per-layer power-of-two LHR product capped at ``max_lhr``
    (default 32) plus an optional global ``weight_bits`` axis.  The
    ``max_lhr``/``weight_bits`` kwargs only shape that default — passing
    them next to a custom subspace raises rather than silently dropping
    them.

    ``objectives`` may use any hardware metric plus ``error``
    (= 1 - accuracy, the minimization form of the accuracy objective).

    ``strategy`` defaults to exhaustive cell enumeration (``GridSearch``);
    pass ``RandomSearch``/``EvolutionarySearch`` (with a declared joint
    space) plus ``train_budget=k`` for the NAS-style budgeted loop,
    ``workers=N`` to farm cell training across processes, and
    ``stack=True`` to batch same-signature cells into one vmapped stack
    (``repro.distributed.cellstack``) — all forwarded to ``dse.explore``.
    """
    study = explore(
        space, workload=workload, datasets=datasets, num_steps=num_steps,
        population=population, hw_space=hw_space, max_lhr=max_lhr,
        weight_bits=weight_bits, objectives=objectives, cache=cache,
        seed=seed, chunk_size=chunk_size, keep_all=keep_all, lib=lib,
        strategy=strategy if strategy is not None else GridSearch(chunk_size),
        train_budget=train_budget, workers=workers, stack=stack)
    return CoExploreResult(objectives=study.objectives,
                           frontier=study.frontier, cells=study.cells,
                           n_evaluated=study.n_evaluated, cache=study.cache,
                           table=study.table, study=study)
