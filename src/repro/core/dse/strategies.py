"""Pluggable search strategies — ask/tell interface.

A strategy decides *which* candidates to evaluate; the driver
(``dse.study.explore``) owns the chunked evaluation and the incremental
Pareto merge.  The contract is pull-style:

    bind(space, objectives)     begin a fresh run over ``space``
    ask(n) -> digits            up to ``n`` candidates as an (m, n_axes)
                                mixed-radix digit matrix; an empty matrix
                                means the strategy is done
    tell(digits, objective_mat) the (m, K) float64 objective values for the
                                digits just asked (minimization; a row of
                                ``+inf`` marks an infeasible candidate the
                                driver refused to evaluate, e.g. a model
                                cell outside the training budget)
    state_dict()/load_state_dict()
                                JSON-serializable snapshot of everything
                                between ask/tell rounds (RNG state, cursors,
                                pending populations) — the hook ``Study``
                                checkpoints use to resume mid-search

The driver strictly alternates ``ask``/``tell`` and never re-orders rows,
so a strategy may rely on ``tell`` receiving exactly the digits of the
preceding ``ask``.

* ``GridSearch``         — exhaustive, chunked; any space size streams in
                           fixed memory.
* ``RandomSearch``       — uniform i.i.d. samples, for spaces too large to
                           enumerate (works past 2^63 candidates: sampling
                           is per-axis digits, never a flat index).  Exact
                           duplicate rows within one asked chunk are
                           dropped, so ``n_evaluated`` counts distinct
                           candidates.
* ``EvolutionarySearch`` — (mu + lambda)-style loop: parents are the
                           generation's non-dominated set padded by
                           normalized-sum rank (infeasible rows rank last);
                           children come from uniform crossover plus
                           per-gene random-reset mutation.
"""
from __future__ import annotations

import numpy as np

from repro.core.dse.pareto import pareto_mask_k
from repro.core.dse.space import SearchSpace


def _dedup_rows(digits: np.ndarray) -> np.ndarray:
    """Drop exact duplicate rows, keeping first occurrences in order."""
    if len(digits) < 2:
        return digits
    _, first = np.unique(digits, axis=0, return_index=True)
    first.sort()
    return digits[first]


def _rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state          # plain dict of ints / strings


def _rng_from_state(state: dict) -> np.random.Generator:
    rng = np.random.default_rng()
    rng.bit_generator.state = state
    return rng


class Strategy:
    """Shared ask/tell scaffolding (binding + empty-result helper)."""

    _space: SearchSpace | None = None

    def bind(self, space: SearchSpace, objectives: tuple[str, ...]) -> None:
        """Begin a fresh run: reset all between-round state."""
        self._space = space
        self._objectives = tuple(objectives)

    def _empty(self) -> np.ndarray:
        return np.empty((0, len(self._space.axes)), dtype=np.int64)

    def ask(self, n: int) -> np.ndarray:
        raise NotImplementedError

    def tell(self, digits: np.ndarray, objective_mat: np.ndarray) -> None:
        """Default: stateless strategies ignore the results."""

    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass

    def signature(self) -> dict:
        """The hyperparameters that define the search trajectory — part of
        the ``Study`` resume guard, so a checkpoint refuses a same-class
        strategy configured differently (seed, sample count, ...)."""
        return {k: v for k, v in vars(self).items()
                if not k.startswith("_")}


class GridSearch(Strategy):
    def __init__(self, chunk_size: int = 65536):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.chunk_size = chunk_size

    def bind(self, space: SearchSpace, objectives) -> None:
        super().bind(space, objectives)
        if space.size >= 2 ** 62:
            raise ValueError(f"{space.size} candidates cannot be enumerated; "
                             f"use RandomSearch or EvolutionarySearch")
        self._cursor = 0

    def ask(self, n: int) -> np.ndarray:
        m = min(n, self.chunk_size, self._space.size - self._cursor)
        if m <= 0:
            return self._empty()
        digits = self._space.digits(
            np.arange(self._cursor, self._cursor + m, dtype=np.int64))
        self._cursor += m
        return digits

    def state_dict(self) -> dict:
        return {"cursor": int(self._cursor)}

    def load_state_dict(self, state: dict) -> None:
        self._cursor = int(state["cursor"])


class RandomSearch(Strategy):
    def __init__(self, n_samples: int, seed: int = 0,
                 chunk_size: int = 65536):
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, got {n_samples}")
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.n_samples = n_samples
        self.seed = seed
        self.chunk_size = chunk_size

    def bind(self, space: SearchSpace, objectives) -> None:
        super().bind(space, objectives)
        self._rng = np.random.default_rng(self.seed)
        self._emitted = 0

    def ask(self, n: int) -> np.ndarray:
        m = min(n, self.chunk_size, self.n_samples - self._emitted)
        if m <= 0:
            return self._empty()
        digits = _dedup_rows(self._space.sample_digits(self._rng, m))
        self._emitted += len(digits)
        return digits

    def state_dict(self) -> dict:
        return {"emitted": int(self._emitted), "rng": _rng_state(self._rng)}

    def load_state_dict(self, state: dict) -> None:
        self._emitted = int(state["emitted"])
        self._rng = _rng_from_state(state["rng"])


class EvolutionarySearch(Strategy):
    def __init__(self, population: int = 128, generations: int = 16,
                 seed: int = 0, mutation_rate: float | None = None):
        if population < 4:
            raise ValueError("population must be >= 4")
        if generations < 1:
            raise ValueError(f"generations must be >= 1, got {generations}")
        self.population = population
        self.generations = generations
        self.seed = seed
        self.mutation_rate = mutation_rate

    def bind(self, space: SearchSpace, objectives) -> None:
        super().bind(space, objectives)
        self._rng = np.random.default_rng(self.seed)
        self._gen = 0
        self._pop = space.sample_digits(self._rng, self.population)
        self._offset = 0                       # asked rows of current pop
        self._pending: list[np.ndarray] = []   # told objective rows

    def ask(self, n: int) -> np.ndarray:
        if self._gen >= self.generations or self._offset >= len(self._pop):
            return self._empty()
        m = min(n, len(self._pop) - self._offset)
        rows = self._pop[self._offset:self._offset + m]
        self._offset += m
        return rows

    def tell(self, digits: np.ndarray, objective_mat: np.ndarray) -> None:
        self._pending.append(np.asarray(objective_mat, np.float64))
        if sum(len(p) for p in self._pending) >= len(self._pop):
            self._breed()

    def _breed(self) -> None:
        obj = np.concatenate(self._pending)
        n_axes = len(self._space.axes)
        mut_p = self.mutation_rate or 1.0 / max(n_axes, 1)
        rng = self._rng
        # rank: non-dominated first, then by normalized objective sum;
        # infeasible rows (any +/-inf or nan objective) always last
        finite = np.isfinite(obj).all(axis=1)
        score = np.full(len(obj), np.inf)
        if finite.any():
            fo = obj[finite]
            nondom = pareto_mask_k(fo)
            span = np.maximum(fo.max(axis=0) - fo.min(axis=0), 1e-300)
            s = ((fo - fo.min(axis=0)) / span).sum(axis=1)
            score[finite] = s + np.where(nondom, 0.0, fo.shape[1])
        order = np.argsort(score, kind="stable")
        parents = self._pop[order[:max(2, self.population // 2)]]
        pa = parents[rng.integers(len(parents), size=self.population)]
        pb = parents[rng.integers(len(parents), size=self.population)]
        children = np.where(
            rng.random((self.population, n_axes)) < 0.5, pa, pb)
        mutate = rng.random((self.population, n_axes)) < mut_p
        self._pop = np.where(
            mutate, self._space.sample_digits(rng, self.population), children)
        self._gen += 1
        self._offset = 0
        self._pending = []

    def state_dict(self) -> dict:
        return {"rng": _rng_state(self._rng),
                "generation": int(self._gen),
                "offset": int(self._offset),
                "pop": np.asarray(self._pop).tolist(),
                "pending": [p.tolist() for p in self._pending]}

    def load_state_dict(self, state: dict) -> None:
        self._rng = _rng_from_state(state["rng"])
        self._gen = int(state["generation"])
        self._offset = int(state["offset"])
        self._pop = np.asarray(state["pop"], dtype=np.int64)
        self._pending = [np.asarray(p, np.float64).reshape(-1, len(
            self._objectives)) for p in state["pending"]]
