"""Pluggable search strategies.

A strategy decides *which* candidates to evaluate; the engine owns the
streaming evaluation and the incremental Pareto merge.  The contract is

    run(space, evaluate, objectives) -> number of candidates evaluated

where ``evaluate(cols)`` takes axis columns (from ``space.decode`` /
``space.assemble``) and returns the metric columns, after feeding them to
the Pareto accumulator.

* ``GridSearch``         — exhaustive, chunked; any space size streams in
                           fixed memory.
* ``RandomSearch``       — uniform i.i.d. samples, for spaces too large to
                           enumerate (works past 2^63 candidates: sampling
                           is per-axis digits, never a flat index).
* ``EvolutionarySearch`` — (mu + lambda)-style loop: parents are the chunk's
                           non-dominated set padded by normalized-sum rank;
                           children come from uniform crossover plus
                           per-gene random-reset mutation.
"""
from __future__ import annotations

import numpy as np

from repro.core.dse.pareto import pareto_mask_k
from repro.core.dse.space import SearchSpace


class GridSearch:
    def __init__(self, chunk_size: int = 65536):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.chunk_size = chunk_size

    def run(self, space: SearchSpace, evaluate, objectives) -> int:
        total = space.size
        if total >= 2 ** 62:
            raise ValueError(f"{total} candidates cannot be enumerated; "
                             f"use RandomSearch or EvolutionarySearch")
        for start in range(0, total, self.chunk_size):
            stop = min(start + self.chunk_size, total)
            evaluate(space.decode(np.arange(start, stop, dtype=np.int64)))
        return total


class RandomSearch:
    def __init__(self, n_samples: int, seed: int = 0,
                 chunk_size: int = 65536):
        self.n_samples = n_samples
        self.seed = seed
        self.chunk_size = chunk_size

    def run(self, space: SearchSpace, evaluate, objectives) -> int:
        rng = np.random.default_rng(self.seed)
        done = 0
        while done < self.n_samples:
            m = min(self.chunk_size, self.n_samples - done)
            evaluate(space.assemble(space.sample_digits(rng, m)))
            done += m
        return done


class EvolutionarySearch:
    def __init__(self, population: int = 128, generations: int = 16,
                 seed: int = 0, mutation_rate: float | None = None):
        if population < 4:
            raise ValueError("population must be >= 4")
        self.population = population
        self.generations = generations
        self.seed = seed
        self.mutation_rate = mutation_rate

    def run(self, space: SearchSpace, evaluate, objectives) -> int:
        rng = np.random.default_rng(self.seed)
        n_axes = len(space.axes)
        mut_p = self.mutation_rate or 1.0 / max(n_axes, 1)
        pop = space.sample_digits(rng, self.population)
        evaluated = 0
        for _ in range(self.generations):
            metrics = evaluate(space.assemble(pop))
            evaluated += len(pop)
            obj = np.stack([np.asarray(metrics[k], np.float64)
                            for k in objectives], axis=1)
            nondom = pareto_mask_k(obj)
            # rank: non-dominated first, then by normalized objective sum
            span = np.maximum(obj.max(axis=0) - obj.min(axis=0), 1e-300)
            score = ((obj - obj.min(axis=0)) / span).sum(axis=1)
            order = np.argsort(score + np.where(nondom, 0.0, obj.shape[1]),
                               kind="stable")
            parents = pop[order[:max(2, self.population // 2)]]
            pa = parents[rng.integers(len(parents), size=self.population)]
            pb = parents[rng.integers(len(parents), size=self.population)]
            children = np.where(
                rng.random((self.population, n_axes)) < 0.5, pa, pb)
            mutate = rng.random((self.population, n_axes)) < mut_p
            pop = np.where(mutate, space.sample_digits(rng, self.population),
                           children)
        return evaluated
