"""Design Space Exploration subsystem (paper Sec. IV), unified + streaming.

The paper's contribution is *joint* exploration of hardware and model
parameters.  This package is a vectorized multi-axis search engine with a
single ask/tell front end:

* ``space``      — declarative ``SearchSpace``: per-layer LHR, per-layer
                   memory blocks, weight precision, PENC width, clock, as
                   independent / zipped / global axes over an
                   ``AcceleratorConfig`` — plus **model axes**
                   (``num_steps``, ``population``, ``dataset``) that resolve
                   by training.  Nothing is materialized; chunks of digit
                   rows decode to column arrays on demand.
* ``table``      — ``CandidateTable``: structure-of-arrays storage (NumPy
                   columns for cycles/LUT/REG/BRAM/DSP/energy), no
                   per-candidate Python objects.
* ``evaluate``   — one vectorised call per chunk through the batched cycle
                   model and component library.
* ``pareto``     — k-objective Pareto mask + chunk-incremental frontier
                   merge, so arbitrarily large spaces stream in the memory
                   of a single chunk.
* ``strategies`` — the ask/tell contract (``ask(n) -> digits``,
                   ``tell(digits, obj)``): exhaustive ``GridSearch``,
                   ``RandomSearch`` sampling, and a (mu+lambda)
                   ``EvolutionarySearch`` — all checkpointable via
                   ``state_dict``.
* ``study``      — ``explore(space, ...) -> Study``: the unified driver
                   that owns chunked evaluation, the Pareto merge,
                   model-cell resolution with a **training budget in cache
                   misses**, checkpoint/resume, and ``workers=N`` cell
                   farming.
* ``engine``     — ``search``/``SearchResult``/``auto_select``, exact thin
                   wrappers over ``explore`` for hardware-only spaces.
* ``coexplore``  — the classic cell-enumerating co-exploration front end,
                   also a thin wrapper over ``explore``.
* ``compat``     — the seed API (``sweep``, ``sweep_memory_blocks``,
                   ``sweep_weight_bits``, ``Candidate``/``DSEResult``) as
                   thin wrappers over the engine.

How to explore a joint space
----------------------------
::

    from repro.core import dse, workloads
    from repro.core.accelerator import arch

    wl = workloads.get("mnist-mlp")
    tmpl = arch.from_snn_config(wl.build(8, 1.0))

    space = (dse.SearchSpace(tmpl)
             # model axes: every combination is a cell that must train
             .add_model("num_steps", (4, 8, 15))
             .add_model("population", (0.5, 1.0, 2.0))
             # hardware axes, rebound (and lhr-clamped) per cell
             .add_per_layer("lhr", [dse.pow2_values(min(32, l.logical))
                                    for l in tmpl.layers])
             .add_global("weight_bits", (4, 6, 8)))

    study = dse.explore(space, workload=wl,
                        strategy=dse.EvolutionarySearch(population=32,
                                                        generations=8),
                        train_budget=4,          # at most 4 cache misses
                        checkpoint_dir="/tmp/study")   # resumable
    print(study.summary)                         # cache + budget counters
    best = study.best_under("cycles", error=0.1)       # row dict

    # interrupted?  continue exactly where the checkpoint left off:
    study = dse.explore(space, workload=wl, strategy=...,
                        train_budget=4, checkpoint_dir="/tmp/study",
                        resume=True)

Hardware-only spaces work the same way (``dse.explore(space,
counts=counts)``), and ``dse.search`` / ``dse.coexplore`` remain as exact
thin wrappers for the classic push-style signatures.  Spaces of any size
stream through chunked evaluation — memory stays flat and the frontier
merge is exact (see tests/test_dse.py, tests/test_explore.py).  See
DESIGN.md §8–§10 and ``examples/train_snn_dse.py`` for the full
walkthrough.
"""
from repro.core.dse.coexplore import (CO_METRICS, DEFAULT_CO_OBJECTIVES,
                                      CellRecord, CoExploreResult, coexplore)
from repro.core.dse.compat import (Candidate, DSEResult, MemBlockCandidate,
                                   lhr_grid, sweep, sweep_memory_blocks,
                                   sweep_spike_train_length,
                                   sweep_weight_bits)
from repro.core.dse.engine import (DEFAULT_OBJECTIVES, SearchResult,
                                   auto_select, search)
from repro.core.dse.evaluate import METRICS, evaluate_columns
from repro.core.dse.pareto import (ParetoAccumulator, any_dominates,
                                   frontier_of, pareto_mask, pareto_mask_k)
from repro.core.dse.space import MODEL_AXES, Axis, SearchSpace, pow2_values
from repro.core.dse.strategies import (EvolutionarySearch, GridSearch,
                                       RandomSearch, Strategy)
from repro.core.dse.study import Study, explore
from repro.core.dse.table import CandidateTable

__all__ = [
    "Axis", "CO_METRICS", "Candidate", "CandidateTable", "CellRecord",
    "CoExploreResult", "DEFAULT_CO_OBJECTIVES", "DEFAULT_OBJECTIVES",
    "DSEResult", "EvolutionarySearch", "GridSearch", "METRICS", "MODEL_AXES",
    "MemBlockCandidate", "ParetoAccumulator", "RandomSearch", "SearchResult",
    "SearchSpace", "Strategy", "Study", "any_dominates", "auto_select",
    "coexplore", "evaluate_columns", "explore", "frontier_of", "lhr_grid",
    "pareto_mask", "pareto_mask_k", "pow2_values", "search", "sweep",
    "sweep_memory_blocks", "sweep_spike_train_length", "sweep_weight_bits",
]
