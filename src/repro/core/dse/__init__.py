"""Design Space Exploration subsystem (paper Sec. IV), unified + streaming.

The paper's contribution is *joint* exploration of hardware and model
parameters.  This package turns the seed's single-axis LHR sweep into a
vectorized multi-axis search engine:

* ``space``      — declarative ``SearchSpace``: per-layer LHR, per-layer
                   memory blocks, weight precision, PENC width, clock, as
                   independent / zipped / global axes over an
                   ``AcceleratorConfig``.  Nothing is materialized; chunks
                   of flat indices decode to column arrays on demand.
* ``table``      — ``CandidateTable``: structure-of-arrays storage (NumPy
                   columns for cycles/LUT/REG/BRAM/DSP/energy), no
                   per-candidate Python objects.
* ``evaluate``   — one vectorised call per chunk through the batched cycle
                   model and component library.
* ``pareto``     — k-objective Pareto mask + chunk-incremental frontier
                   merge, so arbitrarily large spaces stream in the memory
                   of a single chunk.
* ``strategies`` — exhaustive ``GridSearch``, ``RandomSearch`` sampling, and
                   a simple ``EvolutionarySearch`` for spaces too big to
                   enumerate.
* ``engine``     — ``search``/``SearchResult``/``auto_select`` tying it all
                   together.
* ``compat``     — the seed API (``sweep``, ``sweep_memory_blocks``,
                   ``sweep_weight_bits``, ``Candidate``/``DSEResult``) as
                   thin wrappers over the new engine.

How to define a search space
----------------------------
::

    from repro.core import dse
    from repro.core.accelerator import paper_nets

    cfg = paper_nets.build("net-1")
    counts = paper_nets.paper_counts("net-1", cfg)

    space = (dse.SearchSpace(cfg)
             # per-layer LHR: independent power-of-two options per layer
             .add_per_layer("lhr", [dse.pow2_values(min(64, l.logical))
                                    for l in cfg.layers])
             # memory blocks: all layers move together (zipped options)
             .add_joint("mem_blocks",
                        [tuple(max(1, l.num_nus // d) for l in cfg.layers)
                         for d in (1, 2, 4)])
             # weight precision: one global value per candidate
             .add_global("weight_bits", (4, 6, 8)))

    result = dse.search(cfg, counts, space,
                        objectives=("cycles", "lut", "bram", "energy"))
    print(result.n_evaluated, len(result.frontier))
    best = result.best_within_latency(max_cycles=2e4)   # row dict
    hw = result.config_for(best)                        # AcceleratorConfig

Spaces past the old 200k cap stream through chunked evaluation — memory
stays flat and the frontier merge is exact (see tests/test_dse.py).  For
spaces too large to enumerate, pass ``strategy=dse.RandomSearch(100_000)``
or ``dse.EvolutionarySearch()``.  See DESIGN.md §8 and
``examples/train_snn_dse.py`` for the full walkthrough.

Model parameters are axes too: ``space.add_model("num_steps", (8, 15, 25))``
/ ``add_model("population", ...)`` / ``add_model("dataset", ...)`` declare
the model subspace, and ``dse.coexplore`` (DESIGN.md §9) factors the joint
space into (model cell) x (hardware subspace), resolving each cell once
through the ``repro.core.workloads`` trace cache and minimizing ``error``
(= 1 - accuracy) next to the hardware objectives.
"""
from repro.core.dse.coexplore import (CO_METRICS, DEFAULT_CO_OBJECTIVES,
                                      CellRecord, CoExploreResult, coexplore)
from repro.core.dse.compat import (Candidate, DSEResult, MemBlockCandidate,
                                   lhr_grid, sweep, sweep_memory_blocks,
                                   sweep_spike_train_length,
                                   sweep_weight_bits)
from repro.core.dse.engine import (DEFAULT_OBJECTIVES, SearchResult,
                                   auto_select, search)
from repro.core.dse.evaluate import METRICS, evaluate_columns
from repro.core.dse.pareto import (ParetoAccumulator, any_dominates,
                                   frontier_of, pareto_mask, pareto_mask_k)
from repro.core.dse.space import MODEL_AXES, Axis, SearchSpace, pow2_values
from repro.core.dse.strategies import (EvolutionarySearch, GridSearch,
                                       RandomSearch)
from repro.core.dse.table import CandidateTable

__all__ = [
    "Axis", "CO_METRICS", "Candidate", "CandidateTable", "CellRecord",
    "CoExploreResult", "DEFAULT_CO_OBJECTIVES", "DEFAULT_OBJECTIVES",
    "DSEResult", "EvolutionarySearch", "GridSearch", "METRICS", "MODEL_AXES",
    "MemBlockCandidate", "ParetoAccumulator", "RandomSearch", "SearchResult",
    "SearchSpace", "any_dominates", "auto_select", "coexplore",
    "evaluate_columns", "frontier_of", "lhr_grid", "pareto_mask",
    "pareto_mask_k", "pow2_values", "search", "sweep", "sweep_memory_blocks",
    "sweep_spike_train_length", "sweep_weight_bits",
]
