"""Batched candidate evaluation: axis columns in, metric columns out.

One call evaluates a whole chunk of candidates through the vectorised cycle
model and component library — there is no per-candidate Python object or
``with_lhr`` materialization anywhere on this path.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.accelerator import cycle_model, resources
from repro.core.accelerator.arch import AcceleratorConfig

METRICS = ("cycles", "lut", "reg", "bram", "dsp", "energy")

AXIS_NAMES = frozenset(
    {"lhr", "mem_blocks", "weight_bits", "penc_width", "clock_mhz"})


def evaluate_columns(cfg: AcceleratorConfig, counts: Sequence[np.ndarray],
                     cols: dict[str, np.ndarray],
                     lib: Optional[resources.CostLibrary] = None
                     ) -> dict[str, np.ndarray]:
    """Evaluate a chunk of candidates given as column arrays.

    ``cols`` maps axis names (``lhr``, ``mem_blocks``, ``weight_bits``,
    ``penc_width``, ``clock_mhz``) to (n, L) per-layer or (n,) global
    arrays.  Returns (n,) metric columns for ``METRICS``.
    """
    unknown = set(cols) - AXIS_NAMES
    if unknown:
        raise ValueError(f"unknown axes {sorted(unknown)}; "
                         f"known: {sorted(AXIS_NAMES)}")
    if not cols:
        raise ValueError("no axis columns to evaluate")
    lib = lib or resources.CostLibrary()
    n = len(next(iter(cols.values())))
    lhr = cols.get("lhr")
    mem = cols.get("mem_blocks")
    wb = cols.get("weight_bits")
    pw = cols.get("penc_width")
    clk = cols.get("clock_mhz")

    cycles = cycle_model.latency_cycles(
        cfg, counts, lhr_matrix=lhr, mem_blocks_matrix=mem, penc_width=pw)
    cycles = np.broadcast_to(np.asarray(cycles, np.float64), (n,)).copy()

    if any(a is not None for a in (lhr, mem, wb, pw)):
        res = resources.estimate_vector(
            cfg, lhr_matrix=lhr, mem_blocks_matrix=mem, weight_bits=wb,
            penc_width=pw, lib=lib)
        lut, reg = res.lut, res.reg
        bram, dsp = res.bram36, res.dsp
    else:                                    # only clock_mhz varies
        r = resources.estimate(cfg, lib)
        lut, reg, bram, dsp = r.lut, r.reg, r.bram36, r.dsp

    energy = resources.energy_mj_vector(
        cfg, counts, cycles, lhr_matrix=lhr, lut=lut, clock_mhz=clk, lib=lib)

    def bcast(x, dtype):
        return np.broadcast_to(np.asarray(x, dtype), (n,)).copy()

    return {"cycles": cycles,
            "lut": bcast(lut, np.float64),
            "reg": bcast(reg, np.float64),
            "bram": bcast(bram, np.int64),
            "dsp": bcast(dsp, np.int64),
            "energy": bcast(energy, np.float64)}
