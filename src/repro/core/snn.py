"""Spiking network definition (the paper's model substrate, in JAX).

Networks are declared as a sequence of layer specs (``Dense``, ``Conv``,
``MaxPool``) mirroring the topologies in the paper's Table I (net-1..net-5).
The temporal dimension is driven by ``lax.scan`` (BPTT unrolls through it);
every spiking layer's output train is returned so that

* ``repro.core.sparsity`` can reproduce the Fig.-1 firing-ratio analysis, and
* ``repro.core.accelerator.cycle_model`` can be driven by the *actual* spike
  traffic of the trained model — the paper's "dump spikes from snntorch"
  step.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, lif_step
from repro.kernels import ops as kernel_ops

PyTree = Any

# ---------------------------------------------------------------------------
# Matmul backends (DESIGN.md §11–§12)
# ---------------------------------------------------------------------------
# The accumulate phase of every Dense layer can run on the pure-jnp
# reference matmul, the block-skip Pallas kernel (``repro.kernels``, wrapped
# in a custom_vjp whose backward is also block-skip), or the fused
# GEMM+LIF scan-step kernel (``spike_gemm_fused``: the LIF update runs in
# the accumulate epilogue so membrane state never round-trips through HBM).
# Conv layers run the same block-skip accumulate over their im2col patch
# matrix (``kernels/spike_conv.py``) on both kernel backends.  ``None``
# resolves through the environment so DSE cell training can opt whole
# processes in without threading a flag.

MATMUL_BACKENDS = ("jnp", "spike_gemm", "spike_gemm_fused")
MATMUL_BACKEND_ENV = "REPRO_MATMUL_BACKEND"

#: kernel tile shape on the training path: batch rows are few (``block_m``
#: shares the f32 sublane minimum with the standalone LIF kernel's
#: ``block_b`` — one constant, see kernels/lif_step.py) while K rides full
#: 128-lane tiles — the skip granule benchmarks/bench_kernels.py measures.
KERNEL_BLOCKS = {"block_m": kernel_ops.LIF_BLOCKS["block_b"],
                 "block_n": 128, "block_k": 128}


def resolve_matmul_backend(backend: Optional[str] = None) -> str:
    """Resolve an explicit backend choice, falling back to the
    ``REPRO_MATMUL_BACKEND`` environment variable, then ``"jnp"``."""
    backend = backend or os.environ.get(MATMUL_BACKEND_ENV) or "jnp"
    if backend not in MATMUL_BACKENDS:
        raise ValueError(f"unknown matmul backend {backend!r}; "
                         f"pick from {MATMUL_BACKENDS}")
    return backend


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dense:
    features: int
    lif: LIFParams = LIFParams()


@dataclasses.dataclass(frozen=True)
class Conv:
    features: int
    kernel: int = 3
    stride: int = 1
    padding: str = "SAME"
    lif: LIFParams = LIFParams()


@dataclasses.dataclass(frozen=True)
class MaxPool:
    """Spike OR-pooling, non-overlapping (paper Sec. V-C: 2x2 OR gate)."""
    window: int = 2


LayerSpec = Union[Dense, Conv, MaxPool]


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    """A full spiking model: topology + coding hyper-parameters."""
    name: str
    input_shape: tuple[int, ...]          # (H, W, C) for conv nets, (D,) for MLPs
    layers: tuple[LayerSpec, ...]
    num_classes: int
    pcr: int = 1                          # population-coding ratio (neurons/class)
    num_steps: int = 25                   # spike-train length T

    @property
    def output_features(self) -> int:
        return self.num_classes * self.pcr

    def layer_sizes(self) -> list[int]:
        """Logical neuron count of every *spiking* layer (used for LHR sizing)."""
        sizes = []
        shape = self.input_shape
        for spec in self.layers:
            shape = _out_shape(spec, shape)
            if isinstance(spec, (Dense, Conv)):
                sizes.append(int(math.prod(shape)))
        return sizes

    def spiking_layers(self) -> list[LayerSpec]:
        return [l for l in self.layers if isinstance(l, (Dense, Conv))]


def _out_shape(spec: LayerSpec, in_shape: tuple[int, ...]) -> tuple[int, ...]:
    if isinstance(spec, Dense):
        return (spec.features,)
    if isinstance(spec, Conv):
        h, w, _ = in_shape
        if spec.padding == "SAME":
            oh, ow = -(-h // spec.stride), -(-w // spec.stride)
        else:
            oh = (h - spec.kernel) // spec.stride + 1
            ow = (w - spec.kernel) // spec.stride + 1
        return (oh, ow, spec.features)
    if isinstance(spec, MaxPool):
        h, w, c = in_shape
        return (h // spec.window, w // spec.window, c)
    raise TypeError(spec)


def output_shapes(cfg: SNNConfig) -> list[tuple[int, ...]]:
    shapes, shape = [], cfg.input_shape
    for spec in cfg.layers:
        shape = _out_shape(spec, shape)
        shapes.append(shape)
    return shapes


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: SNNConfig, dtype=jnp.float32) -> PyTree:
    params = []
    shape = cfg.input_shape
    for spec in cfg.layers:
        if isinstance(spec, Dense):
            fan_in = int(math.prod(shape))
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (fan_in, spec.features), dtype) / math.sqrt(fan_in)
            params.append({"w": w, "b": jnp.zeros((spec.features,), dtype)})
        elif isinstance(spec, Conv):
            cin = shape[-1]
            fan_in = spec.kernel * spec.kernel * cin
            key, sub = jax.random.split(key)
            w = jax.random.normal(
                sub, (spec.kernel, spec.kernel, cin, spec.features), dtype
            ) / math.sqrt(fan_in)
            params.append({"w": w, "b": jnp.zeros((spec.features,), dtype)})
        else:
            params.append({})
        shape = _out_shape(spec, shape)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_current(spec: LayerSpec, p: PyTree, s_in: jax.Array,
                   matmul_backend: str = "jnp",
                   perm: Optional[jax.Array] = None) -> jax.Array:
    """Synaptic current for one layer given the pre-synaptic spike tensor.

    The binary matmul here is the accelerator's accumulate phase.  With
    ``matmul_backend="spike_gemm"`` Dense layers route through
    ``repro.kernels`` (block-skip Pallas forward and backward via
    custom_vjp); the jnp path is the reference semantics.  The
    ``"spike_gemm_fused"`` backend bypasses this function entirely for Dense
    layers — ``step`` calls the fused GEMM+LIF kernel instead, so only jnp
    and spike_gemm (and every Conv layer) land here.  Conv layers route
    through the patch-tiled block-skip kernel (``ops.spike_conv_train``) on
    BOTH kernel backends; there is no fused conv epilogue, so the fused
    backend shares the spike_gemm conv path.  ``perm`` is an optional
    profiled pre-synaptic permutation (``ops.firing_rate_permutation``)
    that clusters cold neurons into skippable tiles — applied as
    ``S[:, perm] @ W[perm, :]``, which leaves the product invariant
    (Dense-only; conv layers take no permutation).
    """
    if isinstance(spec, Dense):
        flat = s_in.reshape(s_in.shape[0], -1)
        if matmul_backend == "spike_gemm":
            w = p["w"]
            if perm is not None:
                flat, w = kernel_ops.apply_permutation(flat, w, perm)
            return kernel_ops.spike_gemm_train(flat, w,
                                               **KERNEL_BLOCKS) + p["b"]
        return flat @ p["w"] + p["b"]
    if isinstance(spec, Conv):
        if matmul_backend in ("spike_gemm", "spike_gemm_fused"):
            return kernel_ops.spike_conv_train(
                s_in, p["w"], stride=spec.stride, padding=spec.padding,
                **KERNEL_BLOCKS) + p["b"]
        out = jax.lax.conv_general_dilated(
            s_in, p["w"],
            window_strides=(spec.stride, spec.stride),
            padding=spec.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return out + p["b"]
    raise TypeError(spec)


def _fused_dense_step(spec: Dense, p: PyTree, s_in: jax.Array,
                      state: tuple[jax.Array, jax.Array],
                      perm: Optional[jax.Array]
                      ) -> tuple[jax.Array, jax.Array]:
    """Accumulate + bias + LIF update in one Pallas pass
    (``matmul_backend="spike_gemm_fused"``): the kernel's epilogue applies
    the membrane update while the accumulator tile is VMEM-resident, so the
    (B, N) current never round-trips through HBM (DESIGN.md §12)."""
    flat = s_in.reshape(s_in.shape[0], -1)
    w = p["w"]
    if perm is not None:
        flat, w = kernel_ops.apply_permutation(flat, w, perm)
    u_prev, s_prev = state
    lif = spec.lif
    return kernel_ops.spike_gemm_lif_step(
        flat, w, p["b"], u_prev, s_prev,
        beta=lif.beta, threshold=lif.threshold, slope=lif.slope,
        reset_mechanism=lif.reset_mechanism, **KERNEL_BLOCKS)


def _or_pool(s: jax.Array, window: int) -> jax.Array:
    return jax.lax.reduce_window(
        s, -jnp.inf, jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, window, window, 1),
        padding="VALID",
    )


def init_states(cfg: SNNConfig, batch: int, dtype=jnp.float32) -> list:
    states, shape = [], cfg.input_shape
    for spec in cfg.layers:
        shape = _out_shape(spec, shape)
        if isinstance(spec, (Dense, Conv)):
            z = jnp.zeros((batch,) + shape, dtype)
            states.append((z, z))
        else:
            states.append(None)
    return states


def step(cfg: SNNConfig, params: PyTree, states: list, s_in: jax.Array,
         matmul_backend: str = "jnp",
         layer_perms: Optional[Sequence] = None
         ) -> tuple[list, list[jax.Array]]:
    """One time step through all layers.

    Returns (new_states, per-spiking-layer output spikes).  Note the hardware
    is layer-pipelined so different layers process different time steps
    concurrently; functionally (spike-to-spike) the result is identical to
    this sequential sweep, which is what the validation checks.

    ``layer_perms``: optional per-layer pre-synaptic permutations aligned
    with ``cfg.layers`` (``None`` entries for unpermuted layers; see
    ``train_snn.profiled_permutations``).
    """
    if layer_perms is not None and len(layer_perms) != len(cfg.layers):
        raise ValueError(f"layer_perms has {len(layer_perms)} entries for "
                         f"{len(cfg.layers)} layers")
    perms = layer_perms or (None,) * len(cfg.layers)
    new_states, spikes = [], []
    x = s_in
    for spec, p, st, perm in zip(cfg.layers, params, states, perms):
        if isinstance(spec, Dense) and matmul_backend == "spike_gemm_fused":
            u, s = _fused_dense_step(spec, p, x, st, perm)
            new_states.append((u, s))
            spikes.append(s)
            x = s
        elif isinstance(spec, (Dense, Conv)):
            cur = _layer_current(spec, p, x, matmul_backend, perm)
            u_prev, s_prev = st
            u, s = lif_step(u_prev, s_prev, cur, spec.lif)
            new_states.append((u, s))
            spikes.append(s)
            x = s
        elif isinstance(spec, MaxPool):
            x = _or_pool(x, spec.window)
            new_states.append(None)
        else:
            raise TypeError(spec)
    return new_states, spikes


def apply(cfg: SNNConfig, params: PyTree, spike_input: jax.Array,
          return_all_layers: bool = False,
          matmul_backend: Optional[str] = None,
          layer_perms: Optional[Sequence] = None):
    """Run the net over a (T, B, ...) input spike train.

    Returns the output layer's (T, B, n_out) spike train; with
    ``return_all_layers`` also every hidden layer's train (instrumentation).
    ``matmul_backend``/``layer_perms`` select the accumulate-phase execution
    path (see ``_layer_current``); results are backend-invariant.
    """
    backend = resolve_matmul_backend(matmul_backend)
    batch = spike_input.shape[1]
    states0 = init_states(cfg, batch)

    def scan_fn(states, s_in):
        new_states, spikes = step(cfg, params, states, s_in,
                                  matmul_backend=backend,
                                  layer_perms=layer_perms)
        out = spikes if return_all_layers else spikes[-1]
        return new_states, out

    _, collected = jax.lax.scan(scan_fn, states0, spike_input)
    return collected


def layer_input_trains(cfg: SNNConfig, params: PyTree,
                       spike_input: jax.Array,
                       matmul_backend: Optional[str] = None
                       ) -> list[jax.Array]:
    """The (T, B, ...) spike train **entering** each spiking layer.

    Entry ``l`` is spiking layer ``l``'s input traffic (entry 0 is the
    encoded input train); pooling between layers is applied first, because
    the hardware's ECU sees the pooled train.  This is the statistic behind
    both the cycle model (``spike_counts_per_layer``) and the profile-guided
    tile permutation (``train_snn.profiled_permutations``).
    """
    all_spikes = apply(cfg, params, spike_input, return_all_layers=True,
                       matmul_backend=matmul_backend)
    # Build the input train of each spiking layer: input spikes, then each
    # spiking layer's output (pooled if a MaxPool follows it).
    trains = [spike_input]
    spiking_idx = 0
    layer_list = list(cfg.layers)
    for i, spec in enumerate(layer_list):
        if isinstance(spec, (Dense, Conv)):
            train = all_spikes[spiking_idx]
            # apply any pooling that immediately follows
            j = i + 1
            while j < len(layer_list) and isinstance(layer_list[j], MaxPool):
                w = layer_list[j].window
                train = jax.vmap(lambda s: _or_pool(s, w))(train)
                j += 1
            trains.append(train)
            spiking_idx += 1
    # drop the final output train: it feeds no further layer
    return trains[:-1]


def spike_counts_per_layer(cfg: SNNConfig, params: PyTree,
                           spike_input: jax.Array,
                           matmul_backend: Optional[str] = None
                           ) -> list[jax.Array]:
    """Per-layer **input** spike counts, shape (T, B) each — the traffic
    statistic that drives the accelerator cycle model.

    Entry ``l`` counts spikes entering spiking layer ``l`` (so entry 0 counts
    the encoded input train); see ``layer_input_trains``.
    """
    trains = layer_input_trains(cfg, params, spike_input,
                                matmul_backend=matmul_backend)
    return [t.reshape(t.shape[0], t.shape[1], -1).sum(-1) for t in trains]
