"""Train-step builders: loss, gradient accumulation, optimizer update,
sharding constraints, donation.  One jit-compiled function per
(arch x shape x mesh) — the artifact the dry-run lowers and the launcher
runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding
from repro.models import registry

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    z_loss: float = 1e-4
    aux_loss_weight: float = 0.01        # MoE load-balance loss
    optimizer: str = "adamw"             # adamw | adafactor
    microbatches: int = 1                # gradient accumulation
    remat: bool = True


def make_optimizer(s: TrainSettings) -> optim.GradientTransform:
    if s.optimizer == "adafactor":
        return optim.adafactor_lite(s.learning_rate)
    return optim.adamw(s.learning_rate, weight_decay=s.weight_decay,
                       clip_norm=s.clip_norm)


def loss_fn(params: PyTree, cfg: ArchConfig, batch: dict, settings: TrainSettings,
            mesh=None) -> tuple[jax.Array, dict]:
    logits, aux = registry.forward(params, cfg, batch, remat=settings.remat)
    if mesh is not None:
        # keep the (B, S, V) logits sharded: batch over (pod, data), vocab
        # over model — the largest single activation in the program
        ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, P(ba, None, "model")))
    labels = batch["labels"]
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    # label logit via iota-mask reduction: elementwise over the
    # vocab-sharded logits + a sharded sum — take_along_axis would gather
    # (replicate) the full logits tensor
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits32.shape,
                                         logits32.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_ids == labels[..., None], logits32, 0.0), axis=-1)
    nll = jnp.mean(logz - label_logit)
    zl = settings.z_loss * jnp.mean(jnp.square(logz))
    total = nll + zl + settings.aux_loss_weight * aux
    return total, {"nll": nll, "z_loss": zl, "aux": aux}


def grads_fn(params: PyTree, cfg: ArchConfig, batch: dict,
             settings: TrainSettings, mesh=None):
    """(loss, metrics), grads — with optional microbatch accumulation."""
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if settings.microbatches <= 1:
        (loss, metrics), grads = vg(params, cfg, batch, settings, mesh)
        return loss, metrics, grads

    n = settings.microbatches

    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    # positions has batch on axis 1
    micro = {}
    for k, v in batch.items():
        if k == "positions":
            micro[k] = jnp.moveaxis(
                v.reshape(v.shape[0], n, v.shape[1] // n, *v.shape[2:]), 1, 0)
        else:
            micro[k] = split(v)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        (loss, metrics), grads = vg(params, cfg, mb, settings, mesh)
        grads_acc = jax.tree.map(lambda a, g: a + g, grads_acc, grads)
        return (loss_acc + loss, grads_acc), metrics

    zero_grads = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads), micro)
    grads = jax.tree.map(lambda g: g / n, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / n, metrics, grads


def build_train_step(cfg: ArchConfig, settings: TrainSettings, mesh=None
                     ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Not yet jitted — the caller wraps with jax.jit and shardings
    (launch/train.py, launch/dryrun.py)."""
    tx = make_optimizer(settings)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_fn(params, cfg, batch, settings, mesh)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = dict(metrics, loss=loss,
                       grad_norm=optim.global_norm(grads))
        return params, opt_state, metrics

    return train_step


def abstract_state(cfg: ArchConfig, settings: TrainSettings):
    """ShapeDtypeStructs for (params, opt_state) — no allocation."""
    tx = make_optimizer(settings)
    params = jax.eval_shape(
        lambda: registry.init_params(jax.random.key(0), cfg))
    opt_state = jax.eval_shape(tx.init, params)
    return params, opt_state


def state_shardings(cfg: ArchConfig, settings: TrainSettings, mesh):
    """NamedShardings for (params, opt_state).

    Optimizer state additionally shards over "data" (ZeRO-1) wherever a
    large leaf still has a free dim — fp32 moments are the biggest resident
    tensors and, unlike FSDP'd *weights*, resharding them costs one
    transfer per optimizer step, not per layer per microbatch.
    """
    params_s, opt_s = abstract_state(cfg, settings)
    p_specs = sharding.param_specs(cfg, params_s, mesh)
    o_specs = sharding.opt_state_specs(opt_s, params_s, p_specs)
    o_specs = jax.tree.map(
        lambda spec, leaf: (sharding.fsdp_extend(spec, leaf.shape, mesh,
                                                 min_size=4096,
                                                 skip_tp_experts=False)
                            if leaf.ndim >= 2 else spec),
        o_specs, opt_s, is_leaf=lambda x: isinstance(x, P))
    return (sharding.to_named(p_specs, mesh),
            sharding.to_named(o_specs, mesh), params_s, opt_s)
