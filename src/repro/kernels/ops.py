"""Public jit'd wrappers around the Pallas kernels: padding, flag
computation, dtype handling, and interpret-mode dispatch (this container has
no TPU; ``interpret=True`` runs the kernel bodies on CPU for validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.lif_step import lif_step_pallas
from repro.kernels.spike_gemm import spike_gemm_pallas


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("beta", "threshold",
                                             "reset_mechanism", "block_b",
                                             "block_n", "interpret"))
def lif_step(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array, *,
             beta: float, threshold: float, reset_mechanism: str = "subtract",
             block_b: int = 8, block_n: int = 512,
             interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF update on arbitrary (B, N); pads to tile multiples."""
    B, N = u_prev.shape
    args = [_pad_to(a, (block_b, block_n)) for a in (u_prev, s_prev, current)]
    u, s = lif_step_pallas(*args, beta=beta, threshold=threshold,
                           reset_mechanism=reset_mechanism,
                           block_b=block_b, block_n=block_n,
                           interpret=interpret)
    return u[:B, :N], s[:B, :N]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def spike_gemm(spikes: jax.Array, weights: jax.Array, *,
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               interpret: bool = True) -> jax.Array:
    """Sparsity-aware S @ W with block-level spike skipping."""
    M, K = spikes.shape
    _, N = weights.shape
    s = _pad_to(spikes, (block_m, block_k))
    w = _pad_to(weights, (block_k, block_n))
    flags = ref.block_flags_ref(s, block_m, block_k)
    out = spike_gemm_pallas(flags, s, w, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("capacity", "block_b",
                                             "interpret"))
def penc_compact(spikes: jax.Array, capacity: int, *, block_b: int = 8,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Spike-address extraction (the ECU's PENC) on (B, N) spike rows."""
    from repro.kernels.penc_compact import penc_compact_pallas
    B, N = spikes.shape
    s = _pad_to(spikes, (block_b, 1))
    idx, cnt = penc_compact_pallas(s, capacity=capacity, block_b=block_b,
                                   interpret=interpret)
    return idx[:B], cnt[:B]


def skip_fraction(spikes: jax.Array, block_m: int = 128,
                  block_k: int = 128) -> float:
    """Fraction of (M,K) tiles the kernel skips — the measurable benefit of
    the sparsity-aware design on given traffic."""
    s = _pad_to(spikes, (block_m, block_k))
    flags = ref.block_flags_ref(s, block_m, block_k)
    return float(1.0 - flags.mean())


# ---------------------------------------------------------------------------
# Profile-guided neuron permutation (beyond-paper optimization)
# ---------------------------------------------------------------------------
# Uniformly-spread spikes almost never leave a 128-wide tile empty, even at
# 1-10% firing (the paper's Fig.-1 regime): P(empty) = (1-p)^(bm*bk).  But SNN
# firing is heavy-tailed — a minority of neurons produce most spikes.  Sorting
# the pre-synaptic axis by *profiled* firing rate (the very statistic the
# paper's DSE collects) clusters cold neurons into tiles that are empty on
# most steps.  The weight rows are permuted once, offline; runtime cost is
# zero.  This is the LHR-style "allocate by observed sparsity" insight applied
# to MXU tiles instead of hardware neurons.

def firing_rate_permutation(rates: jax.Array) -> jax.Array:
    """Permutation placing rarely-firing pre-synaptic neurons first.

    ``rates``: (K,) mean firing probability per neuron (from profiling).
    Apply to spike columns and weight rows: ``S[:, perm] @ W[perm, :]``.
    """
    return jnp.argsort(rates)


def apply_permutation(spikes: jax.Array, weights: jax.Array,
                      perm: jax.Array) -> tuple[jax.Array, jax.Array]:
    return spikes[:, perm], weights[perm, :]


def spike_gemm_profiled(spikes: jax.Array, weights: jax.Array,
                        perm: jax.Array, **kw) -> jax.Array:
    """spike_gemm with a profile-guided pre-synaptic permutation; exactly
    equal to the unpermuted product (permutation-invariance of matmul)."""
    s, w = apply_permutation(spikes, weights, perm)
    return spike_gemm(s, w, **kw)
