"""Public jit'd wrappers around the Pallas kernels: padding, flag
computation, dtype handling, and interpret-mode dispatch (this container has
no TPU; ``interpret=True`` runs the kernel bodies on CPU for validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.lif_step import lif_step_pallas
from repro.kernels.spike_gemm import spike_gemm_pallas


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("beta", "threshold",
                                             "reset_mechanism", "block_b",
                                             "block_n", "interpret"))
def lif_step(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array, *,
             beta: float, threshold: float, reset_mechanism: str = "subtract",
             block_b: int = 8, block_n: int = 512,
             interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF update on arbitrary (B, N); pads to tile multiples."""
    B, N = u_prev.shape
    args = [_pad_to(a, (block_b, block_n)) for a in (u_prev, s_prev, current)]
    u, s = lif_step_pallas(*args, beta=beta, threshold=threshold,
                           reset_mechanism=reset_mechanism,
                           block_b=block_b, block_n=block_n,
                           interpret=interpret)
    return u[:B, :N], s[:B, :N]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def block_flags(spikes: jax.Array, *, block_m: int = 128,
                block_k: int = 128) -> jax.Array:
    """Per-tile occupancy flags for ``spikes`` padded to block multiples —
    the array ``spike_gemm`` prefetches.  Computed once here, it can be fed
    back via ``spike_gemm(..., flags=...)`` so hot loops that already
    measured ``skip_fraction`` don't pay the reduction twice."""
    s = _pad_to(spikes, (block_m, block_k))
    return ref.block_flags_ref(s, block_m, block_k)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def spike_gemm(spikes: jax.Array, weights: jax.Array, *,
               flags: jax.Array = None,
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               interpret: bool = True) -> jax.Array:
    """Sparsity-aware S @ W with block-level spike skipping.

    ``flags``: optional precomputed occupancy from ``block_flags`` (same
    block shape); when omitted the flags are computed here.
    """
    M, K = spikes.shape
    _, N = weights.shape
    s = _pad_to(spikes, (block_m, block_k))
    w = _pad_to(weights, (block_k, block_n))
    if flags is None:
        flags = ref.block_flags_ref(s, block_m, block_k)
    want = (s.shape[0] // block_m, s.shape[1] // block_k)
    if flags.shape != want:
        raise ValueError(
            f"flags shape {flags.shape} does not match the {want} tile grid "
            f"of spikes {spikes.shape} at block_m={block_m}, "
            f"block_k={block_k}; build them with ops.block_flags on the "
            f"same spike matrix and block sizes")
    out = spike_gemm_pallas(flags, s, w, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Differentiable spike GEMM (the training hot path)
# ---------------------------------------------------------------------------
# BPTT needs gradients through the accumulate phase; the Pallas kernel only
# defines a forward.  ``spike_gemm_train`` wraps it in a ``jax.custom_vjp``:
# block-skip forward, *dense reference* backward (the exact jnp cotangents
# dS = g @ W^T, dW = S^T @ g) — so surrogate-gradient training through
# ``lax.scan`` is numerically the same as the pure-jnp path while the
# forward skips empty spike tiles.  DESIGN.md §11.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spike_gemm_train(blocks: tuple, spikes: jax.Array,
                      weights: jax.Array) -> jax.Array:
    block_m, block_n, block_k, interpret = blocks
    return spike_gemm(spikes, weights, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def _spike_gemm_train_fwd(blocks, spikes, weights):
    return _spike_gemm_train(blocks, spikes, weights), (spikes, weights)


def _spike_gemm_train_bwd(blocks, res, g):
    spikes, weights = res
    g32 = g.astype(jnp.float32)
    d_spikes = jnp.dot(g32, weights.T,
                       preferred_element_type=jnp.float32).astype(spikes.dtype)
    d_weights = jnp.dot(spikes.T, g32,
                        preferred_element_type=jnp.float32).astype(weights.dtype)
    return d_spikes, d_weights


_spike_gemm_train.defvjp(_spike_gemm_train_fwd, _spike_gemm_train_bwd)


def spike_gemm_train(spikes: jax.Array, weights: jax.Array, *,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, interpret: bool = True) -> jax.Array:
    """Differentiable S @ W: block-skip Pallas forward, dense jnp backward."""
    return _spike_gemm_train((block_m, block_n, block_k, interpret),
                             spikes, weights)


@functools.partial(jax.jit, static_argnames=("capacity", "block_b",
                                             "interpret"))
def penc_compact(spikes: jax.Array, capacity: int, *, block_b: int = 8,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Spike-address extraction (the ECU's PENC) on (B, N) spike rows."""
    from repro.kernels.penc_compact import penc_compact_pallas
    B, N = spikes.shape
    s = _pad_to(spikes, (block_b, 1))
    idx, cnt = penc_compact_pallas(s, capacity=capacity, block_b=block_b,
                                   interpret=interpret)
    return idx[:B], cnt[:B]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def _skip_fraction(spikes: jax.Array, *, block_m: int, block_k: int):
    flags = block_flags(spikes, block_m=block_m, block_k=block_k)
    return 1.0 - flags.astype(jnp.float32).mean()


def skip_fraction(spikes: jax.Array, block_m: int = 128,
                  block_k: int = 128) -> float:
    """Fraction of (M,K) tiles the kernel skips — the measurable benefit of
    the sparsity-aware design on given traffic.

    Jitted (pad + tile-reduce fuse and the trace is cached per shape), so
    calling it on the benchmark hot loop costs one compiled reduction, not
    an eager re-pad per call; pair with ``block_flags`` + ``spike_gemm(...,
    flags=...)`` to reuse the same occupancy for the matmul itself."""
    # clamp: fp rounding of the mean can land a hair past 1.0
    return max(0.0, float(_skip_fraction(spikes, block_m=block_m,
                                         block_k=block_k)))


# ---------------------------------------------------------------------------
# Profile-guided neuron permutation (beyond-paper optimization)
# ---------------------------------------------------------------------------
# Uniformly-spread spikes almost never leave a 128-wide tile empty, even at
# 1-10% firing (the paper's Fig.-1 regime): P(empty) = (1-p)^(bm*bk).  But SNN
# firing is heavy-tailed — a minority of neurons produce most spikes.  Sorting
# the pre-synaptic axis by *profiled* firing rate (the very statistic the
# paper's DSE collects) clusters cold neurons into tiles that are empty on
# most steps.  The weight rows are permuted once, offline; runtime cost is
# zero.  This is the LHR-style "allocate by observed sparsity" insight applied
# to MXU tiles instead of hardware neurons.

def firing_rate_permutation(rates: jax.Array) -> jax.Array:
    """Permutation placing rarely-firing pre-synaptic neurons first.

    ``rates``: (K,) mean firing probability per neuron (from profiling).
    Apply to spike columns and weight rows: ``S[:, perm] @ W[perm, :]``.
    """
    return jnp.argsort(rates)


def apply_permutation(spikes: jax.Array, weights: jax.Array,
                      perm: jax.Array) -> tuple[jax.Array, jax.Array]:
    return spikes[:, perm], weights[perm, :]


def spike_gemm_profiled(spikes: jax.Array, weights: jax.Array,
                        perm: jax.Array, **kw) -> jax.Array:
    """spike_gemm with a profile-guided pre-synaptic permutation; exactly
    equal to the unpermuted product (permutation-invariance of matmul)."""
    s, w = apply_permutation(spikes, weights, perm)
    return spike_gemm(s, w, **kw)
