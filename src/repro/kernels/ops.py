"""Public jit'd wrappers around the Pallas kernels: padding, flag
computation, dtype handling, and interpret-mode dispatch (this container has
no TPU; ``interpret=True`` runs the kernel bodies on CPU for validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.lif_step import LIF_BLOCKS, lif_step_pallas
from repro.kernels.spike_conv import (conv_out_size, conv_patches,
                                      spike_conv_pallas)
from repro.kernels.spike_gemm import spike_gemm_pallas
from repro.kernels.spike_gemm_bwd import (spike_gemm_ds_pallas,
                                          spike_gemm_dw_pallas)
from repro.kernels.spike_gemm_fused import spike_gemm_lif_pallas


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("beta", "threshold",
                                             "reset_mechanism", "block_b",
                                             "block_n", "interpret"))
def lif_step(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array, *,
             beta: float, threshold: float, reset_mechanism: str = "subtract",
             block_b: int = LIF_BLOCKS["block_b"],
             block_n: int = LIF_BLOCKS["block_n"],
             interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF update on arbitrary (B, N); pads to tile multiples.

    Default tile is ``lif_step.LIF_BLOCKS`` (shared with the kernel module;
    see the constant's note on why it is wider than ``snn.KERNEL_BLOCKS``).
    """
    B, N = u_prev.shape
    args = [_pad_to(a, (block_b, block_n)) for a in (u_prev, s_prev, current)]
    u, s = lif_step_pallas(*args, beta=beta, threshold=threshold,
                           reset_mechanism=reset_mechanism,
                           block_b=block_b, block_n=block_n,
                           interpret=interpret)
    return u[:B, :N], s[:B, :N]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def block_flags(spikes: jax.Array, *, block_m: int = 128,
                block_k: int = 128) -> jax.Array:
    """Per-tile occupancy flags for ``spikes`` padded to block multiples —
    the array ``spike_gemm`` prefetches.  Computed once here, it can be fed
    back via ``spike_gemm(..., flags=...)`` so hot loops that already
    measured ``skip_fraction`` don't pay the reduction twice."""
    s = _pad_to(spikes, (block_m, block_k))
    return ref.block_flags_ref(s, block_m, block_k)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def spike_gemm(spikes: jax.Array, weights: jax.Array, *,
               flags: jax.Array = None,
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               interpret: bool = True) -> jax.Array:
    """Sparsity-aware S @ W with block-level spike skipping.

    ``flags``: optional precomputed occupancy from ``block_flags`` (same
    block shape); when omitted the flags are computed here.
    """
    M, K = spikes.shape
    _, N = weights.shape
    s = _pad_to(spikes, (block_m, block_k))
    w = _pad_to(weights, (block_k, block_n))
    if flags is None:
        flags = ref.block_flags_ref(s, block_m, block_k)
    want = (s.shape[0] // block_m, s.shape[1] // block_k)
    if flags.shape != want:
        raise ValueError(
            f"flags shape {flags.shape} does not match the {want} tile grid "
            f"of spikes {spikes.shape} at block_m={block_m}, "
            f"block_k={block_k}; build them with ops.block_flags on the "
            f"same spike matrix and block sizes")
    out = spike_gemm_pallas(flags, s, w, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Block-skip backward kernels (the other two matmuls of BPTT)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def cotangent_block_flags(g: jax.Array, *, block_m: int = 128,
                          block_n: int = 128) -> jax.Array:
    """Any-nonzero per-tile occupancy of a SIGNED cotangent, padded to block
    multiples — the gate of the dS backward pass.  Distinct from
    ``block_flags``: a float tile whose entries cancel to a zero sum still
    holds work (``ref.block_flags_any_ref``)."""
    gp = _pad_to(g, (block_m, block_n))
    return ref.block_flags_any_ref(gp, block_m, block_n)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def spike_gemm_bwd_dw(spikes: jax.Array, g: jax.Array, *,
                      flags: jax.Array = None,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = True) -> jax.Array:
    """dW[K,N] = Sᵀ·g with block-skip on the spike tiles.

    ``flags``: the FORWARD's occupancy array (``block_flags`` on the same
    spike matrix and block sizes) — a skipped (m, k) spike tile is all-zero
    and contributes exactly zero to dW rows k, so reusing the flags makes the
    sparse backward bit-identical to running the same kernel unskipped.
    """
    M, K = spikes.shape
    _, N = g.shape
    s = _pad_to(spikes, (block_m, block_k))
    gp = _pad_to(g, (block_m, block_n))
    if flags is None:
        flags = ref.block_flags_ref(s, block_m, block_k)
    want = (s.shape[0] // block_m, s.shape[1] // block_k)
    if flags.shape != want:
        raise ValueError(
            f"flags shape {flags.shape} does not match the {want} tile grid "
            f"of spikes {spikes.shape} at block_m={block_m}, "
            f"block_k={block_k}")
    dw = spike_gemm_dw_pallas(flags, s, gp, block_m=block_m, block_n=block_n,
                              block_k=block_k, interpret=interpret)
    return dw[:K, :N]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def spike_gemm_bwd_ds(g: jax.Array, weights: jax.Array, *,
                      gflags: jax.Array = None,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = True) -> jax.Array:
    """dS[M,K] = g·Wᵀ with block-skip on the cotangent tiles.

    Surrogate-gradient cotangents vanish wherever ``|u - θ|`` is large, so
    whole (m, n) tiles of ``g`` are exactly zero late in training; ``gflags``
    (``cotangent_block_flags``) gates the accumulate the same way the
    forward's spike flags do.
    """
    M, N = g.shape
    K, _ = weights.shape
    gp = _pad_to(g, (block_m, block_n))
    w = _pad_to(weights, (block_k, block_n))
    if gflags is None:
        gflags = ref.block_flags_any_ref(gp, block_m, block_n)
    want = (gp.shape[0] // block_m, gp.shape[1] // block_n)
    if gflags.shape != want:
        raise ValueError(
            f"gflags shape {gflags.shape} does not match the {want} tile "
            f"grid of g {g.shape} at block_m={block_m}, block_n={block_n}")
    ds = spike_gemm_ds_pallas(gflags, gp, w, block_m=block_m, block_n=block_n,
                              block_k=block_k, interpret=interpret)
    return ds[:M, :K]


# ---------------------------------------------------------------------------
# Differentiable spike GEMM (the training hot path)
# ---------------------------------------------------------------------------
# BPTT needs gradients through the accumulate phase.  ``spike_gemm_train``
# wraps the Pallas kernels in a ``jax.custom_vjp``: block-skip forward AND
# block-skip backward — dW = Sᵀ·g reuses the forward's occupancy flags
# (saved in the VJP residuals so neither pass recomputes the reduction),
# dS = g·Wᵀ is gated on any-nonzero cotangent-tile occupancy.  Skipping is
# exact in both directions (an empty tile contributes exactly zero), so
# surrogate-gradient training through ``lax.scan`` stays numerically the
# dense reference up to fp32 tile-order rounding.  DESIGN.md §11–§12.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spike_gemm_train(blocks: tuple, spikes: jax.Array,
                      weights: jax.Array) -> jax.Array:
    block_m, block_n, block_k, interpret = blocks
    return spike_gemm(spikes, weights, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def _spike_gemm_train_fwd(blocks, spikes, weights):
    block_m, block_n, block_k, interpret = blocks
    flags = block_flags(spikes, block_m=block_m, block_k=block_k)
    out = spike_gemm(spikes, weights, flags=flags, block_m=block_m,
                     block_n=block_n, block_k=block_k, interpret=interpret)
    return out, (spikes, weights, flags)


def _spike_gemm_train_bwd(blocks, res, g):
    block_m, block_n, block_k, interpret = blocks
    spikes, weights, flags = res
    g32 = g.astype(jnp.float32)
    d_spikes = spike_gemm_bwd_ds(
        g32, weights, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret).astype(spikes.dtype)
    d_weights = spike_gemm_bwd_dw(
        spikes, g32, flags=flags, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret).astype(weights.dtype)
    return d_spikes, d_weights


_spike_gemm_train.defvjp(_spike_gemm_train_fwd, _spike_gemm_train_bwd)


def spike_gemm_train(spikes: jax.Array, weights: jax.Array, *,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, interpret: bool = True) -> jax.Array:
    """Differentiable S @ W: block-skip Pallas forward and backward."""
    return _spike_gemm_train((block_m, block_n, block_k, interpret),
                             spikes, weights)


# ---------------------------------------------------------------------------
# Block-skip spike convolution (the conv datapath of the same engine)
# ---------------------------------------------------------------------------
# A Conv layer is the same sparsity-aware accumulate run over the im2col view
# of its spike input: patches of {0,1} spikes are still {0,1} spikes, so the
# sum>0 occupancy gate of ``block_flags`` stays exact on the patch matrix and
# both backward matmuls are ordinary GEMM cotangents of that matrix — the
# dW/dS kernels of spike_gemm_bwd.py are reused verbatim.  DESIGN.md §13.

@functools.partial(jax.jit, static_argnames=("stride", "padding", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def spike_conv(s_in: jax.Array, weights: jax.Array, *, stride: int = 1,
               padding: str = "SAME", flags: jax.Array = None,
               block_m: int = 128, block_n: int = 128, block_k: int = 128,
               interpret: bool = True) -> jax.Array:
    """Sparsity-aware NHWC x HWIO convolution with patch-tile skipping.

    ``flags``: optional precomputed occupancy of the PATCH matrix
    (``block_flags(conv_patches(s_in, ...))`` with the same block sizes);
    when omitted the flags are computed here.  Output is (B, OH, OW, F),
    bit-identical to ``lax.conv_general_dilated`` up to fp32 tile-order
    rounding (exactly equal on grid operands — see tests/test_kernels.py).
    """
    B, H, W, C = s_in.shape
    kh, kw, cin, cout = weights.shape
    if cin != C:
        raise ValueError(f"weights expect {cin} input channels, spikes "
                         f"have {C}")
    oh, _, _ = conv_out_size(H, kh, stride, padding)
    ow, _, _ = conv_out_size(W, kw, stride, padding)
    patches = conv_patches(s_in, kh, kw, stride, padding)
    p = _pad_to(patches, (block_m, block_k))
    w = _pad_to(weights.reshape(kh * kw * cin, cout), (block_k, block_n))
    if flags is None:
        flags = ref.block_flags_ref(p, block_m, block_k)
    want = (p.shape[0] // block_m, p.shape[1] // block_k)
    if flags.shape != want:
        raise ValueError(
            f"flags shape {flags.shape} does not match the {want} tile grid "
            f"of the patch matrix {patches.shape} at block_m={block_m}, "
            f"block_k={block_k}; build them with ops.block_flags on "
            f"ops.conv_patches of the same spike tensor")
    out = spike_conv_pallas(flags, p, w, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
    return out[:B * oh * ow, :cout].reshape(B, oh, ow, cout)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spike_conv_train(static: tuple, s_in: jax.Array,
                      weights: jax.Array) -> jax.Array:
    stride, padding, block_m, block_n, block_k, interpret = static
    return spike_conv(s_in, weights, stride=stride, padding=padding,
                      block_m=block_m, block_n=block_n, block_k=block_k,
                      interpret=interpret)


def _spike_conv_train_fwd(static, s_in, weights):
    stride, padding, block_m, block_n, block_k, interpret = static
    kh, kw = weights.shape[:2]
    patches = conv_patches(s_in, kh, kw, stride, padding)
    flags = block_flags(patches, block_m=block_m, block_k=block_k)
    out = spike_conv(s_in, weights, stride=stride, padding=padding,
                     flags=flags, block_m=block_m, block_n=block_n,
                     block_k=block_k, interpret=interpret)
    # the flags ride the residuals (PR-6 contract): the backward reuses the
    # forward's occupancy reduction instead of recomputing it.  The patch
    # matrix itself is NOT saved — it is cheap deterministic slicing of
    # ``s_in`` and rebuilding it keeps residual memory at O(B·H·W·C) instead
    # of O(B·OH·OW·KH·KW·C).
    return out, (s_in, weights, flags)


def _spike_conv_train_bwd(static, res, g):
    stride, padding, block_m, block_n, block_k, interpret = static
    s_in, weights, flags = res
    kh, kw, cin, cout = weights.shape
    patch_fn = lambda x: conv_patches(x, kh, kw, stride, padding)
    patches, unpatch = jax.vjp(patch_fn, s_in)
    g2 = g.reshape(-1, cout).astype(jnp.float32)
    d_w = spike_gemm_bwd_dw(
        patches, g2, flags=flags, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret)
    d_patches = spike_gemm_bwd_ds(
        g2, weights.reshape(kh * kw * cin, cout), block_m=block_m,
        block_n=block_n, block_k=block_k, interpret=interpret)
    # col2im: the exact linear transpose of conv_patches (pad + strided
    # slice + concat), derived by jax.vjp so overlap scatter-adds match the
    # dense conv's input cotangent bit for bit on grid operands.
    (d_s,) = unpatch(d_patches.astype(s_in.dtype))
    return d_s, d_w.reshape(kh, kw, cin, cout).astype(weights.dtype)


_spike_conv_train.defvjp(_spike_conv_train_fwd, _spike_conv_train_bwd)


def spike_conv_train(s_in: jax.Array, weights: jax.Array, *, stride: int = 1,
                     padding: str = "SAME", block_m: int = 128,
                     block_n: int = 128, block_k: int = 128,
                     interpret: bool = True) -> jax.Array:
    """Differentiable block-skip convolution: patch-tiled forward, block-skip
    dW/dS backward reusing the forward's flags from the VJP residuals."""
    return _spike_conv_train(
        (int(stride), str(padding), block_m, block_n, block_k, interpret),
        s_in, weights)


# ---------------------------------------------------------------------------
# Fused GEMM + LIF scan step (matmul_backend="spike_gemm_fused")
# ---------------------------------------------------------------------------
# One Dense training step is accumulate -> +bias -> leak/threshold/reset;
# ``spike_gemm_lif_step`` runs all of it in the fused Pallas kernel
# (spike_gemm_fused.py) so membrane state never round-trips through HBM
# between the matmul and the neuron update.  The custom_vjp backward applies
# the fast-sigmoid surrogate (exactly ``lif.spike_fn``'s rule), the LIF
# chain rule, and the two block-skip backward kernels above — the forward's
# flags again ride the residuals.  DESIGN.md §12.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _spike_gemm_lif_train(static: tuple, spikes: jax.Array,
                          weights: jax.Array, bias: jax.Array,
                          u_prev: jax.Array, s_prev: jax.Array
                          ) -> tuple[jax.Array, jax.Array]:
    out, _ = _spike_gemm_lif_fwd_impl(static, spikes, weights, bias,
                                      u_prev, s_prev)
    return out


def _spike_gemm_lif_fwd_impl(static, spikes, weights, bias, u_prev, s_prev):
    (block_m, block_n, block_k, interpret,
     beta, threshold, slope, reset_mechanism) = static
    B, K = spikes.shape
    _, N = weights.shape
    s = _pad_to(spikes, (block_m, block_k))
    w = _pad_to(weights, (block_k, block_n))
    b = _pad_to(bias.reshape(1, -1), (1, block_n))
    u0 = _pad_to(u_prev, (block_m, block_n))
    s0 = _pad_to(s_prev, (block_m, block_n))
    flags = ref.block_flags_ref(s, block_m, block_k)
    u, sp = spike_gemm_lif_pallas(
        flags, s, w, b, u0, s0, beta=beta, threshold=threshold,
        reset_mechanism=reset_mechanism, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret)
    return (u[:B, :N], sp[:B, :N]), flags


def _spike_gemm_lif_train_fwd(static, spikes, weights, bias, u_prev, s_prev):
    (u, sp), flags = _spike_gemm_lif_fwd_impl(static, spikes, weights, bias,
                                              u_prev, s_prev)
    return (u, sp), (spikes, weights, bias, u_prev, s_prev, u, flags)


def _spike_gemm_lif_train_bwd(static, res, cots):
    (block_m, block_n, block_k, interpret,
     beta, threshold, slope, reset_mechanism) = static
    spikes, weights, bias, u_prev, s_prev, u, flags = res
    gu, gs = cots
    # fast-sigmoid surrogate through s = H(u - theta), then the LIF chain
    # rule — term for term what autodiff derives on the unfused
    # lif.lif_step, so fused and unfused cotangents agree.
    v = u - threshold
    surr = 1.0 / jnp.square(1.0 + slope * jnp.abs(v))
    g = gu + gs * surr
    if reset_mechanism == "subtract":
        d_u_prev = beta * g
        d_s_prev = -threshold * g
    else:
        d_u_prev = beta * (1.0 - s_prev) * g
        d_s_prev = -(beta * u_prev) * g
    g32 = g.astype(jnp.float32)
    d_bias = g32.sum(0).astype(bias.dtype)
    d_spikes = spike_gemm_bwd_ds(
        g32, weights, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret).astype(spikes.dtype)
    d_weights = spike_gemm_bwd_dw(
        spikes, g32, flags=flags, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret).astype(weights.dtype)
    return d_spikes, d_weights, d_bias, d_u_prev.astype(u_prev.dtype), \
        d_s_prev.astype(s_prev.dtype)


_spike_gemm_lif_train.defvjp(_spike_gemm_lif_train_fwd,
                             _spike_gemm_lif_train_bwd)


def spike_gemm_lif_step(spikes: jax.Array, weights: jax.Array,
                        bias: jax.Array, u_prev: jax.Array,
                        s_prev: jax.Array, *, beta: float, threshold: float,
                        slope: float = 25.0,
                        reset_mechanism: str = "subtract",
                        block_m: int = 8, block_n: int = 128,
                        block_k: int = 128, interpret: bool = True
                        ) -> tuple[jax.Array, jax.Array]:
    """Differentiable fused scan step: (u, s) = LIF(u, s, S @ W + b).

    Bit-identical forward to ``spike_gemm_train(S, W) + b`` composed with
    ``lif.lif_step`` (same accumulate order, same epilogue expression);
    surrogate-gradient backward through the block-skip kernels.
    """
    return _spike_gemm_lif_train(
        (block_m, block_n, block_k, interpret,
         float(beta), float(threshold), float(slope), reset_mechanism),
        spikes, weights, bias, u_prev, s_prev)


@functools.partial(jax.jit, static_argnames=("capacity", "block_b",
                                             "interpret"))
def penc_compact(spikes: jax.Array, capacity: int, *, block_b: int = 8,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Spike-address extraction (the ECU's PENC) on (B, N) spike rows."""
    from repro.kernels.penc_compact import penc_compact_pallas
    B, N = spikes.shape
    s = _pad_to(spikes, (block_b, 1))
    idx, cnt = penc_compact_pallas(s, capacity=capacity, block_b=block_b,
                                   interpret=interpret)
    return idx[:B], cnt[:B]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def _skip_fraction(spikes: jax.Array, *, block_m: int, block_k: int):
    flags = block_flags(spikes, block_m=block_m, block_k=block_k)
    return 1.0 - flags.astype(jnp.float32).mean()


def skip_fraction(spikes: jax.Array, block_m: int = 128,
                  block_k: int = 128) -> float:
    """Fraction of (M,K) tiles the kernel skips — the measurable benefit of
    the sparsity-aware design on given traffic.

    Jitted (pad + tile-reduce fuse and the trace is cached per shape), so
    calling it on the benchmarks/bench_kernels.py hot loop costs one
    compiled reduction, not an eager re-pad per call; pair with
    ``block_flags`` + ``spike_gemm(..., flags=...)`` to reuse the same
    occupancy for the matmul itself."""
    # clamp: fp rounding of the mean can land a hair past 1.0
    return max(0.0, float(_skip_fraction(spikes, block_m=block_m,
                                         block_k=block_k)))


# ---------------------------------------------------------------------------
# Profile-guided neuron permutation (beyond-paper optimization)
# ---------------------------------------------------------------------------
# Uniformly-spread spikes almost never leave a 128-wide tile empty, even at
# 1-10% firing (the paper's Fig.-1 regime): P(empty) = (1-p)^(bm*bk).  But SNN
# firing is heavy-tailed — a minority of neurons produce most spikes.  Sorting
# the pre-synaptic axis by *profiled* firing rate (the very statistic the
# paper's DSE collects) clusters cold neurons into tiles that are empty on
# most steps.  The weight rows are permuted once, offline; runtime cost is
# zero.  This is the LHR-style "allocate by observed sparsity" insight applied
# to MXU tiles instead of hardware neurons.

def firing_rate_permutation(rates: jax.Array) -> jax.Array:
    """Permutation placing rarely-firing pre-synaptic neurons first.

    ``rates``: (K,) mean firing probability per neuron (from profiling).
    Apply to spike columns and weight rows: ``S[:, perm] @ W[perm, :]``.
    """
    return jnp.argsort(rates)


def apply_permutation(spikes: jax.Array, weights: jax.Array,
                      perm: jax.Array) -> tuple[jax.Array, jax.Array]:
    return spikes[:, perm], weights[perm, :]


def spike_gemm_profiled(spikes: jax.Array, weights: jax.Array,
                        perm: jax.Array, **kw) -> jax.Array:
    """spike_gemm with a profile-guided pre-synaptic permutation; exactly
    equal to the unpermuted product (permutation-invariance of matmul)."""
    s, w = apply_permutation(spikes, weights, perm)
    return spike_gemm(s, w, **kw)
