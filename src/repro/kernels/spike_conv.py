"""Block-skip spike convolution — the sparsity-aware conv datapath.

PULSE (arXiv:2402.06210) extends the paper's sparsity-aware accumulate
engine to convolution: incoming spike events only cost work for the output
pixels whose receptive field they touch.  On a TPU the skip granularity is
again an MXU tile (DESIGN.md §2), so the conv is *patch-tiled*:

  1. the (B, H, W, C) spike tensor is lowered to its im2col view — a
     (B·OH·OW, KH·KW·C) patch matrix whose rows are receptive fields and
     whose entries are literal copies of spike bits (zero-padding adds
     zeros), so the patch matrix is itself a {0,1} spike matrix;
  2. per-tile occupancy flags are computed on the patch matrix with the
     *same* ``ops.block_flags`` reduction the Dense path uses — exact for
     {0,1} entries because a tile sums to zero iff it holds no spike;
  3. the kernel below runs the block-skip accumulate over
     ``patches @ W.reshape(KH·KW·C, F)``; an empty patch tile (a tile of
     receptive fields that saw no spikes) costs one SMEM read instead of a
     MAC block, exactly as in ``spike_gemm.py``.

The dW/dS backward matmuls of the conv are plain GEMM cotangents of the
patch matrix, so they reuse the block-skip backward kernels of
``spike_gemm_bwd.py`` verbatim (dW on the forward's flags, dS on any-nonzero
cotangent occupancy); the fold back from patch-space to the input spike
tensor is the exact linear transpose of ``conv_patches`` (DESIGN.md §13).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def conv_out_size(size: int, kernel: int, stride: int,
                  padding: str) -> tuple[int, int, int]:
    """(output size, pad_lo, pad_hi) for one spatial dim — XLA's convention
    (``lax.padtype_to_pads``), so the patch view matches ``lax.conv`` SAME
    semantics exactly."""
    if padding == "SAME":
        out = -(-size // stride)
        pad = max((out - 1) * stride + kernel - size, 0)
        return out, pad // 2, pad - pad // 2
    if padding == "VALID":
        return (size - kernel) // stride + 1, 0, 0
    raise ValueError(f"unknown padding {padding!r}; pick SAME or VALID")


def conv_patches(s_in: jax.Array, kh: int, kw: int, stride: int,
                 padding: str) -> jax.Array:
    """im2col: (B, H, W, C) -> (B·OH·OW, KH·KW·C) patch matrix.

    Row ``b·OH·OW + oh·OW + ow`` is output pixel (b, oh, ow)'s receptive
    field; features are ordered (dy, dx, c) so the matching weight matrix is
    simply ``w.reshape(KH·KW·C, F)`` of the HWIO layout.  Pure pad + strided
    slice + concatenate — linear, so its ``jax.vjp`` is the exact col2im
    scatter-add the backward needs.
    """
    B, H, W, C = s_in.shape
    oh, ph_lo, ph_hi = conv_out_size(H, kh, stride, padding)
    ow, pw_lo, pw_hi = conv_out_size(W, kw, stride, padding)
    xp = jnp.pad(s_in, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy:dy + (oh - 1) * stride + 1:stride,
                           dx:dx + (ow - 1) * stride + 1:stride, :])
    patches = jnp.concatenate(cols, axis=-1)          # (B, OH, OW, KH·KW·C)
    return patches.reshape(B * oh * ow, kh * kw * C)


def _spike_conv_kernel(flags_ref, p_ref, w_ref, o_ref, acc_ref):
    """Block-skip accumulate over the patch matrix (mirrors
    ``spike_gemm.py``: reduction innermost, VMEM f32 accumulator, ``pl.when``
    gating the dot on the scalar-prefetched patch-tile flag)."""
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(flags_ref[i, k] != 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(p_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def spike_conv_pallas(flags: jax.Array, patches: jax.Array,
                      weights: jax.Array, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 128,
                      out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """out[M,N] = patches[M,K] @ weights[K,N], skipping empty patch tiles.

    ``patches``: the im2col view (M = B·OH·OW receptive-field rows,
    K = KH·KW·C); ``weights``: the HWIO filter reshaped to (K, F).
    ``flags``: (M//block_m, K//block_k) occupancy of the patch matrix
    (``ref.block_flags_ref`` — exact for {0,1} spikes).  Shapes must be
    pre-padded to block multiples (the ops.py wrapper pads).
    """
    M, K = patches.shape
    K2, N = weights.shape
    assert K == K2 and M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    grid = (M // block_m, N // block_n, K // block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, flags: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k, flags: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, flags: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _spike_conv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(flags, patches, weights)
