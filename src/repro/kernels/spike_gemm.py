"""Sparsity-aware spike GEMM — the paper's PENC idea re-grained for TPU.

The FPGA design compresses the incoming binary spike train with a priority
encoder so that only firing neurons cost work.  A TPU cannot skip individual
bits — the MXU consumes 128x128 tiles and VMEM moves whole blocks — so the
skip granularity becomes a (block_m x block_k) tile of the spike matrix:

  1. per-tile occupancy flags are computed with a cheap jnp reduction
     (ops.py), the analogue of the ECU's compression pass;
  2. the flags ride in scalar-prefetch memory (SMEM) so the kernel knows,
     *before* the MXU touches a tile, whether it may skip the dot AND the
     VMEM->MXU traffic for that tile;
  3. ``pl.when`` guards the accumulate — an all-zero spike tile costs one
     SMEM read instead of a 128x128x128 MAC block.

With the layerwise firing ratios the paper reports (3-30% of neurons,
Fig. 1), most K-tiles of a deep layer are empty and the skip rate is large;
benchmarks/bench_kernels.py reports measured skip fractions on trained-model
traffic.  DESIGN.md §2 records this hardware adaptation; the backward-pass
kernels that reuse these flags live in spike_gemm_bwd.py (DESIGN.md §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spike_gemm_kernel(flags_ref, s_ref, w_ref, o_ref, acc_ref):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(flags_ref[i, k] != 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(s_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def spike_gemm_pallas(flags: jax.Array, spikes: jax.Array, weights: jax.Array,
                      *, block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """out[M,N] = spikes[M,K] @ weights[K,N], skipping empty spike tiles.

    ``flags``: (M//block_m, K//block_k) int32 occupancy (see ref.block_flags_ref).
    Shapes must be pre-padded to block multiples (ops.py wrapper pads).
    """
    M, K = spikes.shape
    K2, N = weights.shape
    assert K == K2 and M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    grid = (M // block_m, N // block_n, K // block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, flags: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k, flags: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, flags: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _spike_gemm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(flags, spikes, weights)
