"""Fused LIF membrane-update Pallas TPU kernel.

The accelerator's activation phase (leak multiply + synaptic add + bias +
threshold compare + reset) fused into one VMEM-resident elementwise pass —
one HBM round trip for the whole update instead of five.  Tiles are
(block_b, block_n) with block_n a multiple of 128 (VPU lane width) and
block_b a multiple of 8 (sublane), per the TPU tiling rules.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default standalone-LIF tile, shared by this module and the ops.py wrapper
#: (one constant, not two hardcodings).  ``block_b`` equals the training
#: GEMM tile's ``block_m`` (``snn.KERNEL_BLOCKS`` derives from it): both are
#: the f32 sublane minimum of 8.  ``block_n`` is deliberately 4x the GEMM's
#: 128-lane ``block_n``: a pure elementwise VPU pass has no MXU accumulator
#: tile to stay aligned with, so wider tiles amortise grid overhead.  The
#: *fused* GEMM+LIF kernel (spike_gemm_fused.py) instead inherits the GEMM's
#: 128-lane block_n because its epilogue operates on the accumulator tile.
LIF_BLOCKS = {"block_b": 8, "block_n": 512}


def _lif_kernel(u_ref, s_ref, c_ref, u_out_ref, s_out_ref, *,
                beta: float, threshold: float, reset_mechanism: str):
    dt = u_ref.dtype
    u_prev = u_ref[...]
    s_prev = s_ref[...]
    cur = c_ref[...]
    beta_ = jnp.asarray(beta, dt)
    thr = jnp.asarray(threshold, dt)
    if reset_mechanism == "subtract":
        u = beta_ * u_prev + cur - thr * s_prev
    else:
        u = beta_ * u_prev * (jnp.asarray(1.0, dt) - s_prev) + cur
    u_out_ref[...] = u
    s_out_ref[...] = (u > thr).astype(dt)


def lif_step_pallas(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array,
                    *, beta: float, threshold: float,
                    reset_mechanism: str = "subtract",
                    block_b: int = LIF_BLOCKS["block_b"],
                    block_n: int = LIF_BLOCKS["block_n"],
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(B, N) fused LIF update.  Inputs must be pre-padded to block multiples
    (the ops.py wrapper handles padding/unpadding)."""
    B, N = u_prev.shape
    assert B % block_b == 0 and N % block_n == 0, (B, N, block_b, block_n)
    grid = (B // block_b, N // block_n)
    spec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))
    kernel = functools.partial(_lif_kernel, beta=beta, threshold=threshold,
                               reset_mechanism=reset_mechanism)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((B, N), u_prev.dtype),
                   jax.ShapeDtypeStruct((B, N), u_prev.dtype)),
        interpret=interpret,
    )(u_prev, s_prev, current)
