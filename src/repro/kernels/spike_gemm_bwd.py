"""Block-skip Pallas backward kernels for the spike GEMM training path.

BPTT through ``out = S @ W`` needs two cotangent matmuls per layer per scan
step, and both inherit the forward's sparsity (DESIGN.md §12):

* ``dW = Sᵀ · g`` — the contraction runs over the batch/row axis of the
  *same* spike matrix the forward consumed.  A spike tile ``S[m, k]`` that
  the forward skipped is all-zero, so its transposed tile contributes
  exactly zero to the ``dW`` rows ``k``: the forward's ``block_flags``
  array, read transposed (reduction index first), gates the accumulate and
  neither pass recomputes the occupancy reduction.
* ``dS = g · Wᵀ`` — here the sparse operand is the *cotangent*: surrogate
  gradients vanish wherever ``|u - θ|`` is large, so late in training whole
  (m, n) tiles of ``g`` are exactly zero.  Occupancy of ``g`` must be
  computed with an any-nonzero reduction (``ref.block_flags_any_ref``) —
  the forward's sum>0 test is only exact for nonnegative spikes, and a
  float tile whose entries cancel must NOT be skipped.

Both kernels mirror ``spike_gemm.py``: reduction as the innermost grid
dimension, a VMEM f32 accumulator initialised at step 0 and flushed at the
last step, and ``pl.when`` guarding the dot on a scalar-prefetched flag so a
skipped tile costs one SMEM read instead of a MAC block.  Tiles are
transposed in-register (``.T`` on the VMEM block) rather than materialising
Sᵀ/Wᵀ in HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dw_kernel(flags_ref, s_ref, g_ref, dw_ref, acc_ref):
    ki, m = pl.program_id(0), pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # forward flags are (m, k)-indexed; the reduction index comes first here
    @pl.when(flags_ref[m, ki] != 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(s_ref[...].T, g_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(m == pl.num_programs(2) - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def spike_gemm_dw_pallas(flags: jax.Array, spikes: jax.Array, g: jax.Array,
                         *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, out_dtype=jnp.float32,
                         interpret: bool = False) -> jax.Array:
    """dW[K,N] = spikes[M,K]ᵀ @ g[M,N], skipping empty spike tiles.

    ``flags``: the FORWARD's (M//block_m, K//block_k) occupancy array —
    reused verbatim, indexed transposed.  Shapes must be pre-padded to block
    multiples (the ops.py wrapper pads).
    """
    M, K = spikes.shape
    M2, N = g.shape
    assert M == M2 and M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    grid = (K // block_k, N // block_n, M // block_m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda ki, j, m, flags: (m, ki)),
            pl.BlockSpec((block_m, block_n), lambda ki, j, m, flags: (m, j)),
        ],
        out_specs=pl.BlockSpec((block_k, block_n),
                               lambda ki, j, m, flags: (ki, j)),
        scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        _dw_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        interpret=interpret,
    )(flags, spikes, g)


def _ds_kernel(gflags_ref, g_ref, w_ref, ds_ref, acc_ref):
    i, n = pl.program_id(0), pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(gflags_ref[i, n] != 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(g_ref[...], w_ref[...].T,
                                preferred_element_type=jnp.float32)

    @pl.when(n == pl.num_programs(2) - 1)
    def _flush():
        ds_ref[...] = acc_ref[...].astype(ds_ref.dtype)


def spike_gemm_ds_pallas(gflags: jax.Array, g: jax.Array, weights: jax.Array,
                         *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, out_dtype=jnp.float32,
                         interpret: bool = False) -> jax.Array:
    """dS[M,K] = g[M,N] @ weights[K,N]ᵀ, skipping empty cotangent tiles.

    ``gflags``: (M//block_m, N//block_n) any-nonzero occupancy of ``g``
    (``ref.block_flags_any_ref``).  Shapes pre-padded to block multiples.
    """
    M, N = g.shape
    K, N2 = weights.shape
    assert N == N2 and M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    grid = (M // block_m, K // block_k, N // block_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, ki, n, gflags: (i, n)),
            pl.BlockSpec((block_k, block_n), lambda i, ki, n, gflags: (ki, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_k),
                               lambda i, ki, n, gflags: (i, ki)),
        scratch_shapes=[pltpu.VMEM((block_m, block_k), jnp.float32)],
    )
    return pl.pallas_call(
        _ds_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        interpret=interpret,
    )(gflags, g, weights)
