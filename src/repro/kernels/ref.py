"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_step_ref(u_prev: jax.Array, s_prev: jax.Array, current: jax.Array,
                 *, beta: float, threshold: float,
                 reset_mechanism: str = "subtract") -> tuple[jax.Array, jax.Array]:
    """Reference LIF membrane update (matches repro.core.lif.lif_step
    forward semantics, no surrogate gradient)."""
    dt = u_prev.dtype
    beta = jnp.asarray(beta, dt)
    threshold = jnp.asarray(threshold, dt)
    if reset_mechanism == "subtract":
        u = beta * u_prev + current - threshold * s_prev
    else:
        u = beta * u_prev * (1 - s_prev) + current
    s = (u > threshold).astype(dt)
    return u, s


def spike_gemm_ref(spikes: jax.Array, weights: jax.Array) -> jax.Array:
    """Dense reference for the spike-driven accumulation: out = S @ W.

    ``spikes``: (M, K) binary in {0,1} (any float dtype); ``weights``: (K, N).
    Accumulation in fp32 (the kernel uses preferred_element_type=f32).
    """
    return jnp.dot(spikes, weights, preferred_element_type=jnp.float32)


def spike_conv_ref(s_in: jax.Array, weights: jax.Array, *, stride: int = 1,
                   padding: str = "SAME") -> jax.Array:
    """Dense conv oracle: XLA's own NHWC x HWIO convolution — the exact
    operation the block-skip patch-tiled kernel must reproduce."""
    return jax.lax.conv_general_dilated(
        s_in, weights, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)


def penc_compact_ref(spikes: jax.Array, capacity: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the PENC compaction kernel: per row, ascending indices of
    set bits packed to the front, -1 padded, capped at ``capacity``."""
    B, N = spikes.shape
    s = spikes > 0
    pos = jnp.cumsum(s, axis=-1) - s.astype(jnp.int32)
    iota = jnp.arange(N, dtype=jnp.int32)[None, :]
    out = jnp.full((B, capacity), -1, jnp.int32)
    # scatter via comparison (small shapes; oracle clarity over speed)
    for k in range(capacity):
        hit = s & (pos == k)
        idx = jnp.where(hit.any(-1), (iota * hit).sum(-1), -1)
        out = out.at[:, k].set(idx)
    counts = s.sum(-1).astype(jnp.int32)
    return out, counts


def block_flags_ref(spikes: jax.Array, bm: int, bk: int) -> jax.Array:
    """Per (row-block, k-block) spike occupancy — the TPU-granular analogue
    of the paper's PENC compression (DESIGN.md §2).  The sum>0 test is exact
    only for nonnegative inputs (spikes are binary); for signed cotangents
    use ``block_flags_any_ref``."""
    M, K = spikes.shape
    assert M % bm == 0 and K % bk == 0
    blocks = spikes.reshape(M // bm, bm, K // bk, bk)
    return (blocks.sum(axis=(1, 3)) > 0).astype(jnp.int32)


def block_flags_any_ref(x: jax.Array, bm: int, bk: int) -> jax.Array:
    """Any-nonzero per-tile occupancy for SIGNED operands (the backward's
    surrogate-gradient cotangents): a float tile whose entries cancel to a
    zero sum still holds work and must not be skipped (DESIGN.md §12)."""
    M, K = x.shape
    assert M % bm == 0 and K % bk == 0
    blocks = (x != 0).reshape(M // bm, bm, K // bk, bk)
    return blocks.any(axis=(1, 3)).astype(jnp.int32)


def spike_gemm_bwd_ref(spikes: jax.Array, weights: jax.Array, g: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
    """Dense reference cotangents of ``out = S @ W``: the exact jnp
    ``dS = g·Wᵀ`` and ``dW = Sᵀ·g`` in fp32 (what the block-skip backward
    kernels must reproduce — a skipped tile contributes exactly zero)."""
    g32 = g.astype(jnp.float32)
    ds = jnp.dot(g32, weights.T, preferred_element_type=jnp.float32)
    dw = jnp.dot(spikes.T, g32, preferred_element_type=jnp.float32)
    return ds, dw


def spike_gemm_lif_ref(spikes: jax.Array, weights: jax.Array,
                       bias: jax.Array, u_prev: jax.Array, s_prev: jax.Array,
                       *, beta: float, threshold: float,
                       reset_mechanism: str = "subtract"
                       ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused GEMM+LIF scan step: the unfused composition
    ``lif_step_ref(u, s, S @ W + b)``."""
    cur = spike_gemm_ref(spikes, weights).astype(u_prev.dtype) + bias
    return lif_step_ref(u_prev, s_prev, cur, beta=beta, threshold=threshold,
                        reset_mechanism=reset_mechanism)
