"""PENC spike-address compaction as a Pallas TPU kernel.

The paper's Event Control Unit priority-encodes an n-bit spike train into a
shift register of spike ADDRESSES (one per cycle).  The TPU-idiomatic
equivalent extracts, per row, the indices of firing neurons packed to the
front of a fixed-capacity buffer — implemented as a *one-hot matmul*
compaction so the scatter runs on the MXU instead of serial address logic:

    pos[n]  = cumsum(spike)[n] - 1              (running address slot)
    sel     = onehot(pos) * spike               (N x K selection matrix)
    out[k]  = sum_n n * sel[n, k]               (a matmul)

Rows are tiled over VMEM; capacity K bounds per-tile traffic exactly like
the paper's 100-bit PENC chunk bounds FPGA routing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _penc_kernel(s_ref, idx_ref, cnt_ref, *, capacity: int):
    s = s_ref[...]                                   # (block_b, N) {0,1}
    n = s.shape[-1]
    pos = jnp.cumsum(s, axis=-1) - s                 # slot per spike
    slots = jnp.arange(capacity, dtype=s.dtype)
    # selection tensor (b, n, k): spike n writes slot k
    sel = (pos[..., None] == slots[None, None, :]) * s[..., None]
    iota = jnp.arange(n, dtype=jnp.float32)
    idx = jnp.einsum("n,bnk->bk", iota, sel.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    count = jnp.sum(s, axis=-1).astype(jnp.int32)    # (b,)
    valid = slots[None, :] < count[:, None].astype(s.dtype)
    idx_ref[...] = jnp.where(valid, idx, -1.0).astype(jnp.int32)
    cnt_ref[...] = count


def penc_compact_pallas(spikes: jax.Array, *, capacity: int,
                        block_b: int = 8,
                        interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """spikes: (B, N) in {0,1} -> (indices (B, capacity) int32 with -1 pad,
    counts (B,) int32).  Spikes beyond ``capacity`` per row are dropped
    (the ECU's chunk bound); B must be a multiple of block_b (ops pads)."""
    B, N = spikes.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    kernel = functools.partial(_penc_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, N), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((block_b, capacity), lambda i: (i, 0)),
                   pl.BlockSpec((block_b,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((B, capacity), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.int32)),
        interpret=interpret,
    )(spikes)
