"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships three layers: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrappers: padding, flags, permutation), ref.py
(pure-jnp oracles the tests sweep against).
"""
from repro.kernels.ops import (lif_step, spike_gemm, spike_gemm_profiled,
                               spike_gemm_train, spike_gemm_lif_step,
                               spike_gemm_bwd_dw, spike_gemm_bwd_ds,
                               penc_compact, skip_fraction,
                               firing_rate_permutation, apply_permutation)

__all__ = ["lif_step", "spike_gemm", "spike_gemm_profiled",
           "spike_gemm_train", "spike_gemm_lif_step", "spike_gemm_bwd_dw",
           "spike_gemm_bwd_ds", "penc_compact", "skip_fraction",
           "firing_rate_permutation", "apply_permutation"]
