"""Fused spike-GEMM + LIF scan-step Pallas kernel.

One training scan step per Dense layer is ``current = S @ W + b`` followed
by the LIF membrane update — two kernels with the (B, N) current, membrane
and spike tensors round-tripping through HBM between them.  The hardware
analogue (PULSE, arXiv:2402.06210) is a single sparsity-aware unit that
folds the neuron update into the accumulate datapath; this kernel does the
same on the MXU: the block-skip accumulate of ``spike_gemm.py`` runs
unchanged, and the *epilogue* of the K-reduction (the grid step that would
merely flush the accumulator) instead applies bias add, leak, threshold
compare and reset while the accumulator tile is still VMEM-resident
(DESIGN.md §12).

The epilogue evaluates the exact expression ``repro.core.lif.lif_step``
evaluates, in the same operation order, so the fused forward is bit-identical
to the unfused spike_gemm + LIF composition — the property that keeps DSE
cells backend-invariant across all three matmul backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(flags_ref, s_ref, w_ref, b_ref, u_ref, sp_ref,
                  u_out_ref, s_out_ref, acc_ref, *,
                  beta: float, threshold: float, reset_mechanism: str):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(flags_ref[i, k] != 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(s_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        dt = u_ref.dtype
        cur = acc_ref[...].astype(dt) + b_ref[...]
        u_prev = u_ref[...]
        s_prev = sp_ref[...]
        beta_ = jnp.asarray(beta, dt)
        thr = jnp.asarray(threshold, dt)
        if reset_mechanism == "subtract":
            u = beta_ * u_prev + cur - thr * s_prev
        else:
            u = beta_ * u_prev * (jnp.asarray(1.0, dt) - s_prev) + cur
        u_out_ref[...] = u
        s_out_ref[...] = (u > thr).astype(dt)


def spike_gemm_lif_pallas(flags: jax.Array, spikes: jax.Array,
                          weights: jax.Array, bias: jax.Array,
                          u_prev: jax.Array, s_prev: jax.Array, *,
                          beta: float, threshold: float,
                          reset_mechanism: str = "subtract",
                          block_m: int = 8, block_n: int = 128,
                          block_k: int = 128,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """(u, s) = LIF(u_prev, s_prev, spikes @ weights + bias) in one pass.

    ``flags``: (M//block_m, K//block_k) occupancy of ``spikes``; ``bias`` is
    (1, N).  Shapes must be pre-padded to block multiples (ops.py wrapper
    pads) — padded neurons see zero current/state and, for any positive
    threshold, stay silent until sliced away.
    """
    M, K = spikes.shape
    K2, N = weights.shape
    assert K == K2 and u_prev.shape == (M, N) and s_prev.shape == (M, N)
    assert bias.shape == (1, N)
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    grid = (M // block_m, N // block_n, K // block_k)
    state_spec = pl.BlockSpec((block_m, block_n),
                              lambda i, j, k, flags: (i, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, flags: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k, flags: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k, flags: (0, j)),
            state_spec,
            state_spec,
        ],
        out_specs=(state_spec, state_spec),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    kernel = functools.partial(_fused_kernel, beta=beta, threshold=threshold,
                               reset_mechanism=reset_mechanism)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((M, N), u_prev.dtype),
                   jax.ShapeDtypeStruct((M, N), u_prev.dtype)),
        interpret=interpret,
    )(flags, spikes, weights, bias, u_prev, s_prev)
