"""Loop-aware HLO analyzer.

``compiled.cost_analysis()`` counts every while body ONCE — a scanned
80-layer model reports ~1 layer of FLOPs.  This module parses the optimized
HLO text, recovers each loop's trip count from its condition computation
(jax scans lower to ``while`` whose cond compares the induction variable
against a literal ``s32[] constant(N)``), propagates multipliers through the
call graph (while bodies multiply, fusions/reducers don't), and produces
loop-corrected totals:

  * FLOPs    — 2 * out_elems * contraction for every ``dot``; convolutions
               approximated via kernel size.
  * Bytes    — operand + output bytes of every top-level op (fusions at
               their boundary), the HloCostAnalysis bytes-accessed
               approximation.
  * Collective wire bytes — per kind, with ring multipliers.

Validated against analytic FLOP counts in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+"
                    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_EDGE = re.compile(r"(body|condition|calls|to_apply)=\{?%?([\w\.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "call", "conditional", "iota"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    edges: list         # (kind, callee)
    shape: dict         # instr name -> type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = Computation(hdr.group(1), [], [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        inst = Instr(name, type_str, opcode, rest)
        cur.instrs.append(inst)
        cur.shape[name] = type_str
        found = dict()
        for kind, callee in _EDGE.findall(line):
            found.setdefault(kind, callee)
        if "body" in found:            # a while op: body + condition paired
            cur.edges.append(("while", (found["body"],
                                        found.get("condition"))))
        for kind in ("calls", "to_apply"):
            if kind in found:
                cur.edges.append((kind, found[kind]))
    return comps


def _trip_count(comps: dict, cond_name: str) -> Optional[int]:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = []
    for inst in cond.instrs:
        consts += [int(v) for v in _CONST_S32.findall(
            f"{inst.type_str} {inst.opcode}({inst.rest}")]
    return max(consts) if consts else None


def multipliers(comps: dict) -> tuple[dict, int]:
    """Execution-count multiplier per computation; while bodies multiply by
    their trip count.  Returns (multipliers, num_unknown_trip_loops)."""
    mult = {name: 0.0 for name in comps}
    callees = set()
    for c in comps.values():
        for kind, callee in c.edges:
            if kind == "while":
                callees.update(x for x in callee if x)
            else:
                callees.add(callee)
    roots = [n for n in comps if n not in callees]
    unknown = 0

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        c = comps[name]
        for kind, callee in c.edges:
            if kind == "while":
                body, cond = callee
                trip = _trip_count(comps, cond) if cond else None
                if trip is None:
                    trip = 1
                    nonlocal unknown
                    unknown += 1
                visit(body, m * trip, depth + 1)
                if cond:
                    visit(cond, m * trip, depth + 1)
            else:  # calls / to_apply (fusions, reducers, plain calls)
                visit(callee, m, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult, unknown


# ---------------------------------------------------------------------------
# Totals
# ---------------------------------------------------------------------------

def _dot_flops(comp: Computation, inst: Instr) -> float:
    out_elems = _elems(inst.type_str)
    ops = _OPERAND.findall(inst.rest)
    contract = _CONTRACT.search(inst.rest)
    k = 1
    if ops and contract:
        lhs_shape = _dims(comp.shape.get(ops[0], ""))
        for d in contract.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                k *= lhs_shape[int(d)]
    return 2.0 * out_elems * k


def _conv_flops(comp: Computation, inst: Instr) -> float:
    out_elems = _elems(inst.type_str)
    ops = _OPERAND.findall(inst.rest)
    if len(ops) >= 2:
        rhs = _dims(comp.shape.get(ops[1], ""))
        if rhs:
            per_out = 1
            for d in rhs[:-1]:
                per_out *= d
            return 2.0 * out_elems * per_out
    return 2.0 * out_elems


@dataclasses.dataclass
class ModuleStats:
    flops: float
    bytes_accessed: float
    collective_bytes_by_kind: dict
    collective_wire_bytes: float
    unknown_trip_loops: int
    dots: int


def analyze(text: str) -> ModuleStats:
    comps = parse_module(text)
    mult, unknown = multipliers(comps)
    # computations reached via fusion/reduce edges: bytes counted at the
    # CALLER boundary, not inside
    fusion_called = {callee for c in comps.values()
                     for kind, callee in c.edges if kind in ("calls", "to_apply")}
    flops = 0.0
    bytes_acc = 0.0
    coll: dict[str, float] = {}
    wire = 0.0
    dots = 0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        count_bytes = name not in fusion_called
        for inst in comp.instrs:
            if inst.opcode == "dot":
                flops += m * _dot_flops(comp, inst)
                dots += 1
            elif inst.opcode == "convolution":
                flops += m * _conv_flops(comp, inst)
            base = inst.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not inst.opcode.endswith("-done"):
                b = _bytes(inst.type_str)
                coll[base] = coll.get(base, 0.0) + m * b
                wire += m * b * _WIRE_MULT[base]
            if count_bytes and inst.opcode not in _NO_TRAFFIC:
                b = _bytes(inst.type_str)
                for op_name in _OPERAND.findall(inst.rest):
                    if op_name in comp.shape:
                        b += _bytes(comp.shape[op_name])
                bytes_acc += m * b
    return ModuleStats(flops=flops, bytes_accessed=bytes_acc,
                       collective_bytes_by_kind=coll,
                       collective_wire_bytes=wire,
                       unknown_trip_loops=unknown, dots=dots)
