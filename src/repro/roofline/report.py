"""Roofline report builder: reads the dry-run JSON artifacts and emits the
EXPERIMENTS.md §Dry-run and §Roofline tables.

    PYTHONPATH=src python -m repro.roofline.report --dir artifacts/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPES
from repro.models import registry
from repro.roofline import analysis

ADVICE = {
    "compute": ("compute-bound: raise MFU via larger per-chip tiles "
                "(less model-parallel splitting) or reduce remat recompute"),
    "memory": ("HBM-bound: fuse/eliminate activation round-trips, widen "
               "arithmetic intensity (bigger microbatches, bf16 workspace)"),
    "collective": ("collective-bound: reshard to cut all-gathers "
                   "(FSDP prefetch overlap, expert-parallel all-to-all "
                   "scheduling, 1D-ring friendly layouts)"),
}


def load_records(d: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_rows(recs: list[dict], mesh: str = "single") -> list[dict]:
    rows = []
    for rec in recs:
        if rec.get("mesh") != mesh:
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        if rec.get("status") == "skipped":
            rows.append({"arch": arch, "shape": shape_name,
                         "skip": rec.get("reason", "skipped")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": arch, "shape": shape_name,
                         "skip": f"FAILED: {rec.get('error')}"})
            continue
        cfg = registry.load_arch(arch)
        shape = SHAPES[shape_name]
        mf = analysis.model_flops(cfg, shape)
        rl = analysis.roofline_from_record(rec, mf)
        bound_s = max(rl.compute_s, rl.memory_s, rl.collective_s)
        rows.append({
            "arch": arch, "shape": shape_name,
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "bottleneck": rl.bottleneck,
            "model_flops": mf, "hlo_flops": rl.hlo_flops,
            "useful_ratio": rl.useful_ratio,
            # fraction of the bound the useful math occupies: how close the
            # *useful* work is to the roofline of the dominant resource
            "roofline_fraction": (mf / rec["devices"] / analysis.PEAK_FLOPS)
            / bound_s if bound_s else 0.0,
            "mem_gb": rec.get("memory", {}).get("total_bytes_per_device",
                                                0) / 1e9,
            "devices": rec["devices"],
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | bound | "
           "MODEL/HLO flops | roofline frac | mem/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} | {r['mem_gb']:.1f} GB |")
    return "\n".join(lines)


def write_marker(md_path: str, marker: str, content: str):
    """Replace '<!-- MARKER -->' (and any previously-inserted table after
    it, up to the next blank-line+non-table text) with the marker + table."""
    with open(md_path) as f:
        text = f.read()
    tag = f"<!-- {marker} -->"
    if tag not in text:
        raise SystemExit(f"marker {tag} not found in {md_path}")
    head, rest = text.split(tag, 1)
    # drop an existing table directly following the marker
    lines = rest.splitlines()
    i = 0
    while i < len(lines) and (not lines[i].strip() or
                              lines[i].lstrip().startswith("|")):
        i += 1
    rest = "\n".join(lines[i:])
    with open(md_path, "w") as f:
        f.write(head + tag + "\n\n" + content + "\n\n" + rest)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--write-into", default="")
    ap.add_argument("--marker", default="BASELINE_TABLE")
    args = ap.parse_args()
    recs = load_records(args.dir)
    rows = roofline_rows(recs, args.mesh)
    table = markdown_table(rows)
    if args.write_into:
        write_marker(args.write_into, args.marker, table)
        print(f"wrote {len(rows)} rows into {args.write_into}")
        return
    print(table)
    print()
    for r in rows:
        if "skip" not in r:
            print(f"{r['arch']} x {r['shape']}: {ADVICE[r['bottleneck']]}")


if __name__ == "__main__":
    main()
