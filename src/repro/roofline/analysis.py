"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS        (197 TF/s bf16, v5e)
    memory     = HLO_bytes_per_device / HBM_BW            (819 GB/s)
    collective = collective_wire_bytes_per_device / LINK_BW (~50 GB/s/link ICI)

``cost_analysis`` provides FLOPs/bytes of the per-device partitioned module.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO,
summing shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, each scaled by its wire multiplier
(all-reduce counts twice: reduce-scatter + all-gather phases of a ring), and
multiplied by the known trip count of any enclosing while loop (scan over
layers / microbatches).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


class CellSkipped(Exception):
    """Raised for (arch x shape) cells excluded by design (DESIGN.md §4)."""


# ---------------------------------------------------------------------------
# Compiled-artifact summaries
# ---------------------------------------------------------------------------

def memory_summary(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    except Exception as e:                                   # noqa: BLE001
        out["error"] = str(e)
    return out


def cost_summary(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for key in ("flops", "bytes accessed", "transcendentals",
                    "utilization operand 0 {}"):
            if key in ca:
                out[key.replace(" ", "_")] = float(ca[key])
    except Exception as e:                                   # noqa: BLE001
        out["error"] = str(e)
    return out


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

def collective_summary(compiled, lowered=None) -> dict:
    """Loop-corrected collective + flop/byte totals from the optimized HLO
    (roofline.hlo_parse; cost_analysis counts loop bodies once)."""
    from repro.roofline import hlo_parse
    try:
        text = compiled.as_text()
    except Exception:                                        # noqa: BLE001
        text = lowered.as_text() if lowered is not None else ""
    st = hlo_parse.analyze(text)
    return {
        "bytes_by_kind": {k: int(v) for k, v in
                          st.collective_bytes_by_kind.items()},
        "total_wire_bytes": int(st.collective_wire_bytes),
        "unknown_trip_loops": st.unknown_trip_loops,
        "parsed_flops": float(st.flops),
        "parsed_bytes_accessed": float(st.bytes_accessed),
        "dots": st.dots,
    }


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float      # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str

    def table_row(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_record(record: dict, model_flops: float) -> Roofline:
    """Build the three terms from one dry-run JSON record.

    cost_analysis FLOPs/bytes describe the per-device partitioned module;
    collective bytes likewise (per-device program).
    """
    coll_rec = record.get("collectives", {})
    # prefer loop-corrected parsed totals; raw cost_analysis kept for
    # reference (it counts while bodies once)
    flops = coll_rec.get("parsed_flops") or record.get("cost", {}).get(
        "flops", 0.0)
    bytes_acc = coll_rec.get("parsed_bytes_accessed") or record.get(
        "cost", {}).get("bytes_accessed", 0.0)
    coll = coll_rec.get("total_wire_bytes", 0.0)
    chips = record.get("devices", 1)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / (flops * chips) if flops else 0.0
    return Roofline(compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, model_flops=model_flops,
                    hlo_flops=flops, useful_ratio=useful,
                    bottleneck=bottleneck)


def model_flops(cfg, shape, active_params: Optional[float] = None) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference forward);
    MoE uses N_active (top-k of the expert params)."""
    import jax
    from repro.models import registry
    n_total = active_params
    if n_total is None:
        shapes = jax.eval_shape(
            lambda: registry.init_params(jax.random.key(0), cfg))
        n_total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
        if cfg.moe is not None:
            # count expert tensors once, scale to top-k/E activation
            e, k = cfg.moe.num_experts, cfg.moe.top_k
            expert = 3 * cfg.d_model * cfg.d_ff * e * cfg.num_layers
            n_total = n_total - expert + expert * k / e
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_total * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_total * tokens
    return 2.0 * n_total * shape.global_batch      # decode: 1 token/seq
