"""Mixture-of-Experts FFN (GShard-style top-k token-choice with capacity).

Used by mixtral-8x7b (8e top-2) and arctic-480b (128e top-2 + dense
residual).  The dispatch/combine tensors are built per *group* (the token
axis is processed in groups of ``group_size``) so the (S, E, C) one-hots stay
VMEM-friendly; groups are scanned to bound live memory.

Sharding: the expert axis E shards over "model" when E % mesh_model == 0
(arctic); otherwise the expert-internal d_ff dimension shards (mixtral,
8 experts on a 16-way axis) — see distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers

PyTree = Any


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig,
             dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 5)
    E = cfg.num_experts
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(d_ff)
    p = {
        "router": layers.linear_init(ks[0], d_model, E, dtype),
        "w_gate": jax.random.normal(ks[1], (E, d_model, d_ff), dtype) * scale_in,
        "w_up": jax.random.normal(ks[2], (E, d_model, d_ff), dtype) * scale_in,
        "w_down": jax.random.normal(ks[3], (E, d_ff, d_model), dtype) * scale_out,
    }
    if cfg.dense_residual:
        p["dense"] = layers.mlp_init(ks[4], d_model,
                                     cfg.dense_d_ff or d_ff, "swiglu", dtype)
    return p


def _topk_dispatch(router_probs: jax.Array, top_k: int, capacity: int):
    """Token-choice top-k with per-expert capacity.

    router_probs: (S, E).  Returns dispatch (S, E, C) in {0,1} as dtype,
    combine (S, E, C) weights, and the load-balancing aux loss.
    """
    S, E = router_probs.shape
    probs = router_probs
    dispatch_parts, combine_parts = [], []
    # running per-expert fill for capacity bookkeeping across the k passes
    fill_base = jnp.zeros((E,), jnp.int32)
    masked = probs
    for _ in range(top_k):
        idx = jnp.argmax(masked, axis=-1)                     # (S,)
        onehot = jax.nn.one_hot(idx, E, dtype=probs.dtype)    # (S, E)
        gate = jnp.sum(probs * onehot, axis=-1)               # (S,)
        # position of each token within its chosen expert's queue
        pos = jnp.cumsum(onehot, axis=0) - onehot + fill_base[None, :]
        pos_tok = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (S,)
        keep = pos_tok < capacity
        slot = jax.nn.one_hot(pos_tok, capacity, dtype=probs.dtype)  # (S, C)
        disp = onehot[:, :, None] * slot[:, None, :] * keep[:, None, None]
        dispatch_parts.append(disp)
        combine_parts.append(disp * gate[:, None, None])
        fill_base = fill_base + jnp.sum(onehot, axis=0).astype(jnp.int32)
        masked = jnp.where(onehot > 0, -jnp.inf, masked)
    dispatch = sum(dispatch_parts)
    combine = sum(combine_parts)
    # Switch-style load-balance loss over the top-1 assignment
    density = jnp.mean(dispatch_parts[0].sum(-1), axis=0)     # (E,)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * (E ** 2) / max(S, 1)
    return dispatch, combine, aux


def _topk_routing(probs: jax.Array, top_k: int, capacity: int):
    """Shared routing bookkeeping: expert choice, gate, slot position per
    (token, k) assignment.  All O(S*E) — no (S,E,C) tensor.

    Returns expert_idx (S,k), gates (S,k), pos_in_expert (S,k), keep (S,k),
    aux loss.
    """
    S, E = probs.shape
    masked = probs
    experts, gates, positions = [], [], []
    fill = jnp.zeros((E,), jnp.int32)
    top1_onehot = None
    for _ in range(top_k):
        idx = jnp.argmax(masked, axis=-1)                     # (S,)
        onehot = jax.nn.one_hot(idx, E, dtype=probs.dtype)
        if top1_onehot is None:
            top1_onehot = onehot
        gate = jnp.sum(probs * onehot, axis=-1)
        pos = jnp.cumsum(onehot, axis=0) - onehot + fill[None, :]
        pos_tok = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)
        experts.append(idx)
        gates.append(gate)
        positions.append(pos_tok)
        fill = fill + jnp.sum(onehot, axis=0).astype(jnp.int32)
        masked = jnp.where(onehot > 0, -jnp.inf, masked)
    expert_idx = jnp.stack(experts, 1)
    gates_k = jnp.stack(gates, 1)
    pos_k = jnp.stack(positions, 1)
    keep = pos_k < capacity
    density = jnp.mean(top1_onehot, axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E
    return expert_idx, gates_k, pos_k, keep, aux


def _expert_ffn(p: PyTree, xin: jax.Array) -> jax.Array:
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", xin, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E, C, d)


def _group_einsum(p: PyTree, cfg: MoEConfig, xg: jax.Array, capacity: int):
    """GShard-faithful one-hot dispatch (baseline; see MoEConfig.dispatch)."""
    logits = layers.linear(p["router"], xg).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(xg.dtype)
    dispatch, combine, aux = _topk_dispatch(probs, cfg.top_k, capacity)
    xin = jnp.einsum("sd,sec->ecd", xg, dispatch)              # (E, C, d)
    y = _expert_ffn(p, xin)
    out = jnp.einsum("ecd,sec->sd", y, combine)
    return out, aux


def _group_gather(p: PyTree, cfg: MoEConfig, xg: jax.Array, capacity: int):
    """Gather-based dispatch (optimized): tokens land in expert slots via a
    scatter of row indices + one gather; combine is a per-assignment gather
    + weighted sum.  Removes the 2*S*E*C*d dispatch/combine matmul FLOPs
    and the (S,E,C) one-hot bytes of the einsum path."""
    S, d = xg.shape
    E, C = cfg.num_experts, capacity
    logits = layers.linear(p["router"], xg).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(xg.dtype)
    expert_idx, gates, pos, keep, aux = _topk_routing(probs, cfg.top_k, C)
    # slot id per assignment; dropped tokens land in a trash slot E*C
    slot = jnp.where(keep, expert_idx * C + pos, E * C)        # (S, k)
    # token row feeding each slot (slots are filled by <=1 token)
    token_for_slot = jnp.full((E * C + 1,), S, jnp.int32)
    token_for_slot = token_for_slot.at[slot.reshape(-1)].set(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), cfg.top_k), mode="drop")
    xg_pad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], 0)
    xin = xg_pad[token_for_slot[:-1]].reshape(E, C, d)         # gather
    y = _expert_ffn(p, xin)                                    # (E, C, d)
    y_flat = jnp.concatenate([y.reshape(E * C, d),
                              jnp.zeros((1, d), y.dtype)], 0)
    picked = y_flat[slot]                                      # (S, k, d)
    out = jnp.sum(picked * gates[..., None].astype(y.dtype), axis=1)
    return out, aux


def moe_apply(p: PyTree, cfg: MoEConfig, x: jax.Array,
              group_size: int = 4096,
              group_mode: str = "scan") -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are flattened and processed in groups (scan) so per-group routing
    state stays small; the dispatch flavour is cfg.dispatch.
    """
    B, S, d = x.shape
    tokens = x.reshape(B * S, d)
    T = tokens.shape[0]
    g = min(group_size, T)
    n_groups = -(-T // g)
    pad = n_groups * g - T
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, d), tokens.dtype)], axis=0)
    groups = tokens.reshape(n_groups, g, d)
    capacity = max(int(cfg.top_k * g / cfg.num_experts * cfg.capacity_factor),
                   1)
    group_fn = _group_gather if cfg.dispatch == "gather" else _group_einsum

    if group_mode == "vmap":
        # vmap over groups: the group dim is batch-aligned, so it stays
        # sharded over (pod, data) and every group's routing is shard-LOCAL
        # (a scan dynamic-slices the sharded token axis and pays
        # cross-shard gathers per iteration).  Used in TRAINING, where the
        # per-layer remat bounds the live group buffers; serving keeps the
        # scan (all groups at once costs ~32 GB at prefill_32k) —
        # EXPERIMENTS.md §Perf, mixtral group-mode iteration.
        outs, auxs = jax.vmap(lambda xg: group_fn(p, cfg, xg, capacity))(groups)
        aux_total = jnp.sum(auxs)
    else:
        def one_group(carry, xg):
            out, aux = group_fn(p, cfg, xg, capacity)
            return carry + aux, out

        aux_total, outs = jax.lax.scan(one_group, jnp.zeros((), jnp.float32),
                                       groups)
    out = outs.reshape(n_groups * g, d)[:T].reshape(B, S, d)
    if cfg.dense_residual:
        out = out + layers.mlp(p["dense"], x, "swiglu")
    return out, aux_total / n_groups


def expert_activation_stats(p: PyTree, cfg: MoEConfig,
                            x: jax.Array) -> jax.Array:
    """Per-expert activation frequency — the MoE analogue of the paper's
    Fig.-1 layerwise firing analysis (DESIGN.md §4)."""
    logits = layers.linear(p["router"], x.reshape(-1, x.shape[-1]))
    top1 = jnp.argmax(logits, axis=-1)
    return jnp.bincount(top1, length=cfg.num_experts) / top1.shape[0]
