"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention block
(one parameter set) applied every ``shared_attn_every`` blocks
(arXiv:2411.15242).

Structured as ``num_groups = L / every`` groups, each group = ``every``
stacked Mamba2 blocks + one application of the shared attention block.  The
attention parameters are shared across applications but each application
keeps its own KV cache (its inputs differ).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, ssm, transformer

PyTree = Any


def _groups(cfg: ArchConfig) -> tuple[int, int]:
    every = cfg.shared_attn_every
    assert every and cfg.num_layers % every == 0, (cfg.num_layers, every)
    return cfg.num_layers // every, every


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ng, every = _groups(cfg)
    k_embed, k_m, k_a, k_mlp, k_head = jax.random.split(key, 5)
    mkeys = jax.random.split(k_m, cfg.num_layers).reshape(ng, every)
    mamba = jax.vmap(jax.vmap(
        lambda k: ssm.init_block(k, cfg, dtype)))(mkeys)  # (ng, every, ...)
    shared = {
        "attn_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": layers.attn_init(k_a, transformer.attn_config(cfg), dtype),
        "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": layers.mlp_init(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                               dtype),
    }
    return {
        "embed": layers.embed_init(k_embed, cfg.vocab_padded, cfg.d_model,
                                   dtype),
        "mamba": mamba,
        "shared": shared,
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": layers.linear_init(k_head, cfg.d_model, cfg.vocab_padded,
                                      dtype),
    }


def _shared_attn(params: PyTree, cfg: ArchConfig, x: jax.Array,
                 positions: jax.Array, **kv_kw) -> jax.Array:
    sp = params["shared"]
    acfg = transformer.attn_config(cfg)
    h = layers.norm_apply(cfg.norm, sp["attn_norm"], x)
    x = x + layers.attention(sp["attn"], acfg, h, positions, **kv_kw)
    h = layers.norm_apply(cfg.norm, sp["mlp_norm"], x)
    return x + layers.mlp(sp["mlp"], h, cfg.mlp_kind)


def forward(params: PyTree, cfg: ArchConfig, batch: dict,
            remat: bool = False):
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)
    B, S = batch["tokens"].shape
    positions = transformer.make_positions(cfg, B, S)

    def group_body(x, gp):
        def mamba_body(x, lp):
            return ssm.block_forward(lp, cfg, x), None

        x, _ = jax.lax.scan(mamba_body, x, gp)
        x = _shared_attn(params, cfg, x, positions)
        return x, jnp.zeros((), jnp.float32)

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(group_body, x, params["mamba"])
    x = layers.rmsnorm(params["final_norm"], x)
    return layers.linear(params["lm_head"], x), jnp.sum(aux)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> PyTree:
    ng, every = _groups(cfg)
    d = ssm.dims(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    C = transformer.cache_capacity(cfg, max_len)
    hd = cfg.resolved_head_dim
    return {
        "h": jnp.zeros((ng, every, batch_size, d["n_heads"], d["N"], d["P"]),
                       jnp.float32),
        "conv": jnp.zeros((ng, every, batch_size, d["W"] - 1, d["conv_ch"]),
                          dtype),
        "k": jnp.zeros((ng, batch_size, C, cfg.n_kv, hd), dtype),
        "v": jnp.zeros((ng, batch_size, C, cfg.n_kv, hd), dtype),
        "slot_pos": jnp.full((batch_size, C), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill(params: PyTree, cfg: ArchConfig, batch: dict, max_len: int):
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)
    B, S = batch["tokens"].shape
    positions = transformer.make_positions(cfg, B, S)
    abs_pos = positions if positions.ndim == 2 else positions[0]
    acfg = transformer.attn_config(cfg)
    C = transformer.cache_capacity(cfg, max_len)
    keep = min(C, S)
    pad_path = C >= S            # no wrap: cache layout is a plain pad
    slots = abs_pos[:, S - keep:] % C
    bidx = jnp.arange(B)[:, None]
    sp = params["shared"]

    def _to_cache(t):
        if pad_path:
            return jnp.pad(t[:, S - keep:],
                           ((0, 0), (0, C - keep), (0, 0), (0, 0)))
        hd = cfg.resolved_head_dim
        return jnp.zeros((B, C, cfg.n_kv, hd), t.dtype
                         ).at[bidx, slots].set(t[:, S - keep:])

    def group_body(x, gp):
        def mamba_body(x, lp):
            out, (h, conv) = ssm.block_forward(lp, cfg, x, return_state=True)
            return out, (h, conv)

        x, (hs, convs) = jax.lax.scan(mamba_body, x, gp)
        h = layers.norm_apply(cfg.norm, sp["attn_norm"], x)
        k, v = layers.project_kv(sp["attn"], acfg, h, positions)
        x = x + layers.attention(sp["attn"], acfg, h, positions,
                                 kv_override=(k, v), kv_positions=abs_pos)
        h2 = layers.norm_apply(cfg.norm, sp["mlp_norm"], x)
        x = x + layers.mlp(sp["mlp"], h2, cfg.mlp_kind)
        return x, (hs, convs, _to_cache(k), _to_cache(v))

    x, (hs, convs, cks, cvs) = jax.lax.scan(group_body, x, params["mamba"])
    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x[:, -1:, :])
    if pad_path:
        slot_pos = jnp.pad(abs_pos[:, S - keep:], ((0, 0), (0, C - keep)),
                           constant_values=-1)
    else:
        slot_pos = jnp.full((B, C), -1, jnp.int32
                            ).at[bidx, slots].set(abs_pos[:, S - keep:])
    cache = {"h": hs, "conv": convs, "k": cks, "v": cvs,
             "slot_pos": slot_pos, "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params: PyTree, cfg: ArchConfig, token: jax.Array,
                cache: PyTree):
    B = token.shape[0]
    pos_scalar = cache["length"]
    positions = transformer.make_positions(cfg, B, 1, offset=pos_scalar)
    abs_pos = positions if positions.ndim == 2 else positions[0]
    acfg = transformer.attn_config(cfg)
    x = layers.maybe_shard(layers.embed(params["embed"], token),
                           "batch", None, None)
    C = cache["k"].shape[2]
    slot = pos_scalar % C
    slot_pos = cache["slot_pos"].at[:, slot].set(abs_pos[:, 0])
    kv_valid = slot_pos >= 0
    kv_positions = jnp.maximum(slot_pos, 0)
    sp = params["shared"]

    def group_body(x, scanned):
        gp, h_g, conv_g, ck, cv = scanned

        def mamba_body(x, inner):
            lp, h, conv = inner
            out, (h, conv) = ssm.block_decode(lp, cfg, x, h, conv)
            return out, (h, conv)

        x, (hs, convs) = jax.lax.scan(mamba_body, x, (gp, h_g, conv_g))
        h = layers.norm_apply(cfg.norm, sp["attn_norm"], x)
        k, v = layers.project_kv(sp["attn"], acfg, h, positions)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        x = x + layers.attention(sp["attn"], acfg, h, positions,
                                 kv_override=(ck, cv),
                                 kv_positions=kv_positions,
                                 kv_valid=kv_valid)
        h2 = layers.norm_apply(cfg.norm, sp["mlp_norm"], x)
        x = x + layers.mlp(sp["mlp"], h2, cfg.mlp_kind)
        return x, (hs, convs, ck, cv)

    x, (hs, convs, cks, cvs) = jax.lax.scan(
        group_body, x,
        (params["mamba"], cache["h"], cache["conv"], cache["k"], cache["v"]))
    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x)
    return logits, {"h": hs, "conv": convs, "k": cks, "v": cvs,
                    "slot_pos": slot_pos, "length": pos_scalar + 1}
