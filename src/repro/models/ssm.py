"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

The training/prefill path uses the chunked matmul form of SSD (quadratic
inside a chunk, linear across chunks) — MXU-friendly: the inner products
``C B^T`` and the decay-masked chunk matmul map onto 128x128 dots.  The
decode path is the O(1)-per-token recurrence on the (H, N, P) state.

Block layout follows the reference implementation: one fused in_proj to
(z, x, B, C, dt), a width-4 causal conv over the (x, B, C) channels, scalar
per-head A, gated RMSNorm, out_proj.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import layers

PyTree = Any


def dims(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim          # x + B + C channels (G=1)
    return dict(d_inner=d_inner, n_heads=n_heads, conv_ch=conv_ch,
                N=s.state_dim, P=s.head_dim, W=s.conv_width, Q=s.chunk)


def init_block(key, cfg: ArchConfig, dtype) -> PyTree:
    d = dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * d["d_inner"] + 2 * d["N"] + d["n_heads"]
    return {
        "norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "in_proj": layers.linear_init(k1, cfg.d_model, in_dim, dtype),
        "conv_w": jax.random.normal(k2, (d["W"], d["conv_ch"]), dtype) * 0.2,
        "conv_b": jnp.zeros((d["conv_ch"],), dtype),
        "A_log": jnp.zeros((d["n_heads"],), jnp.float32),
        "dt_bias": jnp.full((d["n_heads"],), -2.0, jnp.float32),
        "D": jnp.ones((d["n_heads"],), jnp.float32),
        "gate_norm": layers.rmsnorm_init(d["d_inner"], dtype),
        "out_proj": layers.linear_init(k3, d["d_inner"], cfg.d_model, dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    d = dims(cfg)
    di, N, H = d["d_inner"], d["N"], d["n_heads"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Width-W causal depthwise conv over (B, S, C) channels."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: jax.Array | None = None):
    """Chunked SSD scan.

    x:  (B, S, H, P)   per-head inputs
    dt: (B, S, H)      softplus'd step sizes
    A:  (H,)           negative decay rates (a = exp(A*dt))
    Bm: (B, S, N)      input projections (shared across heads, G=1)
    Cm: (B, S, N)      output projections
    Returns y (B, S, H, P) and final state (B, H, N, P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # dt=0 padding is exact: decay exp(A*0)=1 keeps the state, the
        # update term is dt-scaled so it vanishes
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    # log-decay within chunk: la[..., i] = sum_{j<=i} A*dt_j   (B,nc,Q,H)
    la = jnp.cumsum(A[None, None, None, :] * dtc, axis=2)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]        # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    # the (B,nc,Q,Q,H) intermediates dominate SSD memory — keep them sharded
    # over heads on the model axis (48/80 heads are 16-divisible)
    decay = layers.maybe_shard(decay, "batch", None, None, None, "model")

    # intra-chunk (quadratic, matmul-rich): scores = (C_i . B_j)
    g = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                   preferred_element_type=jnp.float32)       # (B,nc,Q,Q)
    m = g[..., None] * decay * dtc[:, :, None, :, :]         # (B,nc,Q,Q,H)
    m = layers.maybe_shard(m, "batch", None, None, None, "model")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m.astype(x.dtype), xc)

    # chunk summaries: S_c = sum_j exp(la_Q - la_j) dt_j B_j x_j  (B,nc,H,N,P)
    tail = jnp.exp(la[:, :, -1:, :] - la) * dtc              # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", tail.astype(x.dtype), Bc, xc)
    chunk_decay = jnp.exp(la[:, :, -1, :])                   # (B,nc,H)

    # inter-chunk recurrence over nc chunks
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def scan_fn(h, inp):
        s_c, dec = inp                                       # (B,H,N,P), (B,H)
        h_prev = h
        h = h * dec[:, :, None, None] + s_c.astype(jnp.float32)
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (B,nc,H,N,P)

    # inter-chunk contribution: y_inter_i = C_i . (exp(la_i) * h_{c-1})
    inter_decay = jnp.exp(la)                                # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc,
                         inter_decay.astype(x.dtype),
                         h_prevs.astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S_pad, H, P)[:, :S]
    return y, h_final


def block_forward(lp: PyTree, cfg: ArchConfig, x_in: jax.Array,
                  h0: jax.Array | None = None,
                  return_state: bool = False):
    """One Mamba2 block (residual included).  x_in: (B, S, D)."""
    d = dims(cfg)
    h = layers.rmsnorm(lp["norm"], x_in)
    zxbcdt = layers.linear(lp["in_proj"], h)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, lp["conv_w"], lp["conv_b"])
    xm = xBC[..., :d["d_inner"]]
    Bm = xBC[..., d["d_inner"]:d["d_inner"] + d["N"]]
    Cm = xBC[..., d["d_inner"] + d["N"]:]
    Bsz, S, _ = xm.shape
    xh = xm.reshape(Bsz, S, d["n_heads"], d["P"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"]).astype(x_in.dtype)
    A = -jnp.exp(lp["A_log"])
    y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm.chunk, h0)
    y = y + xh * lp["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, d["d_inner"])
    y = layers.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z))
    out = x_in + layers.linear(lp["out_proj"], y)
    if return_state:
        conv_state = jnp.concatenate(
            [jnp.zeros((Bsz, max(d["W"] - 1 - S, 0), d["conv_ch"]),
                       zxbcdt.dtype),
             _pre_conv(lp, cfg, h)[:, -(d["W"] - 1):, :]], axis=1)
        return out, (h_final, conv_state)
    return out


def _pre_conv(lp: PyTree, cfg: ArchConfig, h_normed: jax.Array) -> jax.Array:
    """Raw (pre-conv) xBC channels — what the decode conv state stores."""
    zxbcdt = layers.linear(lp["in_proj"], h_normed)
    _, xBC, _ = _split_proj(cfg, zxbcdt)
    return xBC


def block_decode(lp: PyTree, cfg: ArchConfig, x_in: jax.Array,
                 h: jax.Array, conv_state: jax.Array):
    """One-token recurrence.  x_in: (B, 1, D); h: (B, H, N, P);
    conv_state: (B, W-1, conv_ch) raw xBC history."""
    d = dims(cfg)
    hn = layers.rmsnorm(lp["norm"], x_in)
    zxbcdt = layers.linear(lp["in_proj"], hn)
    z, xBC_new, dt_raw = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_state, xBC_new], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, lp["conv_w"]) + lp["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]
    xm = xBC[..., :d["d_inner"]]
    Bm = xBC[..., d["d_inner"]:d["d_inner"] + d["N"]][:, 0]  # (B, N)
    Cm = xBC[..., d["d_inner"] + d["N"]:][:, 0]
    Bsz = xm.shape[0]
    xh = xm.reshape(Bsz, d["n_heads"], d["P"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])[:, 0]
    A = -jnp.exp(lp["A_log"])
    a = jnp.exp(A[None, :] * dt)                             # (B, H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt.astype(xh.dtype), Bm, xh)
    h = h * a[:, :, None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bn,bhnp->bhp", Cm, h.astype(xh.dtype))
    y = y + xh * lp["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(Bsz, 1, d["d_inner"])
    y = layers.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z))
    out = x_in + layers.linear(lp["out_proj"], y)
    return out, (h, window[:, 1:, :])


# ---------------------------------------------------------------------------
# Full model (mamba2-780m): stacked blocks + embedding/unembed
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    lkeys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, dtype))(lkeys)
    return {
        "embed": layers.embed_init(k_embed, cfg.vocab_padded, cfg.d_model,
                                   dtype),
        "layers": stacked,
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": layers.linear_init(k_head, cfg.d_model, cfg.vocab_padded,
                                      dtype),
    }


def forward(params: PyTree, cfg: ArchConfig, batch: dict,
            remat: bool = False):
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)

    def body(x, lp):
        return block_forward(lp, cfg, x), jnp.zeros((), jnp.float32)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(body, x, params["layers"])
    x = layers.rmsnorm(params["final_norm"], x)
    return layers.linear(params["lm_head"], x), jnp.sum(aux)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> PyTree:
    del max_len                                      # state is O(1) in seq
    d = dims(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    L = cfg.num_layers
    return {
        "h": jnp.zeros((L, batch_size, d["n_heads"], d["N"], d["P"]),
                       jnp.float32),
        "conv": jnp.zeros((L, batch_size, d["W"] - 1, d["conv_ch"]), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill(params: PyTree, cfg: ArchConfig, batch: dict, max_len: int):
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)
    S = x.shape[1]

    def body(x, lp):
        out, (h, conv) = block_forward(lp, cfg, x, return_state=True)
        return out, (h, conv)

    x, (hs, convs) = jax.lax.scan(body, x, params["layers"])
    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x[:, -1:, :])
    cache = {"h": hs, "conv": convs,
             "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params: PyTree, cfg: ArchConfig, token: jax.Array,
                cache: PyTree):
    x = layers.maybe_shard(layers.embed(params["embed"], token),
                           "batch", None, None)

    def body(x, scanned):
        lp, h, conv = scanned
        out, (h, conv) = block_decode(lp, cfg, x, h, conv)
        return out, (h, conv)

    x, (hs, convs) = jax.lax.scan(
        body, x, (params["layers"], cache["h"], cache["conv"]))
    x = layers.rmsnorm(params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x)
    return logits, {"h": hs, "conv": convs, "length": cache["length"] + 1}
