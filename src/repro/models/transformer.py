"""Decoder-only transformer family (llama3.2, granite, tinyllama, chatglm3,
qwen2-vl backbone, mixtral w/ MoE + sliding window).

Layers are *stacked* (every layer-param leaf carries a leading ``L`` dim) and
driven by ``lax.scan`` — one compiled layer body regardless of depth, which
keeps dry-run lowering/compile times sane at 80 layers and makes the remat
policy a single ``jax.checkpoint`` around the scanned body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, moe as moe_lib

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def attn_config(cfg: ArchConfig) -> layers.AttnConfig:
    return layers.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.resolved_head_dim, rope=cfg.rope,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
        window=cfg.window, causal=True)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, dtype) -> PyTree:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": layers.attn_init(k1, attn_config(cfg), dtype),
        "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.moe, dtype)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                   dtype)
    return p


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = _dtype(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": layers.embed_init(k_embed, cfg.vocab_padded, cfg.d_model,
                                   dtype),
        "layers": stacked,
        "final_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.linear_init(k_head, cfg.d_model,
                                               cfg.vocab_padded, dtype)
    return params


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def make_positions(cfg: ArchConfig, B: int, S: int,
                   offset: jax.Array | int = 0) -> jax.Array:
    """Default position ids per rope flavour (explicit ids may override —
    qwen2-vl's M-RoPE ids come from the batch)."""
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope == "2d":
        return jnp.stack([pos, pos])
    if cfg.rope == "mrope":
        return jnp.stack([pos, pos, pos])
    return pos


def _abs_positions(positions: jax.Array) -> jax.Array:
    return positions if positions.ndim == 2 else positions[0]


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ArchConfig, acfg: layers.AttnConfig, lp: PyTree,
               x: jax.Array, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    h = layers.norm_apply(cfg.norm, lp["attn_norm"], x)
    x = x + layers.attention(lp["attn"], acfg, h, positions)
    h = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
    if cfg.moe is not None:
        # vmap group mode: training only (remat bounds live group buffers;
        # see moe_apply)
        out, aux = moe_lib.moe_apply(lp["moe"], cfg.moe, h,
                                     group_mode="vmap")
    else:
        out, aux = layers.mlp(lp["mlp"], h, cfg.mlp_kind), jnp.zeros((), jnp.float32)
    return x + out, aux


def embed_inputs(params: PyTree, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Token embedding + modality-frontend merge (vision stub: precomputed
    patch embeddings overwrite the leading positions)."""
    x = layers.embed(params["embed"], batch["tokens"])
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    # pin the residual stream to the canonical activation layout (batch
    # sharded, d replicated) — a d-sharded embedding otherwise leaks model-
    # sharding into every layer (EXPERIMENTS.md §Perf, qwen iteration 3)
    return layers.maybe_shard(x, "batch", None, None)


def forward(params: PyTree, cfg: ArchConfig, batch: dict,
            remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    B, S = batch["tokens"].shape
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, B, S)
    acfg = attn_config(cfg)

    def body(x, lp):
        out, aux = _layer_fwd(cfg, acfg, lp, x, positions)
        return out, aux

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(body, x, params["layers"])
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = unembed(params, cfg, x)
    return logits, jnp.sum(aux)


def unembed(params: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"]["embedding"].T.astype(x.dtype)
    return layers.linear(params["lm_head"], x)


# ---------------------------------------------------------------------------
# KV cache serving
# ---------------------------------------------------------------------------

def cache_capacity(cfg: ArchConfig, max_len: int) -> int:
    """Rolling-buffer capacity: windowed archs cap the cache at the window
    (what a production server does for SWA models)."""
    return min(max_len, cfg.window) if cfg.window else max_len


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> PyTree:
    dtype = _dtype(cfg)
    C = cache_capacity(cfg, max_len)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch_size, C, cfg.n_kv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # absolute position stored in each slot (-1 = empty)
        "slot_pos": jnp.full((batch_size, C), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),    # tokens seen so far
    }


def prefill(params: PyTree, cfg: ArchConfig, batch: dict,
            max_len: int) -> tuple[jax.Array, PyTree]:
    """Run the full prompt, build the cache, return last-token logits."""
    x = embed_inputs(params, cfg, batch)
    B, S = batch["tokens"].shape
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, B, S)
    acfg = attn_config(cfg)
    C = cache_capacity(cfg, max_len)
    abs_pos = _abs_positions(positions)

    def body(x, lp):
        h = layers.norm_apply(cfg.norm, lp["attn_norm"], x)
        k, v = layers.project_kv(lp["attn"], acfg, h, positions)
        x = x + layers.attention(lp["attn"], acfg, h, positions,
                                 kv_override=(k, v), kv_positions=abs_pos)
        h2 = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
        if cfg.moe is not None:
            out, _ = moe_lib.moe_apply(lp["moe"], cfg.moe, h2)
        else:
            out = layers.mlp(lp["mlp"], h2, cfg.mlp_kind)
        # cache entries leave the scan in their final split-KV layout
        # (seq on "model") — otherwise the stacked ys materialize
        # replicated inside the loop (EXPERIMENTS.md §Perf, qwen iter 4)
        k = layers.maybe_shard(k, "batch", "model", None, None)
        v = layers.maybe_shard(v, "batch", "model", None, None)
        return x + out, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = unembed(params, cfg, x[:, -1:, :])

    # Build the cache from the last C tokens only: their absolute positions
    # map to C *distinct* rolling slots (consecutive ints mod C), so the
    # scatter has no duplicate indices and the newest data always survives.
    # Fast path: standard prefill (positions = 0..S-1, C >= S) needs no
    # scatter at all — slots are the identity, so the cache is a pad.  The
    # scatter path's (B,S) advanced indexing forces GSPMD to replicate the
    # whole cache (see EXPERIMENTS.md §Perf, qwen2-vl prefill iteration).
    hd = cfg.resolved_head_dim
    keep = min(C, S)
    ks_last, vs_last = ks[:, :, S - keep:], vs[:, :, S - keep:]
    pos_last = abs_pos[:, S - keep:]
    # (when C >= S nothing wraps, so "slot i holds token i" is always a
    # valid layout: decode continues writing at slot length % C == S, and
    # masking reads absolute positions from slot_pos, never from slot ids)
    if C >= S:
        pad = ((0, 0), (0, 0), (0, C - S), (0, 0), (0, 0))
        cache_k = jnp.pad(ks_last, pad)
        cache_v = jnp.pad(vs_last, pad)
        slot_pos = jnp.pad(pos_last, ((0, 0), (0, C - S)),
                           constant_values=-1)
    else:
        slots = pos_last % C                                # (B, keep)
        cache_k = jnp.zeros((cfg.num_layers, B, C, cfg.n_kv, hd), ks.dtype)
        cache_v = jnp.zeros_like(cache_k)
        bidx = jnp.arange(B)[:, None]
        cache_k = cache_k.at[:, bidx, slots].set(ks_last)
        cache_v = cache_v.at[:, bidx, slots].set(vs_last)
        slot_pos = jnp.full((B, C), -1,
                            jnp.int32).at[bidx, slots].set(pos_last)
    cache = {"k": cache_k, "v": cache_v, "slot_pos": slot_pos,
             "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params: PyTree, cfg: ArchConfig, token: jax.Array,
                cache: PyTree) -> tuple[jax.Array, PyTree]:
    """One-token decode against the cache.

    token: (B, 1) int32.  Returns (logits (B,1,V), updated cache).
    """
    B = token.shape[0]
    pos_scalar = cache["length"]
    positions = make_positions(cfg, B, 1, offset=pos_scalar)
    acfg = attn_config(cfg)
    x = layers.embed(params["embed"], token)
    C = cache["k"].shape[2]
    slot = pos_scalar % C
    abs_pos = _abs_positions(positions)                     # (B, 1)
    slot_pos = cache["slot_pos"].at[:, slot].set(abs_pos[:, 0])
    kv_valid = slot_pos >= 0                                # (B, C)
    kv_positions = jnp.maximum(slot_pos, 0)

    def body(x, scanned):
        lp, ck, cv = scanned
        h = layers.norm_apply(cfg.norm, lp["attn_norm"], x)
        k, v = layers.project_kv(lp["attn"], acfg, h, positions)  # (B,1,kv,hd)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        x = x + layers.attention(lp["attn"], acfg, h, positions,
                                 kv_override=(ck, cv),
                                 kv_positions=kv_positions,
                                 kv_valid=kv_valid)
        h2 = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
        if cfg.moe is not None:
            out, _ = moe_lib.moe_apply(lp["moe"], cfg.moe, h2)
        else:
            out = layers.mlp(lp["mlp"], h2, cfg.mlp_kind)
        return x + out, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(body, x,
                                     (params["layers"], cache["k"], cache["v"]))
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = unembed(params, cfg, x)
    new_cache = {"k": new_k, "v": new_v, "slot_pos": slot_pos,
                 "length": pos_scalar + 1}
    return logits, new_cache
