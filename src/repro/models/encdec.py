"""Encoder-decoder backbone (seamless-m4t-large-v2, arXiv:2308.11596).

Modality frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d) — the speech feature extractor
never runs here.  The backbone is a standard enc-dec transformer: a
bidirectional encoder over frames and a causal decoder with cross-attention.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, transformer

PyTree = Any


def _acfg(cfg: ArchConfig, causal: bool) -> layers.AttnConfig:
    return dataclasses.replace(transformer.attn_config(cfg), causal=causal)


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 6)
    acfg = transformer.attn_config(cfg)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
            "attn": layers.attn_init(k1, acfg, dtype),
            "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                   dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
            "self_attn": layers.attn_init(k1, acfg, dtype),
            "cross_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
            "cross_attn": layers.attn_init(k2, acfg, dtype),
            "mlp_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                   dtype),
        }

    enc = jax.vmap(enc_layer)(jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(dec_layer)(jax.random.split(ks[1], cfg.num_layers))
    return {
        "embed": layers.embed_init(ks[2], cfg.vocab_padded, cfg.d_model,
                                   dtype),
        "encoder": enc,
        "decoder": dec,
        "enc_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "final_norm": layers.norm_init(cfg.norm, cfg.d_model, dtype),
        "lm_head": layers.linear_init(ks[3], cfg.d_model, cfg.vocab_padded,
                                      dtype),
    }


def encode(params: PyTree, cfg: ArchConfig, frames: jax.Array,
           remat: bool = False) -> jax.Array:
    """frames: (B, S_enc, d) precomputed frame embeddings (frontend stub)."""
    acfg = _acfg(cfg, causal=False)
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = frames

    def body(x, lp):
        h = layers.norm_apply(cfg.norm, lp["attn_norm"], x)
        x = x + layers.attention(lp["attn"], acfg, h, positions)
        h = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
        return x + layers.mlp(lp["mlp"], h, cfg.mlp_kind), None

    if remat:
        # without this, the microbatch scan stashes every microbatch's
        # encoder activations in fp32 (EXPERIMENTS.md §Perf, seamless note)
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.norm_apply(cfg.norm, params["enc_norm"], x)


def _decoder_layer(cfg: ArchConfig, lp: PyTree, x, positions, memory,
                   self_kv=None, kv_positions=None, kv_valid=None):
    acfg = transformer.attn_config(cfg)
    h = layers.norm_apply(cfg.norm, lp["self_norm"], x)
    kw = {}
    if self_kv is not None:
        kw = dict(kv_override=self_kv, kv_positions=kv_positions,
                  kv_valid=kv_valid)
    x = x + layers.attention(lp["self_attn"], acfg, h, positions, **kw)
    h = layers.norm_apply(cfg.norm, lp["cross_norm"], x)
    if isinstance(memory, tuple):       # precomputed cross K/V (decode path)
        x = x + layers.attention(lp["cross_attn"], acfg, h, positions,
                                 kv_override=memory,
                                 kv_positions=jnp.zeros(
                                     (x.shape[0], memory[0].shape[1]),
                                     jnp.int32),
                                 kv_valid=jnp.ones(
                                     (x.shape[0], memory[0].shape[1]), bool))
    else:
        x = x + layers.attention(lp["cross_attn"], acfg, h, positions,
                                 cross_kv=memory)
    h = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
    return x + layers.mlp(lp["mlp"], h, cfg.mlp_kind)


def forward(params: PyTree, cfg: ArchConfig, batch: dict,
            remat: bool = False):
    """Teacher-forced training forward.  batch: frames + tokens."""
    memory = encode(params, cfg, batch["frames"], remat=remat)
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        return _decoder_layer(cfg, lp, x, positions, memory), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    return layers.linear(params["lm_head"], x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               enc_len: int = 0) -> PyTree:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    enc_len = enc_len or max_len
    return {
        "k": jnp.zeros((L, batch_size, max_len, cfg.n_kv, hd), dtype),
        "v": jnp.zeros((L, batch_size, max_len, cfg.n_kv, hd), dtype),
        "cross_k": jnp.zeros((L, batch_size, enc_len, cfg.n_kv, hd), dtype),
        "cross_v": jnp.zeros((L, batch_size, enc_len, cfg.n_kv, hd), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def prefill(params: PyTree, cfg: ArchConfig, batch: dict, max_len: int):
    """Encode source frames, project cross-K/V once per layer, and prime the
    decoder self-cache with the prompt tokens."""
    memory = encode(params, cfg, batch["frames"])
    B, S = batch["tokens"].shape
    acfg = transformer.attn_config(cfg)
    x = layers.maybe_shard(layers.embed(params["embed"], batch["tokens"]),
                           "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    zero_pos = jnp.zeros((B, memory.shape[1]), jnp.int32)

    def body(x, lp):
        h = layers.norm_apply(cfg.norm, lp["self_norm"], x)
        k, v = layers.project_kv(lp["self_attn"], acfg, h, positions)
        x = x + layers.attention(lp["self_attn"], acfg, h, positions,
                                 kv_override=(k, v), kv_positions=positions)
        ck = layers.linear(lp["cross_attn"]["wk"], memory).reshape(
            B, -1, cfg.n_kv, cfg.resolved_head_dim)
        cv = layers.linear(lp["cross_attn"]["wv"], memory).reshape(
            B, -1, cfg.n_kv, cfg.resolved_head_dim)
        h = layers.norm_apply(cfg.norm, lp["cross_norm"], x)
        x = x + layers.attention(
            lp["cross_attn"], acfg, h, positions, kv_override=(ck, cv),
            kv_positions=zero_pos,
            kv_valid=jnp.ones((B, memory.shape[1]), bool))
        h = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
        x = x + layers.mlp(lp["mlp"], h, cfg.mlp_kind)
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["decoder"])
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x[:, -1:, :])
    hd = cfg.resolved_head_dim
    pad = max_len - S
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "cross_k": cks, "cross_v": cvs,
        "length": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


def decode_step(params: PyTree, cfg: ArchConfig, token: jax.Array,
                cache: PyTree):
    B = token.shape[0]
    pos_scalar = cache["length"]
    positions = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    acfg = transformer.attn_config(cfg)
    x = layers.maybe_shard(layers.embed(params["embed"], token),
                           "batch", None, None)
    C = cache["k"].shape[2]
    kv_positions = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    kv_valid = kv_positions <= pos_scalar

    def body(x, scanned):
        lp, ck, cv, xk, xv = scanned
        h = layers.norm_apply(cfg.norm, lp["self_norm"], x)
        k, v = layers.project_kv(lp["self_attn"], acfg, h, positions)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos_scalar, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos_scalar, 0, 0))
        x = x + layers.attention(lp["self_attn"], acfg, h, positions,
                                 kv_override=(ck, cv),
                                 kv_positions=kv_positions, kv_valid=kv_valid)
        h = layers.norm_apply(cfg.norm, lp["cross_norm"], x)
        x = x + layers.attention(
            lp["cross_attn"], acfg, h, positions, kv_override=(xk, xv),
            kv_positions=jnp.zeros((B, xk.shape[1]), jnp.int32),
            kv_valid=jnp.ones((B, xk.shape[1]), bool))
        h = layers.norm_apply(cfg.norm, lp["mlp_norm"], x)
        x = x + layers.mlp(lp["mlp"], h, cfg.mlp_kind)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = layers.linear(params["lm_head"], x)
    new_cache = dict(cache, k=ks, v=vs, length=pos_scalar + 1)
    return logits, new_cache
