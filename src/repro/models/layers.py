"""Shared model layers: norms, rotary variants, GQA attention (with KV cache
and sliding windows), and gated MLPs.  Pure-functional: params are plain
dicts, every function is ``jit``/``scan``/``pjit`` friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """Best-effort sharding constraint against the ambient mesh.

    ``spec`` entries: axis name, tuple of names, None, or the sentinel
    "batch" (resolved to ("pod","data") on the multi-pod mesh, ("data",) on
    the single-pod mesh).  Outside a mesh context (unit tests) this is a
    no-op.  Uneven dims are fine — GSPMD pads (llama's 24 heads on the
    16-way model axis).
    """
    from jax.sharding import PartitionSpec as _P
    candidates = []
    for batch_axes in (("pod", "data"), "data", None):
        resolved = tuple(batch_axes if s == "batch" else s for s in spec)
        candidates.append(resolved)
    candidates.append(tuple(None for _ in spec))
    for cand in candidates:
        try:
            return jax.lax.with_sharding_constraint(x, _P(*cand))
        except Exception:                                    # noqa: BLE001
            continue
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"].astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(dt) * p["scale"].astype(dt) + p["bias"].astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> PyTree:
    return rmsnorm_init(d, dtype) if kind == "rms" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: PyTree, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rms" else layernorm(p, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings (1D, 2D-ChatGLM, 3D M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0,
                     rotary_dim: Optional[int] = None) -> jax.Array:
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs (even, odd) of the last dim by per-pair angles.

    x: (..., rd) with rd even; angles: broadcastable (..., rd//2).
    """
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rotary_frac: float = 1.0) -> jax.Array:
    """Standard 1D RoPE.  x: (B, S, H, D); positions: (B, S) int.

    ``rotary_frac < 1`` rotates only the leading fraction of head dims
    (ChatGLM's 2D-RoPE rotates half and leaves half as NoPE-style passthrough
    for the second positional channel; see apply_rope_2d).
    """
    D = x.shape[-1]
    rd = int(D * rotary_frac)
    rd -= rd % 2
    freqs = rope_frequencies(D, theta, rd)                  # (rd/2,)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # (B,S,1,rd/2)
    rotated = _rotate(x[..., :rd].astype(jnp.float32), ang).astype(x.dtype)
    return jnp.concatenate([rotated, x[..., rd:]], axis=-1) if rd < D else rotated


def apply_rope_2d(x: jax.Array, positions: jax.Array,
                  theta: float = 10000.0) -> jax.Array:
    """ChatGLM-style 2D RoPE: the head dim is split in halves, each rotated
    by its own positional channel.  positions: (2, B, S)."""
    D = x.shape[-1]
    half = D // 2
    a = apply_rope(x[..., :half], positions[0], theta)
    b = apply_rope(x[..., half:], positions[1], theta)
    return jnp.concatenate([a, b], axis=-1)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """Qwen2-VL M-RoPE: rotary pairs are partitioned into (temporal, h, w)
    sections, each driven by its own position id.  positions: (3, B, S);
    ``sections`` are pair counts summing to D//2 (e.g. (16, 24, 24) for
    D=128)."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    freqs = rope_frequencies(D, theta)                      # (D/2,)
    # choose the position channel per frequency-pair index
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=D // 2)
    pos = positions[sec_id, :, :]                           # (D/2, B, S)
    ang = jnp.einsum("dbs,d->bsd", pos.astype(jnp.float32), freqs)
    return _rotate(x.astype(jnp.float32), ang[:, :, None, :]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, dtype=jnp.float32,
                bias: bool = False) -> PyTree:
    w = jax.random.normal(key, (d_in, d_out), dtype) / math.sqrt(d_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: PyTree, x: jax.Array) -> jax.Array:
    out = x @ p["w"].astype(x.dtype)
    if "b" in p:
        out = out + p["b"].astype(x.dtype)
    return out


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> PyTree:
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: PyTree, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / bidirectional / sliding window, KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope: str = "1d"                 # "1d" | "2d" | "mrope" | "none"
    rope_theta: float = 10000.0
    rope_frac: float = 1.0
    mrope_sections: tuple[int, ...] = ()
    window: int = 0                  # sliding window (0 = full)
    causal: bool = True
    qkv_bias: bool = False


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> PyTree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": linear_init(k1, cfg.d_model, cfg.n_heads * cfg.head_dim, dtype,
                          bias=cfg.qkv_bias),
        "wk": linear_init(k2, cfg.d_model, cfg.n_kv * cfg.head_dim, dtype,
                          bias=cfg.qkv_bias),
        "wv": linear_init(k3, cfg.d_model, cfg.n_kv * cfg.head_dim, dtype,
                          bias=cfg.qkv_bias),
        "wo": linear_init(k4, cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }


def _apply_positional(cfg: AttnConfig, x: jax.Array,
                      positions: jax.Array) -> jax.Array:
    if cfg.rope == "1d":
        return apply_rope(x, positions, cfg.rope_theta, cfg.rope_frac)
    if cfg.rope == "2d":
        return apply_rope_2d(x, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return x


def _mask_bias(cfg: AttnConfig, q_pos: jax.Array, kv_pos: jax.Array,
               kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """(B?, Sq, Skv) additive mask from causality + window + cache validity.

    q_pos: (B, Sq); kv_pos: (B, Skv) absolute positions.
    """
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    ok = jnp.ones(q.shape[:1] + (q.shape[1], k.shape[2]), bool)
    if cfg.causal:
        ok &= k <= q
    if cfg.window:
        ok &= k > q - cfg.window
    if kv_valid is not None:
        ok &= kv_valid[:, None, :]
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


ATTN_CHUNK = 1024     # query-chunk length for memory-efficient attention


def _attend_block(cfg: AttnConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                  bias: jax.Array) -> jax.Array:
    """One (q-chunk x kv) attention block.  q: (B,Sq,H,D); k/v: (B,Skv,H,D)
    (kv already expanded to full heads); bias: (B,Sq,Skv) additive."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(cfg.head_dim)
    scores = maybe_shard(scores, "batch", "model", None, None)
    probs = jax.nn.softmax(scores + bias[:, None], axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _attend_decode(cfg: AttnConfig, q: jax.Array, k: jax.Array,
                   v: jax.Array, bias: jax.Array) -> jax.Array:
    """Short-query (decode) attention: grouped GQA einsum against the cache
    in its NATIVE layout — no kv repeat, no sharding constraint.  The
    head_dim contraction over the model-sharded cache becomes partial
    scores + a tiny all-reduce; forcing head-sharded scores here would make
    GSPMD rematerialize the whole cache (EXPERIMENTS.md §Perf, arctic)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, H // KV, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(D)
    probs = jax.nn.softmax(scores + bias[:, None, None], axis=-1
                           ).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H * D)


def _attend(cfg: AttnConfig, q: jax.Array, k: jax.Array, v: jax.Array,
            q_abs: Optional[jax.Array], kv_abs: Optional[jax.Array],
            kv_valid: Optional[jax.Array], masked: bool,
            chunk: int = ATTN_CHUNK) -> jax.Array:
    """Chunked GQA attention core: queries processed in chunks so the score
    tensor never exceeds (B, H, chunk, Skv); causal chunks also truncate the
    KV span they can see (halves the quadratic work)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if Sq <= 8 and Skv > Sq:      # decode against a cache
        if masked:
            bias = _mask_bias(cfg, q_abs, kv_abs, kv_valid)
        elif kv_valid is not None:
            bias = jnp.where(kv_valid[:, None, :], 0.0, -1e30)
        else:
            bias = jnp.zeros((B, Sq, Skv), jnp.float32)
        return _attend_decode(cfg, q, k, v, bias)
    groups = cfg.n_heads // cfg.n_kv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)

    def bias_for(q_abs_c, lo, hi, qlen):
        if not masked:
            if kv_valid is not None:
                return jnp.where(kv_valid[:, None, lo:hi], 0.0, -1e30)
            return jnp.zeros((B, qlen, hi - lo), jnp.float32)
        kvv = kv_valid[:, lo:hi] if kv_valid is not None else None
        return _mask_bias(cfg, q_abs_c, kv_abs[:, lo:hi], kvv)

    if Sq <= chunk:
        out = _attend_block(cfg, q, k, v, bias_for(q_abs, 0, Skv, Sq))
    else:
        assert Sq % chunk == 0, (Sq, chunk)
        outs = []
        causal_trunc = (masked and cfg.causal and kv_abs is not None
                        and Sq == Skv)
        for i in range(Sq // chunk):
            qc = q[:, i * chunk:(i + 1) * chunk]
            qa = (q_abs[:, i * chunk:(i + 1) * chunk]
                  if q_abs is not None else None)
            lo = 0
            hi = (i + 1) * chunk if causal_trunc else Skv
            if causal_trunc and cfg.window:
                lo = max(0, (i + 1) * chunk - cfg.window - chunk)
            outs.append(_attend_block(cfg, qc, k[:, lo:hi], v[:, lo:hi],
                                      bias_for(qa, lo, hi, chunk)))
        out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, H * D)


def attention(p: PyTree, cfg: AttnConfig, x: jax.Array,
              positions: jax.Array,
              kv_override: Optional[tuple[jax.Array, jax.Array]] = None,
              kv_positions: Optional[jax.Array] = None,
              kv_valid: Optional[jax.Array] = None,
              cross_kv: Optional[jax.Array] = None) -> jax.Array:
    """General GQA attention.

    x: (B, Sq, d); positions: (B, Sq) (or (2/3, B, Sq) for 2d/mrope).
    kv_override: precomputed (k, v) each (B, Skv, n_kv, hd) — decode cache or
    cross-attention memory.  kv_positions/(B, Skv) and kv_valid mask apply.
    cross_kv: (B, Skv, d) source sequence for cross-attention (k/v projected
    from it, no positional rotation).
    """
    B, Sq, _ = x.shape
    q = linear(p["wq"], x).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    q = _apply_positional(cfg, q, positions)

    if kv_override is not None:
        k, v = kv_override
    elif cross_kv is not None:
        Skv = cross_kv.shape[1]
        k = linear(p["wk"], cross_kv).reshape(B, Skv, cfg.n_kv, cfg.head_dim)
        v = linear(p["wv"], cross_kv).reshape(B, Skv, cfg.n_kv, cfg.head_dim)
    else:
        k = linear(p["wk"], x).reshape(B, Sq, cfg.n_kv, cfg.head_dim)
        v = linear(p["wv"], x).reshape(B, Sq, cfg.n_kv, cfg.head_dim)
        k = _apply_positional(cfg, k, positions)

    if cross_kv is not None:
        out = _attend(cfg, q, k, v, None, None, kv_valid, masked=False)
    else:
        q_abs = positions if positions.ndim == 2 else positions[0]
        kv_abs = kv_positions if kv_positions is not None else (
            q_abs if kv_override is None else None)
        assert kv_abs is not None, "kv_positions required with kv_override"
        out = _attend(cfg, q, k, v, q_abs, kv_abs, kv_valid, masked=True)
    return linear(p["wo"], out)


def project_kv(p: PyTree, cfg: AttnConfig, x: jax.Array,
               positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """K/V projection for cache fill.  x: (B, S, d) -> (B, S, n_kv, hd)."""
    B, S, _ = x.shape
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv, cfg.head_dim)
    k = _apply_positional(cfg, k, positions)
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"w_gate": linear_init(k1, d_model, d_ff, dtype),
                "w_up": linear_init(k2, d_model, d_ff, dtype),
                "w_down": linear_init(k3, d_ff, d_model, dtype)}
    return {"w_up": linear_init(k1, d_model, d_ff, dtype),
            "w_down": linear_init(k2, d_ff, d_model, dtype)}


def mlp(p: PyTree, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        return linear(p["w_down"],
                      jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x))
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], x)))
